"""Fault tolerance: SIGKILL a training run mid-flight; auto-resume must
continue from the last COMMITted checkpoint and reach the same final state
as an uninterrupted run (bit-exact: same data cursor, same step count)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_train(ckpt_dir, steps, crash_at=0, auto_resume=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "tinyllama-42m", "--smoke",
           "--steps", str(steps), "--batch", "2", "--seq-len", "32",
           "--ckpt-dir", ckpt_dir, "--ckpt-every", "5", "--log-every", "5"]
    if crash_at:
        cmd += ["--crash-at-step", str(crash_at)]
    if auto_resume:
        cmd += ["--auto-resume"]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1200)


@pytest.mark.slow
def test_crash_resume_matches_uninterrupted(tmp_path):
    # uninterrupted reference
    r0 = _run_train(str(tmp_path / "ref"), steps=15)
    assert r0.returncode == 0, r0.stderr[-2000:]
    ref_line = [ln for ln in r0.stdout.splitlines()
                if ln.startswith("step    15")]
    assert ref_line, r0.stdout

    # crashed at step 8 (checkpoint exists at 5), then resumed
    r1 = _run_train(str(tmp_path / "ft"), steps=15, crash_at=8)
    assert r1.returncode == 17          # fault injection exit
    assert "[fault-injection]" in r1.stdout
    r2 = _run_train(str(tmp_path / "ft"), steps=15)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] step 5" in r2.stdout
    res_line = [ln for ln in r2.stdout.splitlines()
                if ln.startswith("step    15")]
    assert res_line, r2.stdout

    # same final loss (same params/opt/data stream => identical trajectory)
    def loss_of(line):
        return float(line[0].split("loss")[1].split()[0])
    assert abs(loss_of(ref_line) - loss_of(res_line)) < 1e-4


def test_supervisor_restarts_until_success(tmp_path):
    """runtime.ft.supervise restarts a failing command."""
    from repro.runtime.ft import FTConfig, supervise
    marker = tmp_path / "ran"
    script = (f"import os,sys; p=r'{marker}'; "
              "n=int(open(p).read()) if os.path.exists(p) else 0; "
              "open(p,'w').write(str(n+1)); sys.exit(0 if n>=2 else 1)")
    code = supervise([sys.executable, "-c", script],
                     FTConfig(max_restarts=5, restart_backoff_s=0.01))
    assert code == 0
    assert int(open(marker).read()) == 3


def test_hedged_router_mitigates_straggler():
    import time
    from repro.runtime.straggler import HedgedRouter
    calls = {"a": 0, "b": 0}

    def slow(req):
        calls["a"] += 1
        time.sleep(0.25)
        return ("slow", req)

    def fast(req):
        calls["b"] += 1
        return ("fast", req)

    router = HedgedRouter([slow, fast], hedge_after_s=0.03)
    out = router(42)
    assert out == ("fast", 42)          # hedge won
    assert router.stats.hedged == 1
