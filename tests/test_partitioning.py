"""Partitioning correctness: TP-equivalence (subprocess, 8 devices) +
layout algebra unit tests."""
import os
import subprocess
import sys

import pytest

from repro.core.partition import ShardingPlan, dim_layout, head_layout

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_tp_equivalence_subprocess():
    """loss/grads/decode logits identical between tp=1 and (data=2,model=4).
    Runs tests/tp_equiv_main.py under 8 host devices (~10 min on 1 CPU)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tests", "tp_equiv_main.py")],
                       capture_output=True, text=True, env=env,
                       timeout=3000)
    assert "ALL-OK" in r.stdout, r.stdout[-3000:] + r.stderr[-2000:]


# ---------------------------------------------------------------------------
# head layout algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv,tp", [
    (16, 8, 16), (32, 16, 16), (96, 8, 16), (48, 8, 16), (25, 5, 16),
    (16, 16, 16), (8, 8, 4), (64, 64, 16), (6, 2, 4), (32, 8, 16),
])
def test_head_layout_covers_all_heads(hq, hkv, tp):
    hl = head_layout(hq, hkv, tp)
    group = hq // hkv
    assert hl.hq_pad % tp == 0 and hl.hq_loc * tp == hl.hq_pad
    # every REAL q head is assigned the correct kv head
    for i in range(tp):
        for j in range(hl.hq_loc):
            h = i * hl.hq_loc + j
            if h >= hq:
                continue
            slot = j // hl.r
            assert hl.kv_map[i][slot] == h // group, (i, j, h)
    # every kv head is stored somewhere
    stored = {k for row in hl.kv_map for k in row}
    assert stored == set(range(hkv))


def test_head_layout_no_dup_when_divisible():
    hl = head_layout(64, 64, 16)
    assert hl.kv_duplication == 1.0


def test_head_layout_dup_factor_gqa():
    hl = head_layout(16, 8, 16)     # gemma3-12b: kv replicated 2x
    assert hl.kv_duplication == 2.0


@pytest.mark.parametrize("n,tp", [(3072, 16), (1408, 16), (50280, 16),
                                  (100, 7)])
def test_dim_layout(n, tp):
    dl = dim_layout(n, tp)
    assert dl.loc * tp == dl.n_pad >= n and dl.n_pad - n < tp


def test_duplication_report_dense_zero():
    from repro.configs import get_config
    from repro.core.partition import duplication_report
    rep = duplication_report(get_config("mistral-large-123b"),
                             ShardingPlan(tp=16))
    # only deviation for dense GQA archs is the documented kv replication
    # (mistral-large: kv=8 duplicated 2x across tp=16 => 1.8% of weights)
    assert rep["dup_fraction"] < 0.02
    assert rep["pad_fraction"] < 0.01


@pytest.mark.slow
def test_zero1_equivalence_subprocess():
    """ZeRO-1 optimizer sharding follows the identical loss trajectory."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable,
                        os.path.join(ROOT, "tests", "zero1_equiv_main.py")],
                       capture_output=True, text=True, env=env, timeout=1800)
    assert "ZERO1-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-1500:]
