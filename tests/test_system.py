"""End-to-end behaviour tests: serving engine, analytics, attention module,
collectives ledger, shape-support matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import SHAPES, ShapeConfig, shape_supported
from repro.core import analytics, collectives as cc, model, steps
from repro.core.partition import ShardingPlan


# ---------------------------------------------------------------------------
# attention module vs kernel oracle
# ---------------------------------------------------------------------------

def test_core_flash_matches_ref():
    from repro.core.attention import flash_attention
    from repro.kernels import ref
    rng = np.random.RandomState(0)
    B, G, R, S, D = 2, 2, 3, 96, 32
    q = jnp.asarray(rng.randn(B, G, R, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, G, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, G, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    for b in range(B):
        for g in range(G):
            for r in range(R):
                expect = ref.ref_flash_attention(q[b, g, r][None],
                                                 k[b, g][None], v[b, g][None])
                np.testing.assert_allclose(np.asarray(out[b, g, r]),
                                           np.asarray(expect[0]),
                                           rtol=1e-4, atol=1e-4)


def test_core_flash_window_matches_ref():
    from repro.core.attention import flash_attention
    from repro.kernels import ref
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 1, 1, 256, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 256, 16), jnp.float32)
    v = jnp.asarray(rng.randn(1, 1, 256, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=48, q_block=64,
                          kv_block=32)
    expect = ref.ref_flash_attention(q[0, 0], k[0], v[0], causal=True,
                                     window=48)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]),
                               np.asarray(expect[0]), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# serving engine end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_engine_end_to_end(mesh1):
    from repro.serving import Request, SamplerConfig, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"))
    plan = ShardingPlan(tp=1)
    params = model.init_params(cfg, plan)
    SB = 64
    dshape = ShapeConfig("s", "decode", SB, 2)
    pshape = ShapeConfig("p", "decode", SB, 1)
    dec, _, _ = steps.make_decode_step(cfg, plan, mesh1, dshape)
    pre, _, _ = steps.make_prefill_step(cfg, plan, mesh1, pshape)
    eng = ServingEngine(cfg, plan, mesh1, 2, SB, params, jax.jit(pre),
                        jax.jit(dec), sampler=SamplerConfig())
    rng = np.random.RandomState(0)
    for rid in range(4):
        eng.submit(Request(rid=rid,
                           prompt=rng.randint(2, cfg.vocab_size, 8,
                                              ).astype(np.int32),
                           max_new_tokens=6))
    stats = eng.run(max_ticks=200)
    assert stats.prefills == 4
    assert stats.decoded_tokens >= 4 * 1
    assert len(stats.ttft_s) == 4


@pytest.mark.slow
def test_greedy_decode_deterministic(mesh1):
    """Same prompt -> same continuation (greedy), incl. after cache reuse."""
    from repro.serving import Request, SamplerConfig, ServingEngine
    cfg = reduced(get_config("qwen3-0.6b"))
    plan = ShardingPlan(tp=1)
    params = model.init_params(cfg, plan)
    SB = 32
    dec, _, _ = steps.make_decode_step(cfg, plan, mesh1,
                                       ShapeConfig("s", "decode", SB, 1))
    pre, _, _ = steps.make_prefill_step(cfg, plan, mesh1,
                                        ShapeConfig("p", "decode", SB, 1))
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, plan, mesh1, 1, SB, params, jax.jit(pre),
                            jax.jit(dec), sampler=SamplerConfig())
        req = Request(rid=0, prompt=np.arange(2, 10, dtype=np.int32),
                      max_new_tokens=5)
        eng.submit(req)
        eng.run(max_ticks=50)
        outs.append(tuple(req.out_tokens))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# analytics / ledger invariants
# ---------------------------------------------------------------------------

def test_analytic_flops_match_cost_analysis_unrolled(mesh1):
    """Analytic model vs XLA cost_analysis on a small UNROLLED module."""
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=2)
    plan = ShardingPlan(tp=1)
    B, S = 2, 128
    from repro.core.partition import model_layout
    lay = model_layout(cfg, plan)
    params = model.abstract_params(cfg, plan)

    def fwd(p, tokens):
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = model.embed_tokens(p, tokens, cfg, plan, lay)
        x, _ = model._run_stack(x, p["stacks"], cfg.layer_groups(), cfg,
                                plan, lay, "train", positions)
        from repro.core.layers import apply_norm
        x = apply_norm(x, p["final_norm"], cfg)
        return model.final_logits(p, x, cfg, lay)

    with mesh1:
        compiled = jax.jit(fwd).lower(
            params, jax.ShapeDtypeStruct((B, S), jnp.int32)).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # JAX < 0.5: one dict per device
        ca = ca[0]
    hlo_flops = ca["flops"]

    cc.set_axis_sizes({"data": 1, "model": 1})
    shape = ShapeConfig("t", "prefill", S, B)
    cost = analytics.step_cost(cfg, plan, shape, {"data": 1, "model": 1})
    analytic = cost.total_flops
    ratio = analytic / hlo_flops
    assert 0.5 < ratio < 2.2, (analytic, hlo_flops)


def test_two_sync_contract_all_dense_archs(mesh1):
    """The ledger audits exactly 2 block syncs per dense layer."""
    cfg = reduced(get_config("mistral-large-123b"))
    plan = ShardingPlan(tp=1)
    shape = ShapeConfig("t", "train", 32, 2)
    cc.LEDGER.start()
    ts, _ = steps.make_train_step(cfg, plan, mesh1, shape=shape)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    jax.eval_shape(ts, steps.abstract_train_state(cfg, plan), batch)
    cc.LEDGER.stop()
    assert cc.LEDGER.sync_count("block/") == 2 * cfg.n_layers


def test_shape_support_matrix():
    from repro.configs import ASSIGNED
    cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    assert len(cells) == 40
    skipped = [(a, s) for a, s in cells
               if not shape_supported(get_config(a), SHAPES[s])[0]]
    assert len(skipped) == 5
    assert all(s == "long_500k" for _, s in skipped)


def test_param_counts_sane():
    expect = {
        "mamba2-370m": (330e6, 460e6),
        "qwen3-0.6b": (500e6, 800e6),
        "gemma3-12b": (10e9, 14.5e9),
        "gemma3-27b": (24e9, 30e9),
        "mistral-large-123b": (115e9, 130e9),
        "deepseek-moe-16b": (15e9, 19e9),
        "mixtral-8x22b": (130e9, 150e9),
        "pixtral-12b": (11e9, 14e9),
        "tinyllama-42m": (30e6, 60e6),
    }
    for name, (lo, hi) in expect.items():
        n = model.param_count(get_config(name))
        assert lo < n < hi, (name, n)


def test_sim_reproduces_paper_claims():
    """Paper Fig.4/5/6 headline numbers within documented tolerance."""
    from benchmarks.fig4_speedup import derived as d4
    from benchmarks.fig5_energy import derived as d5
    from benchmarks.fig6_scalability import derived as d6

    def ratio(s):
        a, b = s.split("/")
        return float(a) / float(b)

    r4 = d4()
    assert 0.8 < ratio(r4["ar_speedup8_sim_vs_paper"]) < 1.25
    assert 0.8 < ratio(r4["prompt_speedup8_sim_vs_paper"]) < 1.25
    assert r4["ar_memory_dominated_1chip"]
    r5 = d5()
    assert 0.7 < ratio(r5["ar8_ms_sim_vs_paper"]) < 1.3
    assert 0.6 < ratio(r5["ar8_mj_sim_vs_paper"]) < 1.4
    assert r5["resident_at_32chips"] and r5["energy_drops_when_resident"]
    r6 = d6()
    assert 0.85 < ratio(r6["ar_speedup64_sim_vs_paper"]) < 1.2
    assert r6["prompt_diminishing_returns_past_16"]


# ---------------------------------------------------------------------------
# beyond-paper optimization paths (§Perf hillclimbs) — correctness
# ---------------------------------------------------------------------------

def test_flash_attention_split_exact():
    """Recursive causal splitting (hillclimb 2) is exact vs the oracle."""
    from repro.core.attention import flash_attention_split
    from repro.kernels import ref
    rng = np.random.RandomState(3)
    B, G, R, S, D = 1, 2, 1, 512, 32
    q = jnp.asarray(rng.randn(B, G, R, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, G, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, G, S, D), jnp.float32)
    out = flash_attention_split(q, k, v, q_block=64, kv_block=64, depth=3)
    for g in range(G):
        expect = ref.ref_flash_attention(q[0, g], k[0, g][None].repeat(R, 0),
                                         v[0, g][None].repeat(R, 0))
        np.testing.assert_allclose(np.asarray(out[0, g]), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_decode_close(mesh1):
    """int8 KV (hillclimb 1) stays close to bf16-KV decode logits."""
    cfg = reduced(get_config("qwen3-0.6b"), dtype="float32")
    rng = np.random.RandomState(0)
    B, S = 2, 32
    params = model.init_params(cfg, ShardingPlan(tp=1))
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    outs = {}
    for dt in ("float32", "int8"):
        plan = ShardingPlan(tp=1, kv_cache_dtype=dt)
        dec, _, _ = steps.make_decode_step(cfg, plan, mesh1,
                                           ShapeConfig("d", "decode", S, B))
        dec = jax.jit(dec)
        cache = steps.zero_cache_for(cfg, plan, mesh1, B, S)
        with mesh1:
            for t in range(8):
                lg, cache = dec(params, cache, tokens[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32))
        outs[dt] = np.asarray(lg, np.float64)
    err = np.abs(outs["float32"] - outs["int8"]).max()
    assert err < 0.3, err          # quantization-level, not divergence


def test_context_parallel_ssm_subprocess():
    """CP (hillclimb 3): mamba2 loss identical to single-device reference.
    (Validated standalone with 8 host devices; here we assert the CP code
    path at cp=1 degrades to the reference exactly.)"""
    cfg = reduced(get_config("mamba2-370m"), dtype="float32")
    rng = np.random.RandomState(0)
    B, S = 2, 64
    from repro import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for plan in (ShardingPlan(tp=1), ShardingPlan(tp=1, cp_axes=("model",))):
        state = steps.init_train_state(cfg, plan)
        ts, _ = steps.make_train_step(cfg, plan, mesh,
                                      shape=ShapeConfig("t", "train", S, B))
        with mesh:
            _, stats = jax.jit(ts)(state, batch)
        losses.append(float(stats["loss"]))
    assert abs(losses[0] - losses[1]) < 1e-6
