"""Static-analysis gate: seeded-violation fixtures (each checker must
catch its bug class), the suppression/baseline machinery, and the real
tree's budget-table coverage."""
import ast

import jax.numpy as jnp
import pytest

from repro.analysis import RULE_IDS, budget, invariants, refcount, trace
from repro.analysis.core import (
    SourceFile,
    apply_suppressions,
    split_by_baseline,
)


def _src(path, text):
    text = text.lstrip("\n")
    return SourceFile(path=path, text=text, tree=ast.parse(text),
                      lines=text.splitlines())


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# fixture 1: unpaired incref -> refcount-leak
# ---------------------------------------------------------------------------

LEAKY = """
class Cache:
    def pin(self, pages):
        self.allocator.incref(pages)   # held ref never released/escaped

    def pin_ok(self, pages):
        self.allocator.incref(pages)
        self.nodes[1] = pages          # ownership escapes to the tree

    def rollback_ok(self, pages):
        self.allocator.incref(pages)
        try:
            self.commit()
        except RuntimeError:
            self.allocator.decref(pages)
"""


def test_unpaired_incref_detected():
    findings = refcount.scan_source(_src("src/repro/serving/fx.py", LEAKY))
    assert _rules(findings) == ["refcount-leak"]
    assert findings[0].scope == "Cache.pin"


# ---------------------------------------------------------------------------
# fixture 2: free() on possibly-shared pages -> shared-free
# ---------------------------------------------------------------------------

SHARED_FREE = """
class Sched:
    def release(self, adm):
        self.allocator.free(adm.pages)     # may be radix-shared: decref!

    def fresh_ok(self):
        pages = self.allocator.alloc(4)
        self.allocator.free(pages)         # sole owner by construction

    def slab_ok(self, adm):
        self.slab_alloc.free(adm.slab)     # slabs are exclusive: exempt
"""


def test_shared_page_free_detected():
    findings = refcount.scan_source(_src("src/repro/serving/fx.py",
                                         SHARED_FREE))
    assert _rules(findings) == ["shared-free"]
    assert findings[0].scope == "Sched.release"


# ---------------------------------------------------------------------------
# fixture 3: oversized BlockSpec -> pallas-budget (plus shape hygiene)
# ---------------------------------------------------------------------------

def test_oversized_blockspec_detected():
    import functools

    import jax.experimental.pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fat_call(x):
        T, E = x.shape
        return pl.pallas_call(
            kernel, grid=(1,),
            in_specs=[pl.BlockSpec((T, E), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((T, E), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((T, E), x.dtype),
            interpret=True)(x)

    import jax
    (call,) = budget.capture_invocation(
        "fat[T=512 E=512]", "src/repro/kernels/fx.py",
        functools.partial(fat_call), jnp.zeros((512, 512), jnp.float32))
    # 2 * 2 * 512*512*4 = 4 MiB streamed, over any MCU-ish budget
    findings = budget.check_call(call, budget=1_000_000)
    assert "pallas-budget" in _rules(findings)
    assert call.vmem_bytes() == 2 * 2 * 512 * 512 * 4


def test_divisibility_and_bounds_detected():
    import functools

    import jax
    import jax.experimental.pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def bad_call(x):
        T, E = x.shape
        return pl.pallas_call(
            kernel, grid=(3,),                       # 3 * 200 > 512 rows
            in_specs=[pl.BlockSpec((200, E), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((200, E), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((T, E), x.dtype),
            interpret=True)(x)

    (call,) = budget.capture_invocation(
        "bad[512x64]", "src/repro/kernels/fx.py",
        functools.partial(bad_call), jnp.zeros((512, 64), jnp.float32))
    rules = set(_rules(budget.check_call(call, budget=10**9)))
    assert "pallas-divisibility" in rules    # 512 % 200 != 0
    assert "pallas-bounds" in rules          # block 2 starts at row 400


# ---------------------------------------------------------------------------
# fixture 4: .item() in the tick loop -> host-sync
# ---------------------------------------------------------------------------

TICKY = """
class ServingEngine:
    def run(self, max_ticks):
        for _ in range(max_ticks):
            self.tick()

    def tick(self):
        logits, self.cache = self.decode_fn(self.params, self.cache)
        for b in range(self.B):
            tok = logits[b].argmax().item()   # per-slot device sync
            self.emit(b, tok)
        self._stats_tick()

    def _stats_tick(self):
        self.stats.sum_logit += float(self.head_logit)

    def helper_not_hot(self, x):
        return x.item()                       # unreachable from run/tick
"""


def test_item_in_tick_loop_detected():
    findings = trace.scan_source(_src(trace.ENGINE_PATH, TICKY))
    hot = [f for f in findings if f.rule == "host-sync"]
    assert len(hot) == 1                 # .item() in tick; helper exempt
    assert hot[0].scope == "ServingEngine.tick"
    assert ".item()" in hot[0].snippet


ASYNCY = """
class ServingEngine:
    def tick(self):
        plan = self._plan_phase()
        self._collect_phase()
        self._dispatch_phase(plan)

    def _plan_phase(self):
        budget = int(self.headroom.item())     # barrier while step in flight
        return self._plan_admissions(budget)

    def _plan_admissions(self, budget):
        jax.device_get(self.pos)               # reachable from plan: barrier
        return budget

    def _dispatch_phase(self, plan):
        logits, self.cache = self.decode_fn(self.params, self.cache)
        logits.block_until_ready()             # serializes the pipeline
        self._inflight = logits

    def _collect_phase(self):
        if self._inflight is not None:
            toks = jax.device_get(self._inflight)  # the one legal barrier
            self.emit(toks)
"""


def test_async_barrier_in_plan_dispatch_detected():
    findings = trace.scan_source(_src(trace.ENGINE_PATH, ASYNCY))
    hot = [f for f in findings if f.rule == "async-barrier"]
    # .item() in _plan_phase, device_get in the transitively reached
    # _plan_admissions, block_until_ready in _dispatch_phase — and NOT
    # the device_get at the collect point
    assert {f.scope for f in hot} == {"ServingEngine._plan_phase",
                                      "ServingEngine._plan_admissions",
                                      "ServingEngine._dispatch_phase"}
    assert len(hot) == 3


def test_traced_shape_and_missing_donation_detected():
    import textwrap
    src = _src(trace.ENGINE_PATH, textwrap.dedent("""
    import jax

    class ServingEngine:
        def build(self, fn):
            self.decode_fn = jax.jit(fn)             # no donate_argnums

        def tick(self):
            S = len(self.req.prompt)
            out, self.cache = self.prefill_fn(self.params,
                                              self.prompt[:, :S],
                                              self.cache)
    """))
    rules = _rules(trace.scan_source(src))
    assert "missing-donation" in rules
    assert "traced-shape" in rules


# ---------------------------------------------------------------------------
# fixture 5: stale Invariant: clause -> invariant-stale-ref
# ---------------------------------------------------------------------------

STALE = '''
"""Module with invariants.

Invariant: pages are refcounted.
Enforced-by: tests/test_paged_cache.py::test_totally_gone_test

Invariant: no recompiles in the hot loop.
Enforced-by: analysis:no-such-rule

Invariant: prose only, nobody enforces this.

Invariant: this one is fine.
Enforced-by: analysis:refcount-leak
"""
X = 1
'''


def test_stale_invariant_clause_detected():
    findings = invariants.scan_source(
        _src("src/repro/serving/fx.py", STALE), RULE_IDS)
    rules = _rules(findings)
    assert rules.count("invariant-stale-ref") == 2   # dead test + bad rule
    assert rules.count("invariant-unenforced") == 1  # the prose-only one
    assert "invariant-missing" not in rules


def test_missing_invariants_flagged_for_required_module():
    src = _src("src/repro/serving/scheduler.py", '"""No clauses here."""')
    findings = invariants.scan_source(src, RULE_IDS)
    assert _rules(findings) == ["invariant-missing"]


# ---------------------------------------------------------------------------
# suppression and baseline paths
# ---------------------------------------------------------------------------

SUPPRESSED = """
class Cache:
    def pin(self, pages):
        # repro: allow[refcount-leak]  -- ref owned by C layer
        self.allocator.incref(pages)

    def pin2(self, pages):
        self.allocator.incref(pages)  # repro: allow[refcount-leak]

    def pin_star(self, pages):
        self.allocator.incref(pages)  # repro: allow[*]

    def pin_wrong_rule(self, pages):
        self.allocator.incref(pages)  # repro: allow[shared-free]
"""


def test_allow_comment_suppresses_only_that_rule():
    src = _src("src/repro/serving/fx.py", SUPPRESSED)
    findings = refcount.scan_source(src)
    assert len(findings) == 4            # scanner itself flags all four
    kept = apply_suppressions(findings, {src.path: src})
    assert len(kept) == 1                # line-above, same-line and * work
    assert kept[0].scope == "Cache.pin_wrong_rule"


def test_baseline_splits_known_new_and_stale():
    src = _src("src/repro/serving/fx.py", LEAKY)
    (finding,) = refcount.scan_source(src)
    baseline = {finding.fingerprint: "known issue",
                "deadbeefdeadbeef": "fixed long ago"}
    new, known, stale = split_by_baseline([finding], baseline)
    assert not new and [f.fingerprint for f in known] == [
        finding.fingerprint]
    assert stale == ["deadbeefdeadbeef"]
    # an unbaselined finding is NEW
    new, known, stale = split_by_baseline([finding], {})
    assert [f.fingerprint for f in new] == [finding.fingerprint]


def test_fingerprint_survives_line_shifts():
    moved = "# a new comment pushes everything down\n\n" + LEAKY.lstrip("\n")
    (f1,) = refcount.scan_source(_src("src/repro/serving/fx.py", LEAKY))
    (f2,) = refcount.scan_source(_src("src/repro/serving/fx.py", moved))
    assert f1.line != f2.line
    assert f1.fingerprint == f2.fingerprint


# ---------------------------------------------------------------------------
# the real tree: budget table covers every Pallas kernel at paper shapes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_budget_table_covers_all_kernels_and_tree_is_clean():
    findings, rows = budget.run()
    assert findings == []
    covered = {r["file"].rsplit("/", 1)[-1] for r in rows}
    assert covered == {"matmul.py", "rmsnorm.py", "flash_attention.py",
                       "decode_attention.py", "ssd_scan.py"}
    assert all(r["ok"] and 0 < r["utilization"] <= 1 for r in rows)


def test_invariant_clauses_on_tree_are_live():
    findings, _ = invariants.run()
    assert findings == []
