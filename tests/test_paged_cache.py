"""Paged KV-cache subsystem: allocator behavior, paged-vs-contiguous
equivalence (attention level, step level, engine level) and engine
admission/eviction under a randomized request mix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import model, steps
from repro.core.kvcache import PageAllocator, pages_needed
from repro.core.partition import ShardingPlan

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_page_allocator_reuse_and_exhaustion():
    a = PageAllocator(8)                 # page 0 reserved -> 7 usable
    assert a.n_free == 7
    p1 = a.alloc(3)
    p2 = a.alloc(4)
    assert sorted(p1 + p2) == list(range(1, 8))
    assert a.alloc(1) is None            # exhausted: all-or-nothing
    assert a.n_free == 0
    a.free(p1)
    assert a.n_free == 3
    p3 = a.alloc(3)
    assert sorted(p3) == sorted(p1)      # freed pages are reused
    with pytest.raises(AssertionError):
        a.free([0])                      # the scratch page is never freed
    a.free(p3)
    with pytest.raises(AssertionError):
        a.free(p3)                       # double free


def test_pages_needed():
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2


# ---------------------------------------------------------------------------
# attention level: gather path and Pallas kernel vs contiguous oracle
# ---------------------------------------------------------------------------

def _scatter_to_pages(k, bt, psz, n_pages):
    """Contiguous (B, G, S, D) -> page pool (n_pages, G, psz, D)."""
    B, G, S, D = k.shape
    pool = np.zeros((n_pages, G, psz, D), k.dtype)
    for b in range(B):
        for t in range(S):
            pool[bt[b, t // psz], :, t % psz] = k[b, :, t]
    return pool


def _random_tables(rng, B, n_max, n_pages):
    ids = rng.permutation(np.arange(1, n_pages))[: B * n_max]
    return ids.reshape(B, n_max).astype(np.int32)


def test_paged_decode_attention_matches_contiguous():
    from repro.core.attention import decode_attention, paged_decode_attention
    rng = np.random.RandomState(0)
    B, G, R, D, psz, n_max = 3, 2, 2, 16, 4, 6
    n_pages = B * n_max + 1
    S = n_max * psz
    lens = np.array([5, 24, 17], np.int32)
    q = rng.randn(B, G, R, D).astype(np.float32)
    k = rng.randn(B, G, S, D).astype(np.float32)
    v = rng.randn(B, G, S, D).astype(np.float32)
    bt = _random_tables(rng, B, n_max, n_pages)
    kp = _scatter_to_pages(k, bt, psz, n_pages)
    vp = _scatter_to_pages(v, bt, psz, n_pages)
    kv_pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
    for window in (0, 7):
        ref = decode_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), jnp.asarray(kv_pos),
                               jnp.asarray(lens), window=window)
        got = paged_decode_attention(jnp.asarray(q), jnp.asarray(kp),
                                     jnp.asarray(vp), jnp.asarray(bt),
                                     jnp.asarray(lens), window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_pallas_paged_decode_kernel():
    from repro.kernels import ref
    from repro.kernels.decode_attention import paged_decode_attention
    rng = np.random.RandomState(1)
    B, H, D, psz, n_max = 3, 4, 64, 8, 5
    n_pages = B * n_max + 1
    S = n_max * psz
    lens = np.array([13, 40, 1], np.int32)
    q = rng.randn(B, H, D).astype(np.float32)
    k = rng.randn(B, H, S, D).astype(np.float32)
    v = rng.randn(B, H, S, D).astype(np.float32)
    bt = _random_tables(rng, B, n_max, n_pages)
    kp = _scatter_to_pages(k, bt, psz, n_pages)
    vp = _scatter_to_pages(v, bt, psz, n_pages)
    out = paged_decode_attention(jnp.asarray(q), jnp.asarray(kp),
                                 jnp.asarray(vp), jnp.asarray(bt),
                                 jnp.asarray(lens), interpret=True)
    expect = ref.ref_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_paged_verify_attention_matches_ref():
    """Pure-JAX Q-query verify attention (the engine's spec path) against
    the ref oracle; cur_pos semantics (pos of query 0) vs the oracle's
    length = pos + 1."""
    from repro.core.attention import paged_verify_attention
    from repro.kernels import ref
    rng = np.random.RandomState(2)
    B, G, R, Q, D, psz, n_max = 3, 2, 2, 5, 16, 4, 8
    n_pages = B * n_max + 1
    S = n_max * psz
    pos = np.array([4, 19, 27], np.int32)    # query 0's absolute position
    q = rng.randn(B, G, R, Q, D).astype(np.float32)
    k = rng.randn(B, G, S, D).astype(np.float32)
    v = rng.randn(B, G, S, D).astype(np.float32)
    bt = _random_tables(rng, B, n_max, n_pages)
    kp = _scatter_to_pages(k, bt, psz, n_pages)
    vp = _scatter_to_pages(v, bt, psz, n_pages)
    got = paged_verify_attention(jnp.asarray(q), jnp.asarray(kp),
                                 jnp.asarray(vp), jnp.asarray(bt),
                                 jnp.asarray(pos))
    # fold (G, R) -> H for the ref oracle's (B, H, Q, D) layout
    qh = q.reshape(B, G * R, Q, D)
    kh = np.repeat(k, R, axis=1)
    vh = np.repeat(v, R, axis=1)
    expect = ref.ref_verify_attention(jnp.asarray(qh), jnp.asarray(kh),
                                      jnp.asarray(vh),
                                      jnp.asarray(pos + 1))
    np.testing.assert_allclose(
        np.asarray(got).reshape(B, G * R, Q, D), np.asarray(expect),
        rtol=1e-5, atol=1e-5)


def test_pallas_paged_verify_kernel():
    from repro.kernels import ref
    from repro.kernels.decode_attention import paged_verify_attention
    rng = np.random.RandomState(3)
    B, H, Q, D, psz, n_max = 3, 4, 5, 64, 8, 5
    n_pages = B * n_max + 1
    S = n_max * psz
    lens = np.array([13, 36, 1], np.int32)   # pos + 1, as in paged decode
    q = rng.randn(B, H, Q, D).astype(np.float32)
    k = rng.randn(B, H, S, D).astype(np.float32)
    v = rng.randn(B, H, S, D).astype(np.float32)
    bt = _random_tables(rng, B, n_max, n_pages)
    kp = _scatter_to_pages(k, bt, psz, n_pages)
    vp = _scatter_to_pages(v, bt, psz, n_pages)
    out = paged_verify_attention(jnp.asarray(q), jnp.asarray(kp),
                                 jnp.asarray(vp), jnp.asarray(bt),
                                 jnp.asarray(lens), interpret=True)
    expect = ref.ref_verify_attention(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)
    # Q = 1 degenerates to the decode kernel's contract
    out1 = paged_verify_attention(jnp.asarray(q[:, :, :1]), jnp.asarray(kp),
                                  jnp.asarray(vp), jnp.asarray(bt),
                                  jnp.asarray(lens), interpret=True)
    exp1 = ref.ref_decode_attention(jnp.asarray(q[:, :, 0]), jnp.asarray(k),
                                    jnp.asarray(v), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out1[:, :, 0]), np.asarray(exp1),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# step level: chunked prefill + paged decode == exact-length prefill + decode
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_steps_match_contiguous_mixed_lengths(mesh1):
    cfg = reduced(get_config("qwen3-0.6b"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(0)
    S, PSZ, CHUNK, NDEC = 32, 4, 8, 4
    N_MAX = S // PSZ
    N_PAGES = 3 * N_MAX + 1

    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh1,
                                       ShapeConfig("d", "decode", S, 1))
    dec = jax.jit(dec)
    chunk_fn, _, _ = steps.make_prefill_chunk_step(cfg, PLAN, mesh1, CHUNK,
                                                   N_PAGES, PSZ, N_MAX)
    pdec, _, _ = steps.make_paged_decode_step(cfg, PLAN, mesh1, 1, N_PAGES,
                                              PSZ, N_MAX)
    chunk_fn, pdec = jax.jit(chunk_fn), jax.jit(pdec)
    alloc = PageAllocator(N_PAGES)

    for L in (5, 13, 26):                # mixed prompt lengths, one compile
        prompt = rng.randint(2, cfg.vocab_size, L).astype(np.int32)

        # contiguous reference (compiles per length — the cost paging kills)
        pre, _, _ = steps.make_prefill_step(cfg, PLAN, mesh1,
                                            ShapeConfig("p", "decode", S, 1))
        cache = steps.zero_cache_for(cfg, PLAN, mesh1, 1, S)
        with mesh1:
            lg, cache = jax.jit(pre)(params, jnp.asarray(prompt[None]), cache)
        ref_logits = [np.asarray(lg[0], np.float64)]
        tok, pos = int(np.argmax(ref_logits[-1])), L
        with mesh1:
            for _ in range(NDEC):
                lg, cache = dec(params, cache,
                                jnp.asarray([[tok]], jnp.int32),
                                jnp.asarray([pos], jnp.int32))
                ref_logits.append(np.asarray(lg[0], np.float64))
                tok, pos = int(np.argmax(ref_logits[-1])), pos + 1

        # paged: chunk-at-a-time prefill, then block-table decode
        pcache = steps.zero_paged_cache_for(cfg, PLAN, mesh1, N_PAGES, PSZ)
        pages = alloc.alloc(pages_needed(L + NDEC, PSZ))
        bt = np.zeros((1, N_MAX), np.int32)
        bt[0, :len(pages)] = pages
        n_chunks = -(-L // CHUNK)
        padded = np.zeros(n_chunks * CHUNK, np.int32)
        padded[:L] = prompt
        with mesh1:
            for c0 in range(0, n_chunks * CHUNK, CHUNK):
                lg, pcache = chunk_fn(
                    params, pcache, jnp.asarray(padded[None, c0:c0 + CHUNK]),
                    jnp.asarray([c0], jnp.int32),
                    jnp.asarray([min(L - 1 - c0, CHUNK - 1)], jnp.int32),
                    jnp.asarray(bt))
        got_logits = [np.asarray(lg[0], np.float64)]
        tok, pos = int(np.argmax(got_logits[-1])), L
        with mesh1:
            for _ in range(NDEC):
                lg, pcache = pdec(params, pcache,
                                  jnp.asarray([[tok]], jnp.int32),
                                  jnp.asarray([pos], jnp.int32),
                                  jnp.asarray(bt))
                got_logits.append(np.asarray(lg[0], np.float64))
                tok, pos = int(np.argmax(got_logits[-1])), pos + 1

        np.testing.assert_allclose(np.asarray(got_logits),
                                   np.asarray(ref_logits), atol=1e-5)
        alloc.free(pages)


# ---------------------------------------------------------------------------
# engine level: randomized workload, admission under page pressure
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_engine_matches_contiguous_greedy(mesh1):
    """Greedy outputs are token-identical across the two cache disciplines."""
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(0)
    SB, NSLOT = 64, 4
    # few distinct lengths so the contiguous oracle's per-length recompiles
    # stay bounded
    reqs = [(rid, rng.randint(2, cfg.vocab_size,
                              int(rng.choice([4, 9, 17]))).astype(np.int32),
             int(rng.randint(2, 8))) for rid in range(8)]

    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh1,
                                       ShapeConfig("s", "decode", SB, NSLOT))
    pre, _, _ = steps.make_prefill_step(cfg, PLAN, mesh1,
                                        ShapeConfig("p", "decode", SB, 1))
    eng = ServingEngine(cfg, PLAN, mesh1, NSLOT, SB, params, jax.jit(pre),
                        jax.jit(dec))
    rs = [Request(rid=r, prompt=p, max_new_tokens=m) for r, p, m in reqs]
    for r in rs:
        eng.submit(r)
    eng.run(max_ticks=500)
    ref = {r.rid: tuple(r.out_tokens) for r in rs}

    peng = ServingEngine.build_paged(cfg, PLAN, mesh1, NSLOT, SB, params,
                                     page_size=8, prefill_chunk=16,
                                     n_pages=2 * (SB // 8) + 1)
    prs = [Request(rid=r, prompt=p, max_new_tokens=m) for r, p, m in reqs]
    for r in prs:
        peng.submit(r)
    peng.run(max_ticks=2000)
    for r in prs:
        assert r.done
        assert tuple(r.out_tokens) == ref[r.rid], r.rid
    assert peng.allocator.n_free == 2 * (SB // 8)   # every page reclaimed


@pytest.mark.slow
def test_paged_engine_randomized_50_requests(mesh1):
    """50 mixed requests complete through a deliberately tight page pool."""
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(7)
    SB, NSLOT = 32, 4
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, NSLOT, SB, params,
                                    page_size=8, prefill_chunk=8,
                                    n_pages=9)     # 8 usable pages: tight
    reqs = []
    for rid in range(50):
        L = int(rng.randint(1, 20))
        m = int(rng.randint(1, min(8, SB - L)))
        req = Request(rid=rid,
                      prompt=rng.randint(2, cfg.vocab_size,
                                         L).astype(np.int32),
                      max_new_tokens=m)
        reqs.append(req)
        eng.submit(req)
    stats = eng.run(max_ticks=20_000)
    assert all(r.done for r in reqs)
    assert stats.prefills == 50
    assert len(stats.ttft_s) == 50
    assert stats.decoded_tokens >= 50
    assert eng.allocator.n_free == 8               # pool fully reclaimed


def test_paged_engine_rejects_oversized_request(mesh1):
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8, n_pages=4)
    rng = np.random.RandomState(0)
    # fits the sequence budget but can never fit the 3-usable-page pool:
    # rejected at submit, before any in-flight request can be disrupted
    req = Request(rid=0, prompt=rng.randint(2, cfg.vocab_size,
                                            20).astype(np.int32),
                  max_new_tokens=10)
    with pytest.raises(RuntimeError, match="pages"):
        eng.submit(req)


def test_paged_cache_arch_support():
    """SSM/hybrid (slab pools) and enc-dec (cross pools) are paged now;
    only archs whose prefill needs non-token inputs the chunk step cannot
    carry (vision embeds) are rejected — with a precise reason."""
    from repro.core.kvcache import paged_cache_supported, paged_cache_template
    from repro.core.partition import model_layout
    for name in ("mamba2-370m", "hymba-1.5b", "seamless-m4t-large-v2"):
        cfg = reduced(get_config(name))
        ok, why = paged_cache_supported(cfg)
        assert ok, (name, why)
    cfg = reduced(get_config("mamba2-370m"))
    tmpl = paged_cache_template(cfg, PLAN, model_layout(cfg, PLAN), 8, 4,
                                n_slabs=3)
    # slab pools only: a pure-SSM arch has no KV page pools at all
    kinds = {k for pat in tmpl for d in pat for k in d}
    assert kinds == {"ssm"}
    cfg = reduced(get_config("pixtral-12b"))
    ok, why = paged_cache_supported(cfg)
    assert not ok and "vision" in why
    with pytest.raises(ValueError, match="vision"):
        paged_cache_template(cfg, PLAN, model_layout(cfg, PLAN), 8, 4)


# ---------------------------------------------------------------------------
# quantized slab pools under forced preemption
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ssm_int8_forced_preemption_identity(mesh1):
    """int8 KV pages + int8 SSM slabs: the preemption stash snapshots the
    quantized slab (raw int8 payload + per-head scales, never a dequant
    round-trip) and the restore writes it back exactly, so greedy outputs
    stay token-identical to the fp oracle with or without preemption."""
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("hymba-1.5b"), dtype="float32")
    plan_i8 = ShardingPlan(tp=1, kv_cache_dtype="int8",
                           ssm_cache_dtype="int8")
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(3)
    base = [(rng.randint(2, cfg.vocab_size, L).astype(np.int32), m)
            for L, m in zip([13, 9], [8, 6], strict=True)]

    def run(plan, preempt_at):
        eng = ServingEngine.build_paged(cfg, plan, mesh1, 2, 32, params,
                                        page_size=8, prefill_chunk=8)
        reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=m)
                for i, (p, m) in enumerate(base)]
        for r in reqs:
            eng.submit(r)
        tick = 0
        while (eng.has_pending() or
               any(a is not None for a in eng.admissions)) and tick < 500:
            if tick in preempt_at:
                for b in range(eng.B):
                    if eng.admissions[b] is not None:
                        eng.preempt(b)
                        break
            eng.tick()
            tick += 1
        assert all(r.done for r in reqs)
        return {r.rid: tuple(r.out_tokens) for r in reqs}, eng

    ref, _ = run(PLAN, set())                     # fp oracle
    base_i8, _ = run(plan_i8, set())
    assert base_i8 == ref
    for pts in ({1}, {3}, {1, 2, 3}):
        got, eng = run(plan_i8, pts)
        assert got == ref, pts
        assert eng.stats.slab_restores == len(pts)
        for a in eng.allocators:
            assert a.n_free == a.n_pages - a.n_reserved
        assert eng.slab_allocators[0].n_free == eng.n_slabs - 1
