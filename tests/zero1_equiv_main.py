"""ZeRO-1 equivalence runner (8 host devices): the sharded-optimizer train
step must follow the identical loss trajectory as the replicated-optimizer
step on the same (data=2, model=4) mesh."""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core import steps  # noqa: E402
from repro.core.partition import ShardingPlan  # noqa: E402


def main():
    cfg = reduced(get_config("qwen3-0.6b"), dtype="float32")
    B, S = 4, 32
    shape = ShapeConfig("t", "train", S, B)
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    plan = ShardingPlan(tp=4)
    rng = np.random.RandomState(0)
    batches = []
    for _ in range(3):
        t = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
        batches.append({"tokens": t, "labels": t})

    state = steps.init_train_state(cfg, plan)
    ts = jax.jit(steps.make_train_step(cfg, plan, mesh, shape=shape)[0])
    ls = []
    with mesh:
        for b in batches:
            state, st = ts(state, b)
            ls.append(float(st["loss"]))

    plan1 = plan.with_(zero1=True)
    state1 = steps.init_train_state_zero1(cfg, plan1, mesh)
    t1 = jax.jit(steps.make_train_step_zero1(cfg, plan1, mesh,
                                             shape=shape)[0])
    l1 = []
    with mesh:
        for b in batches:
            state1, st = t1(state1, b)
            l1.append(float(st["loss"]))

    rel = max(abs(a - b) / abs(a) for a, b in zip(ls, l1, strict=True))
    print(f"std={ls} zero1={l1} rel={rel:.2e}")
    print("ZERO1-OK" if rel < 1e-4 else "ZERO1-FAIL")
    sys.exit(0 if rel < 1e-4 else 1)


if __name__ == "__main__":
    main()
