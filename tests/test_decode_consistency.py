"""Decode == prefill consistency: token-by-token decoding with the KV/SSM
cache must reproduce the teacher-forced (prefill) logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import model, steps
from repro.core.partition import ShardingPlan, model_layout

PLAN = ShardingPlan(tp=1)
B, S = 2, 48


@pytest.mark.parametrize("name", ["qwen3-0.6b", "mamba2-370m", "gemma3-12b",
                                  "hymba-1.5b", "mixtral-8x22b"])
def test_decode_matches_prefill(name, mesh1):
    cfg = reduced(get_config(name), dtype="float32")
    lay = model_layout(cfg, PLAN)
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)

    shape = ShapeConfig("d", "decode", S + 1, B)
    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh1, shape)
    dec = jax.jit(dec)
    cache = steps.zero_cache_for(cfg, PLAN, mesh1, B, S + 1)

    # teacher-forced full forward (train-mode logits at every position)
    def full(params, tokens):
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        from repro.core.layers import apply_norm
        x = model.embed_tokens(params, tokens, cfg, PLAN, lay)
        x, _ = model._run_stack(x, params["stacks"], cfg.layer_groups(), cfg,
                                PLAN, lay, "train", positions)
        x = apply_norm(x, params["final_norm"], cfg)
        return model.final_logits(params, x, cfg, lay)

    from jax.sharding import PartitionSpec as P

    from repro import compat
    pspecs = model.param_pspecs(cfg, PLAN)
    full_fn = jax.jit(compat.shard_map(
        full, mesh1, in_specs=(pspecs, P(None, None)),
        out_specs=P(None, None, "model")))
    with mesh1:
        ref_logits = np.asarray(full_fn(params, tokens), np.float64)

    got = np.zeros_like(ref_logits)
    with mesh1:
        for t in range(S):
            lg, cache = dec(params, cache, tokens[:, t:t + 1],
                            jnp.full((B,), t, jnp.int32))
            got[:, t] = np.asarray(lg, np.float64)

    # tolerance: decode and teacher-forced paths use different reduction
    # orders (flash decode vs chunked flash); gemma's sqrt(E) embed scaling
    # amplifies absolute logit noise — errors are flat in position (no cache
    # drift).  MoE archs additionally have DISCONTINUOUS routing: ~1e-3
    # numeric noise can flip a top-k tie at isolated positions, producing
    # large but sparse deltas — so MoE asserts on the 99th percentile.
    err = np.abs(got - ref_logits)
    if cfg.n_experts:
        # audited: isolated flip (e.g. one position), no drift, full recovery
        assert float(np.median(err)) < 2e-3, np.median(err)
        assert float(err.max()) < 0.2, err.max()
        assert float((err > 0.1).mean()) < 1e-3
    else:
        np.testing.assert_allclose(got, ref_logits, rtol=6e-3, atol=6e-3)
