"""Paged serving beyond attention-only decoders: SSM state slabs
(hybrid + pure-SSM archs), enc-dec cross-KV paging with shared-frame
reuse, preemption snapshot/restore for recurrent state, joint
page+slab+cross leak-freedom across policies and dp, and the precise
errors for unsupported combinations."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import model, steps
from repro.core.partition import ShardingPlan
from repro.serving import (FairScheduler, PriorityScheduler, Request,
                           ServingEngine)

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")


def _hybrid_cfg():
    return reduced(get_config("hymba-1.5b"), dtype="float32")


def _ssm_cfg():
    return reduced(get_config("mamba2-370m"), dtype="float32")


def _encdec_cfg():
    return reduced(get_config("seamless-m4t-large-v2"), dtype="float32",
                   n_enc_layers=1, enc_seq_len=16)


def _mk_requests(base):
    return [Request(rid=i, prompt=p.copy(), max_new_tokens=m, frames=f)
            for i, (p, m, f) in enumerate(base)]


def _run_contiguous_oracle(cfg, params, mesh, base, SB=32, NSLOT=2):
    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh,
                                       ShapeConfig("s", "decode", SB, NSLOT))
    pre, _, _ = steps.make_prefill_step(cfg, PLAN, mesh,
                                        ShapeConfig("p", "decode", SB, 1))
    eng = ServingEngine(cfg, PLAN, mesh, NSLOT, SB, params, jax.jit(pre),
                        jax.jit(dec))
    reqs = _mk_requests(base)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=2000)
    assert all(r.done for r in reqs)
    return {r.rid: tuple(r.out_tokens) for r in reqs}


def _assert_leak_free(eng):
    """Every page free or cache-held, every slab free, per replica."""
    for rr in range(eng.R):
        a = eng.allocators[rr]
        cached = 0
        if eng.prefix_caches[rr] is not None:
            cached += eng.prefix_caches[rr].n_cached_pages
        if eng.cross_caches:
            cached += eng.cross_caches[rr].n_cached_pages
        assert a.n_free + cached == a.n_pages - a.n_reserved, rr
        if eng.slab_allocators:
            assert eng.slab_allocators[rr].n_free == eng.n_slabs - 1, rr


# ---------------------------------------------------------------------------
# paged vs contiguous oracle (greedy token identity)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", ["hymba-1.5b", "mamba2-370m"])
def test_paged_ssm_archs_match_contiguous(name, mesh1):
    """Hybrid (attn KV pages + SSM slabs) and pure-SSM (slabs only) paged
    engines produce greedy outputs token-identical to the contiguous
    oracle, and release every page and slab."""
    cfg = reduced(get_config(name), dtype="float32")
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(0)
    base = [(rng.randint(2, cfg.vocab_size, L).astype(np.int32), m, None)
            for L, m in zip([5, 9, 17, 12], [6, 4, 5, 3], strict=True)]
    ref = _run_contiguous_oracle(cfg, params, mesh1, base)

    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8)
    reqs = _mk_requests(base)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=2000)
    assert all(r.done for r in reqs)
    assert {r.rid: tuple(r.out_tokens) for r in reqs} == ref
    _assert_leak_free(eng)


@pytest.mark.slow
def test_paged_encdec_matches_contiguous_with_shared_frames(mesh1):
    """Enc-dec: cross-KV paged through the second block table; requests
    with identical frames share one encode's pages by refcount."""
    cfg = _encdec_cfg()
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(1)
    frames = [rng.randn(cfg.enc_seq_len, cfg.d_model).astype(np.float32)
              for _ in range(2)]
    base = [(rng.randint(2, cfg.vocab_size, L).astype(np.int32), m,
             frames[i % 2])
            for i, (L, m) in enumerate(zip([5, 9, 12, 7], [5, 4, 3, 6], strict=True))]
    ref = _run_contiguous_oracle(cfg, params, mesh1, base)

    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8)
    reqs = _mk_requests(base)
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_ticks=2000)
    assert all(r.done for r in reqs)
    assert {r.rid: tuple(r.out_tokens) for r in reqs} == ref
    # two distinct frame tensors -> exactly two encodes; the rest hit
    assert stats.cross_encodes == 2
    assert stats.cross_hits == 2 and stats.cross_lookups == 4
    _assert_leak_free(eng)
    # the shared cross entries stay resident for future identical frames
    assert eng.cross_caches[0].n_entries == 2


def test_pure_ssm_needs_no_kv_pages(mesh1):
    """A pure-SSM arch has no KV pools, so its per-token page demand is
    zero: requests of any length serve through a minimal page pool and
    the allocator never hands out a page."""
    cfg = _ssm_cfg()
    params = model.init_params(cfg, PLAN)
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8, n_pages=2)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(2, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=4)
            for i, L in enumerate([17, 9, 21])]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=500)
    assert all(r.done for r in reqs)
    assert eng.allocators[0].total_allocated == 0
    _assert_leak_free(eng)


@pytest.mark.slow
def test_dp2_encdec_frames_affinity_shares_encodes(mesh1):
    """dp=2 routing scores a frames-digest hit as affinity, so
    identical-frame requests land on the replica whose encode is already
    resident — one encode per distinct frames, not per replica."""
    cfg = _encdec_cfg()
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(2)
    frames = [rng.randn(cfg.enc_seq_len, cfg.d_model).astype(np.float32)
              for _ in range(2)]
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8, dp=2)
    reqs = [Request(rid=i,
                    prompt=rng.randint(2, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3, frames=frames[i % 2])
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_ticks=500)
    assert all(r.done for r in reqs)
    assert stats.cross_encodes == 2
    assert stats.cross_hits == 6
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# preemption: recurrent state snapshot/restore
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hybrid_forced_preemption_identity(mesh1):
    """Forced preemption at arbitrary points (mid-prefill and mid-decode)
    leaves hybrid greedy outputs token-identical: the slab checkpoint is
    restored exactly, nothing resident is recomputed wrongly."""
    cfg = _hybrid_cfg()
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(3)
    base = [(rng.randint(2, cfg.vocab_size, L).astype(np.int32), m, None)
            for L, m in zip([13, 9], [8, 6], strict=True)]

    def run(preempt_at):
        eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                        page_size=8, prefill_chunk=8)
        reqs = _mk_requests(base)
        for r in reqs:
            eng.submit(r)
        tick = 0
        while (eng.has_pending() or
               any(a is not None for a in eng.admissions)) and tick < 500:
            if tick in preempt_at:
                for b in range(eng.B):
                    if eng.admissions[b] is not None:
                        eng.preempt(b)
                        break
            eng.tick()
            tick += 1
        assert all(r.done for r in reqs)
        return {r.rid: tuple(r.out_tokens) for r in reqs}, eng

    ref, _ = run(set())
    for pts in ({1}, {3}, {1, 2, 3}):
        got, eng = run(pts)
        assert got == ref, pts
        assert eng.stats.slab_restores == len(pts)
        _assert_leak_free(eng)


@pytest.mark.slow
def test_encdec_preemption_reencodes_or_hits(mesh1):
    """Enc-dec preemption releases the slot's cross ref; resume re-acquires
    the shared entry (no second encode) and outputs are unchanged."""
    cfg = _encdec_cfg()
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(7)
    fr = rng.randn(cfg.enc_seq_len, cfg.d_model).astype(np.float32)
    base = [(rng.randint(2, cfg.vocab_size, L).astype(np.int32), m, fr)
            for L, m in zip([11, 8], [6, 5], strict=True)]

    def run(preempt_at):
        eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                        page_size=8, prefill_chunk=8)
        reqs = _mk_requests(base)
        for r in reqs:
            eng.submit(r)
        tick = 0
        while (eng.has_pending() or
               any(a is not None for a in eng.admissions)) and tick < 500:
            if tick in preempt_at and eng.admissions[0] is not None:
                eng.preempt(0)
            eng.tick()
            tick += 1
        assert all(r.done for r in reqs)
        return {r.rid: tuple(r.out_tokens) for r in reqs}, eng

    ref, _ = run(set())
    got, eng = run({2})
    assert got == ref
    assert eng.stats.preemptions == 1
    # one encode for the shared frames; the resume was a cross-cache hit
    assert eng.stats.cross_encodes == 1
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# leak-freedom property: policies x dp with preemption, slabs included
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("dp", [1, 2])
@pytest.mark.parametrize("policy", ["fcfs", "priority", "fair"])
def test_slab_and_page_leak_freedom(policy, dp, mesh1):
    """Randomized hybrid workload across fcfs/priority/fair and dp={1,2}:
    after run() + drain(), every replica's pages and slabs are released
    (the leak-freedom property of PR 3/4 extended to slabs)."""
    cfg = _hybrid_cfg()
    params = model.init_params(cfg, PLAN)
    scheduler = {"fcfs": None,
                 "priority": functools.partial(PriorityScheduler,
                                               preemption=True),
                 "fair": functools.partial(FairScheduler, preemption=True,
                                           quantum=16, preempt_after=1),
                 }[policy]
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8,
                                    scheduler=scheduler, dp=dp)
    rng = np.random.RandomState(11 + dp)
    reqs = []
    for rid in range(8):
        L = int(rng.randint(1, 20))
        reqs.append(Request(
            rid=rid, prompt=rng.randint(2, cfg.vocab_size, L).astype(np.int32),
            max_new_tokens=int(rng.randint(1, 6)),
            priority=int(rng.randint(0, 3)), client_id=rid % 3))
    for r in reqs:
        eng.submit(r)
    # a tight tick budget leaves work in flight -> drain must reclaim it
    eng.run(max_ticks=int(rng.randint(3, 30)))
    eng.drain()
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# precise errors for unsupported combinations
# ---------------------------------------------------------------------------

def test_prefix_cache_with_ssm_arch_raises_precisely(mesh1):
    cfg = _hybrid_cfg()
    params = model.init_params(cfg, PLAN)
    with pytest.raises(ValueError, match="SSM layers hold recurrent state"):
        ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                  page_size=8, prefill_chunk=8,
                                  prefix_cache=True)


def test_prefix_cache_with_encdec_arch_raises_precisely(mesh1):
    cfg = _encdec_cfg()
    params = model.init_params(cfg, PLAN)
    with pytest.raises(ValueError, match="encoder frames"):
        ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                  page_size=8, prefill_chunk=8,
                                  prefix_cache=True)


def test_vision_arch_rejected_precisely(mesh1):
    cfg = reduced(get_config("pixtral-12b"), dtype="float32")
    with pytest.raises(ValueError, match="vision"):
        ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params=None,
                                  page_size=8, prefill_chunk=8)


def test_encdec_request_without_frames_raises(mesh1):
    cfg = _encdec_cfg()
    params = model.init_params(cfg, PLAN)
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 1, 32, params,
                                    page_size=8, prefill_chunk=8)
    with pytest.raises(RuntimeError, match="frames"):
        eng.submit(Request(rid=0, prompt=np.arange(2, 8, dtype=np.int32)))
    bad = np.zeros((3, 3), np.float32)
    with pytest.raises(RuntimeError, match="frames shape"):
        eng.submit(Request(rid=1, prompt=np.arange(2, 8, dtype=np.int32),
                           frames=bad))
