"""Checkpoint round-trips, atomicity, async writer, elastic resharding."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import AsyncCheckpointer, CheckpointManager
from repro.checkpoint.resharding import reshard_params
from repro.configs import get_config, reduced
from repro.core import model, steps
from repro.core.partition import ShardingPlan


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path):
    cfg = reduced(get_config("qwen3-0.6b"))
    state = steps.init_train_state(cfg, ShardingPlan(tp=1))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, state, extra={"doc_idx": 17})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 3 and manifest["extra"]["doc_idx"] == 17
    _assert_tree_equal(state, restored)


def test_atomicity_tmp_dirs_ignored(tmp_path):
    cfg = reduced(get_config("qwen3-0.6b"))
    state = steps.init_train_state(cfg, ShardingPlan(tp=1))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    # a crashed half-write: tmp dir without COMMIT must be invisible
    os.makedirs(tmp_path / "step_00000002.tmp")
    os.makedirs(tmp_path / "step_00000003")      # no COMMIT file
    assert mgr.latest_step() == 1


def test_gc_keeps_last_k(tmp_path):
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=1)
    state = steps.init_train_state(cfg, ShardingPlan(tp=1))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_async_checkpointer(tmp_path):
    cfg = reduced(get_config("qwen3-0.6b"), n_layers=1)
    state = steps.init_train_state(cfg, ShardingPlan(tp=1))
    mgr = CheckpointManager(str(tmp_path))
    a = AsyncCheckpointer(mgr)
    a.save(5, state)
    a.wait()
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 5
    _assert_tree_equal(state, restored)


@pytest.mark.parametrize("name", ["qwen3-0.6b", "mamba2-370m",
                                  "deepseek-moe-16b", "hymba-1.5b"])
def test_elastic_reshard_tp1_to_tp4_exact(name):
    """Canonicalize->re-scatter is exact: unshard(reshard(p)) == unshard(p)."""
    cfg = reduced(get_config(name), dtype="float32")
    p1 = model.init_params(cfg, ShardingPlan(tp=1))
    p4 = reshard_params(p1, cfg, ShardingPlan(tp=1), ShardingPlan(tp=4))
    p1b = reshard_params(p4, cfg, ShardingPlan(tp=4), ShardingPlan(tp=1))
    _assert_tree_equal(p1, p1b)
    # and independently-initialized tp=4 params match the resharded ones
    p4_direct = model.init_params(cfg, ShardingPlan(tp=4))
    _assert_tree_equal(p4, p4_direct)
