"""Bench regression gate (benchmarks/check_regression.py): warn-only
while history is thin, fail on real regressions once it isn't."""
import importlib.util
import json
import pathlib


def _load_mod():
    path = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" / \
        "check_regression.py"
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _payload(tps, ttft, mode="paged"):
    return {"bench": "serving",
            "rows": [{"mode": mode, "tokens_per_s": tps,
                      "ttft_p50_ms": ttft}]}


def _run(mod, tmp_path, payload, hist, n):
    cur = tmp_path / f"cur{n}.json"
    cur.write_text(json.dumps(payload))
    return mod.main([str(cur), "--history", str(hist)])


def test_warn_only_then_gate(tmp_path):
    mod = _load_mod()
    hist = tmp_path / "hist" / "serving.jsonl"
    # runs 1-3: no/thin history -> always exit 0, even on a wild swing
    assert _run(mod, tmp_path, _payload(100.0, 10.0), hist, 1) == 0
    assert _run(mod, tmp_path, _payload(10.0, 100.0), hist, 2) == 0
    assert _run(mod, tmp_path, _payload(100.0, 10.0), hist, 3) == 0
    # run 4: >= 3 prior runs; healthy numbers near the median pass
    assert _run(mod, tmp_path, _payload(95.0, 11.0), hist, 4) == 0
    # run 5: throughput collapse beyond the 25% default tolerance fails
    assert _run(mod, tmp_path, _payload(20.0, 10.0), hist, 5) == 1
    # run 6: TTFT blow-up fails too
    assert _run(mod, tmp_path, _payload(100.0, 80.0), hist, 6) == 1
    # failing runs never entered history (no self-rebaselining): only the
    # four passing runs are on file
    assert len(hist.read_text().strip().splitlines()) == 4
    # retrying the same regression keeps failing rather than converging
    assert _run(mod, tmp_path, _payload(20.0, 10.0), hist, 7) == 1


def test_history_is_windowed(tmp_path):
    mod = _load_mod()
    hist = tmp_path / "serving.jsonl"
    for n in range(25):
        assert _run(mod, tmp_path, _payload(100.0, 10.0), hist, n) == 0
    assert len(hist.read_text().strip().splitlines()) == mod.MAX_HISTORY


def test_new_modes_gate_on_their_own_history(tmp_path):
    mod = _load_mod()
    hist = tmp_path / "serving.jsonl"
    for n in range(4):
        assert _run(mod, tmp_path, _payload(100.0, 10.0), hist, n) == 0
    # a mode history has never seen is skipped, not failed
    assert _run(mod, tmp_path, _payload(50.0, 999.0, mode="prio"),
                hist, 10) == 0
    # ...and with only 1-2 prior samples OF THAT MODE, a swing stays
    # warn-only even though the file itself has plenty of payloads
    assert _run(mod, tmp_path, _payload(5.0, 10.0, mode="prio"),
                hist, 11) == 0
    assert _run(mod, tmp_path, _payload(50.0, 10.0, mode="prio"),
                hist, 12) == 0
    # at 3 prior samples the mode gates like any other
    assert _run(mod, tmp_path, _payload(5.0, 10.0, mode="prio"),
                hist, 13) == 1


def test_compare_directionality():
    mod = _load_mod()
    history = [_payload(100.0, 10.0) for _ in range(3)]
    # improvements never violate
    assert mod.compare(_payload(200.0, 5.0)["rows"], history, 0.5) == ([], [])
    # regressions in either direction gate (3 prior samples)
    assert mod.compare(_payload(40.0, 10.0)["rows"], history, 0.5)[0]
    assert mod.compare(_payload(100.0, 20.0)["rows"], history, 0.5)[0]
    # the same regression against thin per-metric history only warns
    fails, warns = mod.compare(_payload(40.0, 10.0)["rows"], history[:2], 0.5)
    assert fails == [] and warns
