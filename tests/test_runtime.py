"""Runtime substrate: offload streaming, elastic layout, gradient
compression, data prefetcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config


def test_offload_executor_matches_resident():
    from repro.runtime.offload import OffloadExecutor
    rng = np.random.RandomState(0)
    E, F = 64, 128
    groups = [{"w1": rng.randn(E, F).astype(np.float32) * 0.1,
               "w2": rng.randn(F, E).astype(np.float32) * 0.1}
              for _ in range(4)]

    @jax.jit
    def fwd(x, p):
        return x + jax.nn.silu(x @ p["w1"]) @ p["w2"]

    x = jnp.asarray(rng.randn(2, 8, E), jnp.float32)
    execu = OffloadExecutor(groups)
    y = execu.stream_forward(x, [lambda x, p: fwd(x, p)] * 4)
    ref = x
    for p in groups:
        ref = fwd(ref, jax.device_put(p))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-6,
                               atol=1e-6)
    assert execu.stats.groups == 4


def test_required_bandwidth():
    from repro.runtime.offload import required_bandwidth
    assert required_bandwidth(1e9, 0.1) == pytest.approx(1e10)


def test_elastic_choose_layout():
    from repro.runtime.elastic import choose_layout
    cfg = get_config("qwen3-0.6b")
    d = choose_layout(256, cfg, prefer_tp=16)
    assert (d.dp, d.tp) == (16, 16)
    d = choose_layout(24, cfg, prefer_tp=16)   # degraded fleet
    assert d.dp * d.tp == 24 and d.tp <= 16
    d = choose_layout(7, cfg, prefer_tp=16)    # prime count
    assert d.dp * d.tp == 7


def test_compressed_psum_single_axis_identity():
    """With axis size 1 the quantize/sum/dequantize round-trip is within one
    quantization step of the input."""
    from repro.core import collectives as cc
    from repro.optim.compression import compressed_psum
    cc.set_axis_sizes({"x": 1})
    rng = np.random.RandomState(0)
    v = jnp.asarray(rng.randn(1000).astype(np.float32) * 3)
    out = compressed_psum(v, ("x",), "t")
    err = np.abs(np.asarray(out) - np.asarray(v))
    assert err.max() < 3 * 2 / 127 + 1e-6


def test_ef_reducer_state_shapes():
    from repro.core import collectives as cc
    from repro.optim.compression import make_ef_grad_reducer
    cc.set_axis_sizes({"data": 1, "pod": 1})
    reduce, init = make_ef_grad_reducer()
    grads = {"a": jnp.ones((64,), jnp.float32),
             "b": jnp.full((32,), 0.5, jnp.float32)}
    err = init(grads)
    out, err2 = reduce(grads, err)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(grads)
    # single-device: output ~= input, error bounded by quantization step
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0, atol=0.02)


def test_prefetcher_preserves_order():
    from repro.data import DataConfig, PackedBatches, Prefetcher
    dc = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
    direct = [next(iter(PackedBatches(dc))) for _ in range(1)]
    pf = Prefetcher(iter(PackedBatches(dc)), depth=2)
    got = next(pf)
    np.testing.assert_array_equal(got["tokens"], direct[0]["tokens"])


def test_exact_resume_cursor_mid_document():
    """Pipeline state (doc cursor + partial buffer) resumes bit-exactly."""
    from repro.data import DataConfig, PackedBatches
    dc = DataConfig(vocab_size=256, seq_len=64, global_batch=2)
    a = PackedBatches(dc)
    for _ in range(3):
        next(iter(a))
    st = a.state()
    b = PackedBatches(dc, start_doc=st["doc_idx"], buf=st["buf"])
    for _ in range(3):
        x, y = next(iter(a)), next(iter(b))
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
