"""Elastic serving under live traffic: replica join (``scale_to`` up),
drain (``scale_to`` down migrates in-flight KV pages to survivors),
crash (``kill_replica`` re-admits orphans as re-prefills), host-side
spill/restore of radix + cross-KV state, and a seeded chaos property
test: across hundreds of random membership schedules, every request
completes with output token-identical to the dp=1 serial oracle and
every replica drains leak-free.

Schedules are driven by ``faultlib.FaultPlan`` through the engine's
``membership_hook`` (fires at the top of each tick, where membership
changes barrier the overlapped pipeline first), so each schedule replays
exactly from its seed; ``--chaos-seed`` / ``CHAOS_SCHEDULES`` reshuffle
or resize the sweep."""
import os

import numpy as np
import pytest
from faultlib import FaultPlan, inject_transfer_fault

from repro.configs import get_config, reduced
from repro.core import model
from repro.core.kvcache import pages_needed
from repro.core.partition import ShardingPlan
from repro.serving import (FairScheduler, HostSpillStore, PriorityScheduler,
                           Request, ServingEngine)
from repro.serving.sampler import SamplerConfig

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")
PLAN_I8 = ShardingPlan(tp=1, kv_cache_dtype="int8")
PLANS = {"fp32": PLAN, "int8": PLAN_I8}

N_SCHEDULES = int(os.environ.get("CHAOS_SCHEDULES", "200"))

_SCHEDULERS = {
    "fcfs": None,
    "priority": lambda **kw: PriorityScheduler(preemption=True, **kw),
    "fair": lambda **kw: FairScheduler(**kw),
}


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("tinyllama-42m"), dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return {tag: model.init_params(cfg, plan) for tag, plan in PLANS.items()}


def _requests(cfg, n=6, seed=0, max_new=(2, 8)):
    rng = np.random.RandomState(seed)
    return [Request(rid=rid,
                    prompt=rng.randint(2, cfg.vocab_size,
                                       int(rng.randint(4, 20)))
                    .astype(np.int32),
                    max_new_tokens=int(rng.randint(*max_new)),
                    priority=int(rng.randint(0, 3)),
                    client_id=int(rng.randint(0, 3)))
            for rid in range(n)]


def _build(cfg, plan, mesh1, params, dp, slots=2, policy="fcfs", **kw):
    return ServingEngine.build_paged(cfg, plan, mesh1, slots, 64, params,
                                     page_size=8, prefill_chunk=16,
                                     prefix_cache=True, dp=dp,
                                     scheduler=_SCHEDULERS[policy], **kw)


def _assert_leak_free(eng):
    for rr in range(eng.R):
        a = eng.allocators[rr]
        cached = 0
        if eng.prefix_caches[rr] is not None:
            cached += eng.prefix_caches[rr].n_cached_pages
        if eng.cross_caches:
            cached += eng.cross_caches[rr].n_cached_pages
        assert a.n_free + cached == a.n_pages - a.n_reserved, rr
        if eng.slab_allocators:
            assert eng.slab_allocators[rr].n_free == eng.n_slabs - 1, rr


# dp=1 serial oracles, computed once per (plan, request-seed, sampler) and
# shared across all chaos schedules that replay the same request set
_ORACLES = {}


def _oracle(cfg, mesh1, params, tag, req_seed, sampler=None, rng_seed=0):
    key = (tag, req_seed, sampler is not None, rng_seed)
    if key not in _ORACLES:
        reqs = _requests(cfg, seed=req_seed)
        eng = _build(cfg, PLANS[tag], mesh1, params[tag], dp=1,
                     overlap=False, sampler=sampler, rng_seed=rng_seed)
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert all(r.done for r in reqs)
        _ORACLES[key] = {r.rid: tuple(r.out_tokens) for r in reqs}
    return _ORACLES[key]


def _outputs(reqs):
    return {r.rid: tuple(r.out_tokens) for r in reqs}


# ---------------------------------------------------------------------------
# chaos property test
# ---------------------------------------------------------------------------

def test_chaos_schedules_complete_and_match_oracle(cfg, params, mesh1,
                                                   pytestconfig):
    """The headline property: under randomized membership schedules —
    scale-down drains with page migration, scale-up joins, crashes with
    re-admission, layered over all three scheduling policies and both KV
    dtypes — every request completes, greedy outputs are token-identical
    to the dp=1 serial oracle, and a post-run drain leaves every replica
    leak-free."""
    base = int(pytestconfig.getoption("--chaos-seed"))
    applied = {"scale": 0, "kill": 0}
    for i in range(N_SCHEDULES):
        rng = np.random.RandomState([base, i])
        tag = ("fp32", "int8")[rng.randint(2)]
        policy = ("fcfs", "priority", "fair")[rng.randint(3)]
        dp0 = int(rng.randint(2, 4))
        req_seed = int(rng.randint(4))
        ref = _oracle(cfg, mesh1, params, tag, req_seed)
        reqs = _requests(cfg, seed=req_seed)
        eng = _build(cfg, PLANS[tag], mesh1, params[tag], dp=dp0,
                     policy=policy)
        plan = FaultPlan.random(rng).install(eng)
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=5000)
        ctx = (i, tag, policy, dp0, req_seed, plan.events)
        assert all(r.done for r in reqs), ctx
        assert _outputs(reqs) == ref, ctx
        eng.drain()
        _assert_leak_free(eng)
        for _, kind, _v in plan.applied:
            applied[kind] += 1
    # the sweep must actually exercise both event kinds, or the property
    # silently degrades to plain dp serving (tiny CHAOS_SCHEDULES debug
    # sweeps are exempt — too few draws to guarantee both)
    if N_SCHEDULES >= 20:
        assert applied["scale"] > 0 and applied["kill"] > 0, applied


def test_sampled_outputs_schedule_invariant(cfg, params, mesh1):
    """Per-request RNG streams make sampled outputs a function of the
    request alone: two different membership schedules (and the serial
    dp=1 run) produce identical sampled tokens."""
    samp = SamplerConfig(temperature=0.8, top_k=40)
    ref = _oracle(cfg, mesh1, params, "fp32", 2, sampler=samp, rng_seed=7)
    for dp0, events in ((2, [(3, "scale", 1), (8, "scale", 2)]),
                        (3, [(4, "kill", 1)])):
        reqs = _requests(cfg, seed=2)
        eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=dp0,
                     sampler=samp, rng_seed=7)
        FaultPlan(events).install(eng)
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert all(r.done for r in reqs)
        assert _outputs(reqs) == ref, (dp0, events)
        _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# targeted membership-change units
# ---------------------------------------------------------------------------

def test_scale_down_mid_overlap_completes_all(cfg, params, mesh1):
    """``scale_to`` called while a dispatched tick is still in flight must
    barrier first (collect the pending plan) before moving any state — no
    request is dropped and outputs match the serial oracle."""
    ref = _oracle(cfg, mesh1, params, "fp32", 0)
    reqs = _requests(cfg, seed=0)
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=2, overlap=True)
    for r in reqs:
        eng.submit(r)
    for _ in range(50):
        eng.tick()
        if eng._inflight is not None:
            break
    assert eng._inflight is not None, "pipeline never went in flight"
    eng.scale_to(1)
    assert eng.R == 1 and eng._inflight is None
    eng.run(max_ticks=5000)
    assert all(r.done for r in reqs)
    assert _outputs(reqs) == ref
    assert eng.stats.scale_events == 1
    eng.drain()
    _assert_leak_free(eng)


def test_scale_down_migrates_pages(cfg, params, mesh1):
    """With free slots on the survivor, draining moves resident KV pages
    via the transfer step instead of preempting — migrated requests keep
    their tokens (no re-prefill) and outputs still match the oracle."""
    ref = _oracle(cfg, mesh1, params, "int8", 1)
    reqs = _requests(cfg, seed=1)
    eng = _build(cfg, PLAN_I8, mesh1, params["int8"], dp=2, slots=4)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.tick()
    eng.scale_to(1)
    assert eng.stats.migrations > 0 and eng.stats.migrated_pages > 0
    eng.run(max_ticks=5000)
    assert all(r.done for r in reqs)
    assert _outputs(reqs) == ref
    eng.drain()
    _assert_leak_free(eng)


def test_migrated_slot_survives_preemption(cfg, params, mesh1):
    """Mid-migration preemption: a slot that just migrated to a survivor
    preempts and resumes there like any native admission."""
    ref = _oracle(cfg, mesh1, params, "fp32", 1)
    reqs = _requests(cfg, seed=1)
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=2, slots=4)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.tick()
    eng.scale_to(1)
    assert eng.stats.migrations > 0
    b = next(b for b, adm in enumerate(eng.admissions) if adm is not None)
    eng.preempt(b)
    assert eng.stats.preemptions >= 1
    eng.run(max_ticks=5000)
    assert all(r.done for r in reqs)
    assert _outputs(reqs) == ref
    eng.drain()
    _assert_leak_free(eng)


def test_crash_during_handoff_rolls_back(cfg, params, mesh1):
    """A transfer fault mid-migration (after the destination admission is
    claimed, before the device copy) must roll back atomically: the
    destination claim is released, the source slot keeps serving, and the
    drain falls back to preemption — refcounts intact."""
    ref_reqs = _requests(cfg, seed=3, n=2)
    e1 = _build(cfg, PLAN, mesh1, params["fp32"], dp=1, overlap=False)
    for r in ref_reqs:
        e1.submit(r)
    e1.run(max_ticks=5000)
    ref = _outputs(ref_reqs)
    reqs = _requests(cfg, seed=3, n=2)
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=2, overlap=False)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.tick()
    b_src = next(b for b, adm in enumerate(eng.admissions)
                 if adm is not None and b // eng.Bp == 1)
    free_before = eng.allocators[0].n_free
    rc_before = [eng.allocators[1].refcount(p)
                 for p in eng.admissions[b_src].pages]
    state = inject_transfer_fault(eng, fail_calls=range(1, 100))
    assert eng._migrate_slot(b_src, [0]) is False
    assert state["faults"] == 1
    # destination claim rolled back, source untouched
    assert eng.allocators[0].n_free == free_before
    assert eng.admissions[b_src] is not None
    assert [eng.allocators[1].refcount(p)
            for p in eng.admissions[b_src].pages] == rc_before
    # with the transfer step still failing, a full drain degrades to
    # preempt + re-admit — still no request lost
    eng.scale_to(1)
    assert eng.stats.migrations == 0
    eng.run(max_ticks=5000)
    assert all(r.done for r in reqs)
    assert _outputs(reqs) == ref
    eng.drain()
    _assert_leak_free(eng)


def test_crash_readmits_exact_continuation(cfg, params, mesh1):
    """``kill_replica`` re-admits the dead replica's in-flight requests
    elsewhere as re-prefills over prompt+emitted — already-emitted tokens
    are kept, not regenerated, and the final outputs match the oracle."""
    ref = _oracle(cfg, mesh1, params, "fp32", 0)
    reqs = _requests(cfg, seed=0)
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=2)
    for r in reqs:
        eng.submit(r)
    victim = None
    for _ in range(200):
        eng.tick()
        victim = next((r for r in reqs
                       if r.replica == 1 and r.out_tokens and not r.done),
                      None)
        if victim is not None:
            break
    assert victim is not None, "no replica-1 request ever emitted a token"
    emitted = list(victim.out_tokens)
    report = eng.kill_replica(1)
    assert report.replica == 1 and victim.rid in report.active_rids
    assert eng.R == 1 and eng.stats.crashes == 1
    eng.run(max_ticks=5000)
    assert all(r.done for r in reqs)
    assert victim.out_tokens[:len(emitted)] == emitted
    assert _outputs(reqs) == ref
    assert eng.stats.readmitted >= len(report.active_rids)
    eng.drain()
    _assert_leak_free(eng)


def test_admission_during_active_drain_avoids_draining_replica(cfg, params,
                                                               mesh1):
    """Router staleness regression: a replica marked draining must be
    excluded from placement even when it has the least page load."""
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=2)
    busy = Request(rid=0, prompt=np.arange(2, 18, dtype=np.int32),
                   max_new_tokens=8)
    eng.submit(busy)
    eng.tick()
    assert busy.replica == 0
    # replica 1 is empty (least load) but draining — placement must skip it
    eng.router.mark_draining(1)
    late = Request(rid=1, prompt=np.arange(2, 8, dtype=np.int32),
                   max_new_tokens=2)
    eng.submit(late)
    assert late.replica == 0
    assert eng.router.decode_placement([0, 1]) == 0
    eng.run(max_ticks=5000)
    assert busy.done and late.done
    eng.drain()
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# host-side spill/restore
# ---------------------------------------------------------------------------

def test_spill_restore_int8_byte_identity(cfg, params, mesh1):
    """Drain-time spill of a leaving replica's radix entries and restore
    into a survivor round-trips int8 payloads (and their scale rows)
    byte-identically — verified leaf-by-leaf against the pre-drain pages."""
    reqs = _requests(cfg, seed=1)
    store = HostSpillStore()
    eng = _build(cfg, PLAN_I8, mesh1, params["int8"], dp=2, spill=store)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5000)
    eng._barrier()
    donor = next(r for r in range(2)
                 if eng.prefix_caches[r].n_cached_pages > 0)
    before = {}
    for toks, pages in eng.prefix_caches[donor].entries():
        before[toks] = [np.asarray(leaf[:, donor, list(pages)])
                        for leaf in eng._kind_leaves("kv")]
    assert before, "no radix entries to spill"
    keep = 1 - donor
    eng._drain_replicas([donor], [keep])
    eng._rebuild([keep], 1)
    eng._restore_from_spill(store)
    assert store.pages_saved > 0 and store.pages_restored > 0
    for toks, payloads in before.items():
        n, pages = eng.prefix_caches[0].lookup(list(toks))
        assert n == len(toks), "restored prefix not found"
        for leaf, want in zip(eng._kind_leaves("kv"), payloads):
            np.testing.assert_array_equal(
                np.asarray(leaf[:, 0, list(pages)]), want)
    _assert_leak_free(eng)


def test_spill_persists_radix_across_restart(cfg, params, mesh1):
    """An engine restart with the previous engine's spill store warm-starts
    the radix cache: a repeated prompt prefix skips prefill work."""
    reqs = _requests(cfg, seed=0, n=4)
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=1)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5000)
    store = eng.spill_state()
    assert store.n_entries > 0 and store.pages_saved > 0

    eng2 = _build(cfg, PLAN, mesh1, params["fp32"], dp=1, spill=store)
    assert store.pages_restored > 0
    again = [Request(rid=r.rid + 100, prompt=r.prompt.copy(),
                     max_new_tokens=int(r.max_new_tokens))
             for r in reqs]
    for r in again:
        eng2.submit(r)
    eng2.run(max_ticks=5000)
    assert all(r.done for r in again)
    assert _outputs(again) == {r.rid + 100: tuple(r.out_tokens)
                               for r in reqs}
    assert eng2.stats.prefix_hits > 0
    assert eng2.stats.prefill_tokens_skipped > 0
    eng2.drain()
    _assert_leak_free(eng2)


@pytest.mark.slow
def test_spill_persists_cross_kv_across_restart(mesh1):
    """Enc-dec: spilled cross-KV entries restore into a fresh engine, so
    a request with already-seen frames hits without re-encoding."""
    cfg = reduced(get_config("seamless-m4t-large-v2"), dtype="float32",
                  n_enc_layers=1, enc_seq_len=16)
    p = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(3)
    frames = rng.randn(cfg.enc_seq_len, cfg.d_model).astype(np.float32)
    mk = lambda rid: Request(  # noqa: E731
        rid=rid, prompt=rng.randint(2, cfg.vocab_size, 7).astype(np.int32),
        max_new_tokens=3, frames=frames.copy())
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, p,
                                    page_size=8, prefill_chunk=8)
    r0 = mk(0)
    eng.submit(r0)
    eng.run(max_ticks=2000)
    assert eng.stats.cross_encodes == 1
    store = eng.spill_state()
    assert store.n_entries > 0

    eng2 = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, p,
                                     page_size=8, prefill_chunk=8,
                                     spill=store)
    r1 = mk(1)
    eng2.submit(r1)
    eng2.run(max_ticks=2000)
    assert r1.done
    assert eng2.stats.cross_hits == 1 and eng2.stats.cross_encodes == 0
    eng2.drain()
    _assert_leak_free(eng2)


# ---------------------------------------------------------------------------
# archs without a transfer path + validation
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ssm_scale_down_preempts_and_recovers(mesh1):
    """Hybrid/SSM state lives in slabs the transfer step doesn't cover, so
    draining such replicas falls back to preempt + host stash — outputs
    still match the serial oracle and slabs stay leak-free."""
    cfg = reduced(get_config("mamba2-370m"), dtype="float32")
    p = model.init_params(cfg, PLAN)

    def build(dp):
        return ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, p,
                                         page_size=8, prefill_chunk=16,
                                         n_pages=16, dp=dp,
                                         overlap=(dp > 1))

    ref = _requests(cfg, seed=4, n=4)
    e1 = build(1)
    for r in ref:
        e1.submit(r)
    e1.run(max_ticks=2000)

    reqs = _requests(cfg, seed=4, n=4)
    eng = build(2)
    FaultPlan([(3, "scale", 1)]).install(eng)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=2000)
    assert all(r.done for r in reqs)
    assert _outputs(reqs) == _outputs(ref)
    assert eng.stats.scale_events == 1 and eng.stats.migrations == 0
    eng.drain()
    _assert_leak_free(eng)


def test_scale_validation(cfg, params, mesh1):
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=1)
    with pytest.raises(ValueError):
        eng.scale_to(0)
    with pytest.raises(ValueError):
        eng.kill_replica(0)            # cannot kill the last replica
    eng.scale_to(1)                    # no-op, not an error
    assert eng.stats.scale_events == 0

    disagg = ServingEngine.build_paged(cfg, PLANS["fp32"], mesh1, 1, 64,
                                       params["fp32"], page_size=8,
                                       prefill_chunk=16, dp=2,
                                       disagg=(1, 1))
    with pytest.raises(ValueError, match="disagg"):
        disagg.scale_to(1)


def test_pages_needed_budget_covers_migration(cfg, params, mesh1):
    """The migration plan's page budget (full effective prompt + remaining
    tokens) always covers the resident-KV transfer set."""
    reqs = _requests(cfg, seed=1)
    eng = _build(cfg, PLAN, mesh1, params["fp32"], dp=2, slots=4)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.tick()
    for b, adm in enumerate(eng.admissions):
        if adm is None:
            continue
        n = (eng.prefill_done[b] if eng.slot_state[b] == "prefill"
             else eng.pos[b])
        assert pages_needed(n, eng.page_size) <= len(adm.pages), b
    eng.run(max_ticks=5000)
    eng.drain()
    _assert_leak_free(eng)
