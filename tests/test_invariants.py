"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import collectives as cc  # noqa: E402
from repro.core.partition import dim_layout, head_layout  # noqa: E402
from repro.sim.simulator import hierarchical_allreduce_time  # noqa: E402
from repro.sim.siracusa import SiracusaConfig  # noqa: E402


# --- paper contract: wire-cost model ---------------------------------------

@given(st.integers(1, 64), st.floats(1, 1e9))
@settings(max_examples=50, deadline=None)
def test_ring_psum_wire_bytes_monotone(n, payload):
    cc.set_axis_sizes({"x": n})
    b = cc.wire_bytes("psum", payload, ("x",))
    assert b >= 0
    if n == 1:
        assert b == 0
    else:
        # ring all-reduce: 2*P*(n-1)/n, strictly under 2*P
        assert abs(b - 2 * payload * (n - 1) / n) < 1e-6
        assert b < 2 * payload


@given(st.integers(2, 256), st.integers(1, 10_000_000))
@settings(max_examples=50, deadline=None)
def test_hierarchical_allreduce_bytes_linear_in_chips(n, payload):
    cfg = SiracusaConfig()
    t, bytes_ = hierarchical_allreduce_time(cfg, float(payload), n)
    assert t > 0 and bytes_ > 0
    # tree reduce+broadcast moves < 2 * n * payload
    assert bytes_ <= 2 * n * payload + 1e-6


# --- layout algebra ----------------------------------------------------------

@given(st.integers(1, 128), st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_head_layout_total_work_conserved(hq_mult, hkv, tp):
    hq = hkv * max(1, hq_mult // hkv)   # ensure divisible hq/hkv
    hl = head_layout(hq, hkv, tp)
    # padded heads never exceed one extra shard-row
    assert hl.hq_pad - hq < tp
    # every shard has identical local work (SPMD uniformity)
    assert hl.hq_loc * tp == hl.hq_pad
    assert hl.r * hl.n_kv_loc == hl.hq_loc
    # valid mask marks exactly hq heads
    assert sum(sum(row) for row in hl.q_valid) == hq


@given(st.integers(1, 100_000), st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_dim_layout_roundtrip(n, tp):
    dl = dim_layout(n, tp)
    assert dl.n_pad % tp == 0
    assert 0 <= dl.n_pad - n < tp
    assert dl.loc == dl.n_pad // tp


# --- quantized collectives ---------------------------------------------------

@given(st.integers(0, 5))
@settings(max_examples=5, deadline=None)
def test_compression_error_feedback_bounded(seed):
    """int8 EF quantization error is bounded by one quantization step."""
    from repro.optim.compression import BLOCK
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(1000) * rng.uniform(0.1, 10), jnp.float32)
    flat = np.asarray(x)
    pad = (-flat.size) % BLOCK
    blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = np.abs(blocks).max(axis=1, keepdims=True) / 127.0 + 1e-12
    q = np.clip(np.round(blocks / scale), -127, 127)
    deq = (q * scale).reshape(-1)[: flat.size]
    err = np.abs(deq - flat)
    assert (err <= scale.max() * 0.5 + 1e-6).all()


# --- data pipeline determinism ------------------------------------------------

@given(st.integers(0, 1000), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_pipeline_deterministic_and_resumable(start_doc, batches):
    from repro.data import DataConfig, PackedBatches
    dc = DataConfig(vocab_size=512, seq_len=32, global_batch=2)
    a = PackedBatches(dc, start_doc=start_doc)
    b = PackedBatches(dc, start_doc=start_doc)
    for _ in range(batches):
        x, y = next(iter(a)), next(iter(b))
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # resume from saved cursor reproduces the stream
    c = PackedBatches(dc, start_doc=a.state()["doc_idx"])
    # drain a's internal buffer to align: fresh instances only guarantee
    # document-boundary resume, which is what checkpoints store
    assert c.state()["doc_idx"] == a.state()["doc_idx"]
