"""Prefix-cache subsystem: radix tree hit/miss/partial-hit, allocator
refcounts, LRU eviction, the COW page-copy step, and engine-level
equivalence — greedy outputs with the prefix cache on are token-identical
to the cache-off oracle while allocating measurably fewer pages."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import model, steps
from repro.core.kvcache import PageAllocator
from repro.core.partition import ShardingPlan
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.scheduler import FCFSScheduler

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")
PSZ = 4


def toks(*ids):
    return np.asarray(ids, np.int32)


# ---------------------------------------------------------------------------
# allocator refcounts
# ---------------------------------------------------------------------------

def test_allocator_refcounts():
    a = PageAllocator(8)
    pages = a.alloc(3)
    assert [a.refcount(p) for p in pages] == [1, 1, 1]
    a.incref(pages)                       # a second owner (e.g. the cache)
    a.decref(pages)
    assert a.n_free == 4                  # still alive: one ref remains
    assert all(a.refcount(p) == 1 for p in pages)
    a.decref(pages)
    assert a.n_free == 7                  # last ref dropped -> pool
    with pytest.raises(AssertionError):
        a.incref([pages[0]])              # can't share a freed page
    assert a.total_allocated == 3


# ---------------------------------------------------------------------------
# radix tree: hit / miss / partial hit / split / refcounts
# ---------------------------------------------------------------------------

def _cache(n_pages=32):
    a = PageAllocator(n_pages)
    return a, RadixPrefixCache(a, PSZ)


def test_radix_miss_and_exact_hit():
    a, c = _cache()
    assert c.lookup(toks(1, 2, 3, 4)) == (0, [])
    pages = a.alloc(2)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), pages)
    assert all(a.refcount(p) == 2 for p in pages)  # slot ref + cache ref
    m, run = c.lookup(toks(1, 2, 3, 4, 5, 6, 7, 8))
    assert m == 8 and run == pages
    # shorter aligned prefix
    m, run = c.lookup(toks(1, 2, 3, 4))
    assert m == 4 and run == pages[:1]
    # unrelated prompt
    assert c.lookup(toks(9, 9, 9, 9))[0] == 0


def test_radix_partial_hit_mid_page_is_cow_source():
    a, c = _cache()
    pages = a.alloc(2)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), pages)
    # diverges inside the first page: match_len 2, page 0 is the COW source
    m, run = c.lookup(toks(1, 2, 99, 98, 97))
    assert m == 2 and run == [pages[0]]
    # diverges inside the second page
    m, run = c.lookup(toks(1, 2, 3, 4, 5, 99))
    assert m == 5 and run == pages


def test_radix_split_shares_page_aligned_prefix():
    a, c = _cache()
    p1 = a.alloc(2)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), p1)
    p2 = a.alloc(2)
    # same first page of tokens, different second page
    new = c.insert(toks(1, 2, 3, 4, 50, 60, 70, 80), p2)
    assert new == 1                       # only the divergent page is new
    assert a.refcount(p2[0]) == 1         # duplicate first page NOT cached
    assert a.refcount(p2[1]) == 2
    m, run = c.lookup(toks(1, 2, 3, 4, 50, 60, 70, 80))
    assert m == 8 and run == [p1[0], p2[1]]   # shared structural prefix
    m, run = c.lookup(toks(1, 2, 3, 4, 5, 6, 7, 8))
    assert m == 8 and run == p1
    assert c.n_nodes == 3                 # split parent + two tails


def test_radix_lru_eviction_and_shared_protection():
    a, c = _cache(n_pages=32)
    p1, p2 = a.alloc(1), a.alloc(1)
    c.insert(toks(1, 2, 3, 4), p1)
    c.insert(toks(9, 8, 7, 6), p2)
    a.decref(p1)                          # both runs now cache-only...
    c.lookup(toks(1, 2, 3, 4))            # ...but run 1 is recently used
    # run 2 still carries its slot ref: eviction must skip it
    freed = c.evict(1)
    assert freed == 1                     # evicted run 1 (LRU among free)
    assert c.lookup(toks(1, 2, 3, 4))[0] == 0
    assert c.lookup(toks(9, 8, 7, 6))[0] == 4
    a.decref(p2)
    freed = c.evict(5)                    # more than cached: frees what it can
    assert freed == 1 and c.n_nodes == 0
    assert a.n_free == 31


def test_radix_eviction_children_before_parents():
    a, c = _cache()
    p = a.alloc(3)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), p[:2])
    c.insert(toks(1, 2, 3, 4, 50, 60, 70, 80), [p[0], p[2]])
    a.decref(p)                           # cache is now the sole owner
    assert c.evict(3) == 3                # leaves first, then the parent
    assert c.n_nodes == 0 and a.n_free == 31


# ---------------------------------------------------------------------------
# COW page-copy step
# ---------------------------------------------------------------------------

def test_page_copy_step(mesh1):
    cfg = reduced(get_config("qwen3-0.6b"), dtype="float32")
    N_PAGES, P_SZ = 6, 4
    copy_fn, _, _ = steps.make_page_copy_step(cfg, PLAN, mesh1, N_PAGES, P_SZ)
    copy_fn = jax.jit(copy_fn)
    cache = steps.zero_paged_cache_for(cfg, PLAN, mesh1, N_PAGES, P_SZ)
    rng = np.random.RandomState(0)
    cache = jax.tree_util.tree_map(
        lambda x: jnp.asarray(rng.randn(*x.shape), x.dtype), cache)
    with mesh1:
        out = copy_fn(cache, jnp.asarray([2], jnp.int32),
                      jnp.asarray([5], jnp.int32))
    for old, new in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(out), strict=True):
        # pools carry a leading replica dim: (reps, R, n_pages, G, psz, D)
        old, new = np.asarray(old)[:, 0], np.asarray(new)[:, 0]
        np.testing.assert_array_equal(new[:, 5], old[:, 2])     # copied
        keep = [i for i in range(N_PAGES) if i != 5]
        np.testing.assert_array_equal(new[:, keep], old[:, keep])


# ---------------------------------------------------------------------------
# scheduler-level: COW planning against a tight pool
# ---------------------------------------------------------------------------

class _Req:
    def __init__(self, rid, prompt, max_new):
        self.rid, self.prompt, self.max_new_tokens = rid, prompt, max_new


def test_scheduler_plans_cow_and_rolls_back_under_pressure():
    a = PageAllocator(8)                  # 7 usable
    c = RadixPrefixCache(a, PSZ)
    stats = None
    sched = FCFSScheduler(seq_budget=64, allocator=a, page_size=PSZ,
                          prefix_cache=c, stats=stats)
    seed_pages = a.alloc(2)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), seed_pages)
    a.decref(seed_pages)                  # cache-only now (5 free)
    # partial hit: 6 of 8 tokens -> 1 shared page + COW copy of page 2
    sched.submit(_Req(0, toks(1, 2, 3, 4, 5, 6, 90, 91), 4))
    (adm,) = sched.plan([0])
    assert adm.cached_len == 6
    assert adm.pages[0] == seed_pages[0]
    assert adm.cow == (seed_pages[1], adm.pages[1])
    assert a.refcount(seed_pages[0]) == 2      # shared full page pinned
    assert a.refcount(seed_pages[1]) == 2      # COW source pinned
    sched.on_cow_done(adm)
    assert a.refcount(seed_pages[1]) == 1      # pin released after the copy
    # a request too big for the remaining pool: head-of-line blocks cleanly
    # (needs 6 pages; only 3 free and the cached run is pinned by adm)
    sched.submit(_Req(1, toks(*range(40, 60)), 4))
    assert sched.plan([1]) == []
    assert a.refcount(seed_pages[0]) == 2      # rollback left refs intact
    sched.on_finish(adm)
    assert a.refcount(seed_pages[0]) == 1
    # retirement freed slot pages; eviction reclaims the now-unpinned run
    (adm2,) = sched.plan([1])
    assert adm2.req.rid == 1 and len(adm2.pages) == 6
    assert c.n_nodes == 0                      # evicted under pressure


def test_scheduler_skips_futile_eviction_and_keeps_hot_prefixes():
    """When eviction cannot cover the shortfall anyway, blocking must not
    wipe cached runs — queued requests would lose the hot prefix for
    nothing."""
    a = PageAllocator(11)                 # 10 usable
    c = RadixPrefixCache(a, PSZ)
    sched = FCFSScheduler(seq_budget=64, allocator=a, page_size=PSZ,
                          prefix_cache=c, stats=None)
    slot_held = a.alloc(6)                # in-flight slots elsewhere
    run = a.alloc(2)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), run)
    a.decref(run)                         # hot cached run; 2 pages free
    sched.submit(_Req(0, toks(*range(20, 38)), 2))   # needs 5, no match
    assert sched.plan([0]) == []          # blocks...
    assert c.n_nodes == 1                 # ...without wiping the hot run
    a.decref(slot_held)                   # slots retire
    (adm,) = sched.plan([0])
    assert len(adm.pages) == 5
    assert c.n_nodes == 1                 # still cached: free pages sufficed
    sched.on_finish(adm)


def test_scheduler_degrades_to_cold_prefill_instead_of_livelock():
    """A submit-accepted request must never block forever on its own prefix
    pins: when the matched run is unevictable only because the request
    pinned it, admission falls back to a cold prefill."""
    a = PageAllocator(8)                  # 7 usable
    c = RadixPrefixCache(a, PSZ)
    sched = FCFSScheduler(seq_budget=64, allocator=a, page_size=PSZ,
                          prefix_cache=c, stats=None)
    run = a.alloc(2)
    c.insert(toks(1, 2, 3, 4, 5, 6, 7, 8), run)
    a.decref(run)                         # cache-only (5 free)
    # shares 6/8 tokens; needs all 7 usable pages -> prefix pins would
    # leave only 5 free with 6 needed and nothing evictable
    sched.submit(_Req(0, toks(1, 2, 3, 4, 5, 6, 90, 91, 92, 93, 94, 95,
                              96, 97, 98, 99, 100, 101, 102, 103, 104), 7))
    (adm,) = sched.plan([0])              # pre-fix: [] forever (livelock)
    assert adm.cached_len == 0 and adm.cow is None
    assert len(adm.pages) == 7            # cold: full budget, run evicted
    assert c.n_nodes == 0
    sched.on_finish(adm)
    assert a.n_free == 7


def test_contiguous_scheduler_rejects_over_budget_prompt():
    sched = FCFSScheduler(seq_budget=16)          # contiguous: no allocator
    with pytest.raises(RuntimeError, match="budget"):
        sched.submit(_Req(0, toks(*range(16)), 4))
    sched.submit(_Req(1, toks(*range(15)), 4))    # strictly inside: fine


def test_scheduler_rejects_empty_prompt():
    sched = FCFSScheduler(seq_budget=16, allocator=PageAllocator(8),
                          page_size=PSZ, prefix_cache=None, stats=None)
    with pytest.raises(RuntimeError, match="empty"):
        sched.submit(_Req(0, toks(), 4))


# ---------------------------------------------------------------------------
# engine-level: shared-prefix workload equivalence + fewer pages
# ---------------------------------------------------------------------------

def _mk_requests(cfg, seed=0):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    base = rng.randint(2, cfg.vocab_size, 21).astype(np.int32)
    prompts = []
    for i in range(5):                    # shared 21-token system prompt
        suf = rng.randint(2, cfg.vocab_size, 3 + i).astype(np.int32)
        prompts.append(np.concatenate([base, suf]).astype(np.int32))
    # diverges mid-page (shares 5 of the first 8 tokens): exercises COW
    prompts.append(np.concatenate(
        [base[:5], rng.randint(2, cfg.vocab_size, 6).astype(np.int32)]))
    # identical full prompt, length a page multiple: COW via the >=1-token
    # prefill floor (cached_len capped at L-1)
    prompts.append(prompts[0].copy())
    return [Request(rid=i, prompt=p.astype(np.int32), max_new_tokens=5)
            for i, p in enumerate(prompts)]


def _run_engine(cfg, params, mesh1, prefix_cache, n_pages=0):
    from repro.serving import ServingEngine
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    n_pages=n_pages,
                                    prefix_cache=prefix_cache)
    reqs = _mk_requests(cfg)
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_ticks=10_000)
    return eng, reqs, stats


@pytest.mark.slow
def test_prefix_cache_engine_matches_oracle_and_saves_pages(mesh1):
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    e_off, r_off, s_off = _run_engine(cfg, params, mesh1, prefix_cache=False)
    e_on, r_on, s_on = _run_engine(cfg, params, mesh1, prefix_cache=True)
    for a, b in zip(r_off, r_on, strict=True):
        assert a.done and b.done
        assert a.out_tokens == b.out_tokens, a.rid   # greedy token-identical
    # the shared prefix was actually reused, including COW divergences
    assert s_on.prefill_tokens_skipped > 0
    assert s_on.cow_copies >= 2           # mid-page diverger + resubmission
    assert s_on.prefix_hits > 0 and s_on.prefix_hit_rate > 0
    assert s_off.prefill_tokens_skipped == 0
    # measurably fewer pages pulled from the pool
    assert e_on.allocator.total_allocated < e_off.allocator.total_allocated
    # accounting: every non-cached page returned; cache refs balance
    usable = e_on.allocator.n_pages - e_on.allocator.n_reserved
    assert e_on.allocator.n_free + e_on.prefix_cache.n_cached_pages == usable
    assert e_off.allocator.n_free == \
        e_off.allocator.n_pages - e_off.allocator.n_reserved
    # per-request TTFT recorded for every request
    assert set(s_on.request_ttft) == {r.rid for r in r_on}


@pytest.mark.slow
def test_prefix_cache_evicts_under_pool_exhaustion(mesh1):
    """Distinct prompts through a pool that can't hold them all cached:
    LRU eviction keeps admissions flowing and every request completes."""
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 32, params,
                                    page_size=8, prefill_chunk=8,
                                    n_pages=9, prefix_cache=True)  # 8 usable
    rng = np.random.RandomState(3)
    reqs = []
    for rid in range(12):
        L = int(rng.randint(8, 20))
        req = Request(rid=rid,
                      prompt=rng.randint(2, cfg.vocab_size, L).astype(np.int32),
                      max_new_tokens=int(rng.randint(1, 6)))
        reqs.append(req)
        eng.submit(req)
    eng.run(max_ticks=20_000)
    assert all(r.done for r in reqs)
    assert eng.prefix_cache.evictions > 0          # pressure really happened
    usable = eng.allocator.n_pages - eng.allocator.n_reserved
    assert eng.allocator.n_free + eng.prefix_cache.n_cached_pages == usable
