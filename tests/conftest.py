import os
import sys

# tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess; multi-device TP tests spawn subprocesses with their own flags)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

AXT = (jax.sharding.AxisType.Auto,)


@pytest.fixture(scope="session")
def mesh1():
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=AXT * 2,
                         devices=jax.devices()[:1])
