import os
import sys

# tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess; multi-device TP tests spawn subprocesses with their own flags)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

from repro import compat  # noqa: E402


def pytest_addoption(parser):
    parser.addoption("--chaos-seed", type=int, default=0,
                     help="base seed for the elastic-serving chaos schedule "
                          "sweep (tests/test_elastic_serving.py); pair with "
                          "CHAOS_SCHEDULES=<n> to resize the sweep")


@pytest.fixture(scope="session")
def mesh1():
    return compat.make_mesh((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
