"""Sampler tests + extra hypothesis properties (attention, analytics).

The sampler tests are plain pytest; only the property tests at the bottom
need ``hypothesis`` (skipped when it isn't installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import (
    SamplerConfig, merged_topk_sample, sample_from_logits)


def test_greedy_ignores_vocab_padding():
    rng = np.random.RandomState(0)
    logits = np.full((2, 10), -1.0, np.float32)
    logits[:, 8:] = 5.0              # padded slots have junk-high logits
    out = sample_from_logits(logits, SamplerConfig(), vocab_size=8, rng=rng)
    assert (out < 8).all()


def test_topk_sampling_support():
    rng = np.random.RandomState(0)
    logits = np.zeros((1, 16), np.float32)
    logits[0, 3], logits[0, 7] = 10.0, 9.0
    cfg = SamplerConfig(temperature=1.0, top_k=2)
    draws = {int(sample_from_logits(logits, cfg, 16, rng)[0])
             for _ in range(50)}
    assert draws <= {3, 7}


@pytest.mark.parametrize("top_p", [0.0, 0.7],
                         ids=["topk-only", "nucleus"])
def test_merged_topk_sampling_matches_single_host(top_p):
    """Sampling on the TP-merged path draws the SAME tokens as
    ``sample_from_logits`` on the full logits, from the same seed — the
    pre-fix code ignored top_p entirely, and the top_k-only branch drew
    over a probability-ordered CDF while the single host draws over
    token-id order, so both silently diverged."""
    cfg = SamplerConfig(temperature=0.8, top_k=8, top_p=top_p)
    for seed in range(5):
        rng = np.random.RandomState(seed)
        full = rng.randn(1, 64).astype(np.float32) * 3.0
        # simulate 4 shards each contributing their local top-8
        vals, ids = [], []
        for s in range(4):
            sl = full[0, s * 16:(s + 1) * 16]
            top = np.argsort(-sl)[:8]
            vals += list(sl[top])
            ids += list(top + s * 16)
        for draw in range(20):
            r1 = np.random.RandomState([seed, draw])
            r2 = np.random.RandomState([seed, draw])
            want = int(sample_from_logits(full, cfg, 64, r1)[0])
            got = merged_topk_sample((np.array(vals), np.array(ids)),
                                     cfg, 64, r2)
            assert got == want, (seed, draw)


def test_merged_topk_top_p_restricts_support():
    """With a sharply peaked distribution, top_p=0.5 must exclude the tail
    candidates even though top_k would admit them."""
    rng = np.random.RandomState(0)
    vals = np.array([10.0, 9.8, 0.0, -1.0, -2.0, -3.0])
    ids = np.arange(6)
    cfg = SamplerConfig(temperature=1.0, top_k=6, top_p=0.5)
    draws = {merged_topk_sample((vals, ids), cfg, 16, rng)
             for _ in range(100)}
    assert draws <= {0, 1}


def test_merged_topk_greedy_exact():
    rng = np.random.RandomState(0)
    full = rng.randn(64).astype(np.float64)
    # simulate 4 shards each contributing their local top-4
    vals, ids = [], []
    for s in range(4):
        sl = full[s * 16:(s + 1) * 16]
        top = np.argsort(-sl)[:4]
        vals += list(sl[top])
        ids += list(top + s * 16)
    got = merged_topk_sample((np.array(vals), np.array(ids)),
                             SamplerConfig(), 64, rng)
    assert got == int(np.argmax(full))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(st.integers(8, 64), st.integers(8, 64), st.integers(0, 3))
    @settings(max_examples=15, deadline=None)
    def test_flash_attention_property(sq, skv, seed):
        """Chunked flash == dense softmax attention for random shapes."""
        from repro.core.attention import flash_attention
        from repro.kernels import ref
        skv = max(skv, sq)           # suffix alignment requires skv >= sq
        rng = np.random.RandomState(seed)
        q = jnp.asarray(rng.randn(1, 1, 1, sq, 8), jnp.float32)
        k = jnp.asarray(rng.randn(1, 1, skv, 8), jnp.float32)
        v = jnp.asarray(rng.randn(1, 1, skv, 8), jnp.float32)
        out = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16,
                              kv_offset=0, q_offset=skv - sq)
        expect = ref.ref_flash_attention(q[0, 0], k[0], v[0], causal=True)
        np.testing.assert_allclose(np.asarray(out[0, 0]),
                                   np.asarray(expect), rtol=2e-4, atol=2e-4)

    @given(st.sampled_from(["qwen3-0.6b", "mamba2-370m", "mixtral-8x22b"]),
           st.sampled_from(["train_4k", "prefill_32k", "decode_32k"]))
    @settings(max_examples=12, deadline=None)
    def test_step_cost_positive_and_scales(arch, shape_name):
        """Analytic cost is positive; decode <= prefill <= train per dev."""
        from repro.configs import SHAPES, get_config
        from repro.core import analytics
        from repro.core.partition import ShardingPlan
        cfg = get_config(arch)
        plan = ShardingPlan(tp=16, remat="block")
        sizes = {"data": 16, "model": 16}
        c = analytics.step_cost(cfg, plan, SHAPES[shape_name], sizes)
        assert c.total_flops > 0 and c.total_bytes > 0
        if shape_name == "train_4k":
            cp = analytics.step_cost(cfg, plan, SHAPES["decode_32k"], sizes)
            assert c.total_flops > cp.total_flops
else:                                    # keep the skip visible in -q runs
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_properties():
        pass
