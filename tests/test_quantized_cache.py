"""Quantized page pools: int8 KV/slab/cross payloads with per-page scale
side tensors.  Covers the template gating (scale leaves exist ONLY under an
int8 plan, so fp paths stay bit-identical), the per-row quantizer units,
the int8 dequant-on-read Pallas kernels against the dequant refs, and
engine-level greedy token-identity against the fp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import model
from repro.core.blocks import _row_quant
from repro.core.kvcache import (kv_pool_is_quantized, paged_cache_template,
                                ssm_pool_is_quantized)
from repro.core.partition import ShardingPlan, model_layout

PLAN_FP = ShardingPlan(tp=1, kv_cache_dtype="float32")
PLAN_I8 = ShardingPlan(tp=1, kv_cache_dtype="int8", ssm_cache_dtype="int8")


def _cfg(name="tinyllama-42m"):
    return reduced(get_config(name), dtype="float32")


# ---------------------------------------------------------------------------
# template gating: scale leaves appear only under int8 plans
# ---------------------------------------------------------------------------

def test_plan_predicates():
    assert not kv_pool_is_quantized(PLAN_FP)
    assert not ssm_pool_is_quantized(PLAN_FP)
    assert kv_pool_is_quantized(PLAN_I8)
    assert ssm_pool_is_quantized(PLAN_I8)
    assert not ssm_pool_is_quantized(ShardingPlan(kv_cache_dtype="int8"))


def _template_keys(cfg, plan, n_slabs=0):
    tmpl = paged_cache_template(cfg, plan, model_layout(cfg, plan), 8, 4,
                                n_slabs=n_slabs)
    out = {}
    for pat in tmpl:
        for d in pat:
            for kind, leaves in d.items():
                for k, (shape, dtype, _) in leaves.items():
                    out[(kind, k)] = (shape[1:], dtype)   # strip scan reps
    return out

def test_template_int8_gains_scale_leaves():
    cfg = _cfg()
    fp = _template_keys(cfg, PLAN_FP)
    i8 = _template_keys(cfg, PLAN_I8)
    assert ("kv", "ksp") not in fp and ("kv", "vsp") not in fp
    assert i8[("kv", "kp")][1] == jnp.int8
    # one float32 scale per (replica, page, token slot)
    for k in ("ksp", "vsp"):
        shape, dtype = i8[("kv", k)]
        assert shape == (1, 8, 4) and dtype == jnp.float32


def test_template_int8_ssm_and_cross():
    hy = _cfg("hymba-1.5b")
    i8 = _template_keys(hy, PLAN_I8, n_slabs=3)
    assert i8[("ssm", "statep")][1] == jnp.int8
    H = model_layout(hy, PLAN_I8).ssm.hq_loc
    assert i8[("ssm", "sscalep")] == ((1, 3, H), jnp.float32)
    # conv pools are NOT quantized (tiny, precision-critical tails)
    assert i8[("ssm", "conv_xp")][1] != jnp.int8
    fp = _template_keys(hy, PLAN_FP, n_slabs=3)
    assert ("ssm", "sscalep") not in fp
    enc = _cfg("seamless-m4t-large-v2")
    i8e = _template_keys(enc, ShardingPlan(tp=1, kv_cache_dtype="int8"))
    assert i8e[("cross", "ckp")][1] == jnp.int8
    assert i8e[("cross", "cksp")] == ((1, 8, 4), jnp.float32)


# ---------------------------------------------------------------------------
# per-row quantizer units
# ---------------------------------------------------------------------------

def test_row_quant_roundtrip_and_zero_rows():
    rng = np.random.RandomState(0)
    x = rng.randn(5, 3, 4, 8).astype(np.float32)
    x[2] = 0.0                             # an all-zero row
    q, s = _row_quant(jnp.asarray(x))
    assert q.dtype == jnp.int8 and s.shape == (5, 3)
    back = np.asarray(q, np.float32) * np.asarray(s)[..., None, None]
    # error bounded by half a quantization step per row
    amax = np.abs(x).max(axis=(-2, -1), keepdims=True)
    assert np.all(np.abs(back - x) <= amax / 127.0 * 0.5 + 1e-7)
    assert np.all(np.asarray(q[2]) == 0) and np.all(np.asarray(s[2]) == 0)
    assert np.all(np.asarray(back[2]) == 0)     # zero rows dequant to zero
    # value-determinism: same row value -> same bytes, regardless of batch
    q1, s1 = _row_quant(jnp.asarray(x[1:2]))
    np.testing.assert_array_equal(np.asarray(q[1]), np.asarray(q1[0]))
    np.testing.assert_array_equal(np.asarray(s[1]), np.asarray(s1[0]))


# ---------------------------------------------------------------------------
# int8 read paths vs the dequant refs (pure JAX + Pallas interpret)
# ---------------------------------------------------------------------------

def _quantized_pool(rng, n_pages, H, psz, D):
    pool = rng.randint(-127, 128, (n_pages, H, psz, D)).astype(np.int8)
    scales = (np.abs(rng.randn(n_pages, psz)) * 0.02).astype(np.float32)
    return pool, scales


def _gather_ref(pool_f, bt):
    B, n_max = bt.shape
    n_pages, H, psz, D = pool_f.shape
    g = pool_f[bt.reshape(-1)].reshape(B, n_max, H, psz, D)
    return np.transpose(g, (0, 2, 1, 3, 4)).reshape(B, H, n_max * psz, D)


def test_gather_pages_dequant_matches_ref():
    from repro.core.attention import gather_pages_dequant
    from repro.kernels.ref import ref_dequant_pool
    rng = np.random.RandomState(0)
    kp, ks = _quantized_pool(rng, 9, 2, 4, 16)
    bt = np.stack([rng.permutation(np.arange(1, 9))[:4]
                   for _ in range(2)]).astype(np.int32)
    got = gather_pages_dequant(jnp.asarray(kp), jnp.asarray(ks),
                               jnp.asarray(bt), jnp.float32)
    want = _gather_ref(np.asarray(ref_dequant_pool(jnp.asarray(kp),
                                                   jnp.asarray(ks))), bt)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)


def test_pallas_paged_decode_kernel_int8():
    from repro.kernels.decode_attention import paged_decode_attention
    from repro.kernels.ref import ref_decode_attention, ref_dequant_pool
    rng = np.random.RandomState(1)
    B, H, D, psz, n_max = 3, 2, 32, 8, 4
    n_pages = B * n_max + 1
    kp, ks = _quantized_pool(rng, n_pages, H, psz, D)
    vp, vs = _quantized_pool(rng, n_pages, H, psz, D)
    bt = rng.permutation(np.arange(1, n_pages))[:B * n_max] \
        .reshape(B, n_max).astype(np.int32)
    lens = np.array([5, 30, 17], np.int32)
    q = rng.randn(B, H, D).astype(np.float32)
    out = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
        jnp.asarray(lens), interpret=True, k_scale=jnp.asarray(ks),
        v_scale=jnp.asarray(vs))
    kf = np.asarray(ref_dequant_pool(jnp.asarray(kp), jnp.asarray(ks)))
    vf = np.asarray(ref_dequant_pool(jnp.asarray(vp), jnp.asarray(vs)))
    expect = ref_decode_attention(jnp.asarray(q),
                                  jnp.asarray(_gather_ref(kf, bt)),
                                  jnp.asarray(_gather_ref(vf, bt)),
                                  jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_pallas_paged_verify_kernel_int8():
    from repro.kernels.decode_attention import paged_verify_attention
    from repro.kernels.ref import ref_dequant_pool, ref_verify_attention
    rng = np.random.RandomState(2)
    B, H, nq, D, psz, n_max = 2, 2, 5, 32, 8, 4
    n_pages = B * n_max + 1
    kp, ks = _quantized_pool(rng, n_pages, H, psz, D)
    vp, vs = _quantized_pool(rng, n_pages, H, psz, D)
    bt = rng.permutation(np.arange(1, n_pages))[:B * n_max] \
        .reshape(B, n_max).astype(np.int32)
    lens = np.array([9, 22], np.int32)
    q = rng.randn(B, H, nq, D).astype(np.float32)
    out = paged_verify_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
        jnp.asarray(lens), interpret=True, k_scale=jnp.asarray(ks),
        v_scale=jnp.asarray(vs))
    kf = np.asarray(ref_dequant_pool(jnp.asarray(kp), jnp.asarray(ks)))
    vf = np.asarray(ref_dequant_pool(jnp.asarray(vp), jnp.asarray(vs)))
    expect = ref_verify_attention(jnp.asarray(q),
                                  jnp.asarray(_gather_ref(kf, bt)),
                                  jnp.asarray(_gather_ref(vf, bt)),
                                  jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_pallas_ssd_scan_int8_state0():
    from repro.kernels.ref import ref_dequant_state, ref_ssd_scan
    from repro.kernels.ssd_scan import ssd_scan
    rng = np.random.RandomState(3)
    S, H, P, N = 64, 2, 8, 16
    x = rng.randn(S, H, P).astype(np.float32)
    dt = (np.abs(rng.randn(S, H)) * 0.1).astype(np.float32)
    Bm = rng.randn(S, N).astype(np.float32)
    Cm = rng.randn(S, N).astype(np.float32)
    A = -np.abs(rng.randn(H)).astype(np.float32)
    s0 = rng.randint(-127, 128, (H, P, N)).astype(np.int8)
    s0s = (np.abs(rng.randn(H)) * 0.02).astype(np.float32)
    y = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(Bm),
                 jnp.asarray(Cm), jnp.asarray(A), chunk=16, interpret=True,
                 state0=jnp.asarray(s0), state0_scale=jnp.asarray(s0s))
    want, _ = ref_ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(Bm),
                           jnp.asarray(Cm), jnp.asarray(A),
                           state0=ref_dequant_state(jnp.asarray(s0),
                                                    jnp.asarray(s0s)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # state0=None stays byte-compatible with the original entry point
    y0 = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(Bm),
                  jnp.asarray(Cm), jnp.asarray(A), chunk=16, interpret=True)
    w0, _ = ref_ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(Bm),
                         jnp.asarray(Cm), jnp.asarray(A))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(w0),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# engine level: int8 pools, greedy token-identity vs the fp oracle
# ---------------------------------------------------------------------------

def _run_engine(cfg, plan, params, mesh, prompts, *, max_new=6, frames=None,
                speculative=0):
    from repro.serving import Request, ServingEngine
    eng = ServingEngine.build_paged(cfg, plan, mesh, 2, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    speculative=speculative)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new,
                    frames=(frames[i % len(frames)] if frames else None))
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=3000)
    assert all(r.done for r in reqs)
    for rr, a in enumerate(eng.allocators):
        cached = eng.cross_caches[rr].n_cached_pages if eng.cross_caches \
            else 0
        assert a.n_free + cached == a.n_pages - a.n_reserved   # leak-free
    return {r.rid: tuple(r.out_tokens) for r in reqs}


@pytest.mark.slow
def test_int8_engine_greedy_identity_attention(mesh1):
    cfg = _cfg()
    params = model.init_params(cfg, PLAN_FP)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(2, cfg.vocab_size,
                           rng.randint(4, 20)).astype(np.int32)
               for _ in range(4)]
    ref = _run_engine(cfg, PLAN_FP, params, mesh1, prompts)
    got = _run_engine(cfg, ShardingPlan(tp=1, kv_cache_dtype="int8"),
                      params, mesh1, prompts)
    assert got == ref


@pytest.mark.slow
def test_int8_engine_greedy_identity_encdec(mesh1):
    cfg = _cfg("seamless-m4t-large-v2")
    params = model.init_params(cfg, PLAN_FP)
    rng = np.random.RandomState(1)
    frames = [rng.randn(cfg.enc_seq_len, cfg.d_model).astype(np.float32)
              for _ in range(2)]
    prompts = [rng.randint(2, cfg.vocab_size,
                           rng.randint(4, 16)).astype(np.int32)
               for _ in range(3)]
    ref = _run_engine(cfg, PLAN_FP, params, mesh1, prompts, frames=frames)
    got = _run_engine(cfg, ShardingPlan(tp=1, kv_cache_dtype="int8"),
                      params, mesh1, prompts, frames=frames)
    assert got == ref
