"""TP-equivalence runner (launched in a subprocess with 8 host devices).

Asserts that the paper's partitioning is *exact*: loss, gradients and decode
logits computed on a (data=2, model=4) mesh match the single-device
reference — including GQA kv-duplication, indivisible-head padding, MoE and
SSD sharding.  Run directly:  XLA flags are set below before jax imports.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core import model, steps  # noqa: E402
from repro.core.partition import ShardingPlan  # noqa: E402


def meshes():
    m1 = compat.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    m8 = compat.make_mesh((2, 4), ("data", "model"))
    return m1, m8


def run_case(name, **overrides):
    cfg = reduced(get_config(name), dtype="float32", **overrides)
    B, S = 4, 32
    shape = ShapeConfig("t", "train", S, B)
    m1, m8 = meshes()
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision_patches":
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_frontend_embeds, cfg.d_model), jnp.float32)

    losses, decs = [], []
    for mesh, tp in ((m1, 1), (m8, 4)):
        # moe_capacity=64: no token drops, so capacity rounding (a per-DP-shard
        # semantic, not a partitioning property) cannot differ between meshes.
        plan = ShardingPlan(tp=tp, moe_capacity=64.0)
        state = steps.init_train_state(cfg, plan)
        ts, _ = steps.make_train_step(cfg, plan, mesh, shape=shape)
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            st2, stats = jax.jit(ts)(state, batch)
        losses.append(float(stats["loss"]))
        # decode one token from an empty cache
        sshape = ShapeConfig("d", "decode", S, B)
        dec, _, _ = steps.make_decode_step(cfg, plan, mesh, sshape)
        cache = steps.zero_cache_for(cfg, plan, mesh, B, S)
        with mesh:
            lg, _ = jax.jit(dec)(state["params"], cache,
                                 tokens[:, :1], jnp.zeros((B,), jnp.int32))
        lg = np.asarray(jax.device_get(lg)).astype(np.float64)
        decs.append(lg[:, :cfg.vocab_size])

    dl = abs(losses[0] - losses[1])
    rel = dl / max(abs(losses[0]), 1e-9)
    dd = np.max(np.abs(decs[0] - decs[1]))
    ok = rel < 2e-4 and dd < 5e-2
    print(f"{name:25s} loss1={losses[0]:.6f} loss4={losses[1]:.6f} "
          f"rel={rel:.2e} max_dlogit={dd:.2e} {'OK' if ok else 'FAIL'}")
    return ok


def run_cp_case():
    """mamba2 under context parallelism (dp=2 x cp=4) == single device."""
    cfg = reduced(get_config("mamba2-370m"), dtype="float32")
    B, S = 4, 64
    shape = ShapeConfig("t", "train", S, B)
    m1, m8 = meshes()
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for mesh, plan in ((m1, ShardingPlan(tp=1)),
                       (m8, ShardingPlan(tp=1, cp_axes=("model",)))):
        state = steps.init_train_state(cfg, plan)
        ts, _ = steps.make_train_step(cfg, plan, mesh, shape=shape)
        with mesh:
            _, stats = jax.jit(ts)(state, batch)
        losses.append(float(stats["loss"]))
    rel = abs(losses[0] - losses[1]) / max(abs(losses[0]), 1e-9)
    ok = rel < 2e-5
    print(f"{'mamba2-370m (CP 2x4)':25s} loss1={losses[0]:.6f} "
          f"lossCP={losses[1]:.6f} rel={rel:.2e} {'OK' if ok else 'FAIL'}")
    return ok


def main():
    cases = [
        ("qwen3-0.6b", {}),                               # GQA + qk_norm
        ("gemma3-12b", {}),                               # local:global + sandwich
        ("mamba2-370m", {}),                              # SSD
        ("deepseek-moe-16b", {"n_experts": 8, "top_k": 2}),  # MoE (TP slicing)
        ("hymba-1.5b", {"n_heads": 6, "n_kv_heads": 2,
                        "n_layers": 2}),                  # hybrid + head padding
        ("mixtral-8x22b", {"n_experts": 2, "top_k": 1}),  # MoE n_exp < tp
        ("seamless-m4t-large-v2", {}),                    # enc-dec
        ("pixtral-12b", {}),                              # vlm splice
    ]
    ok = True
    for name, ov in cases:
        ok &= run_case(name, **ov)
    ok &= run_cp_case()
    print("ALL-OK" if ok else "SOME-FAILED")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
