"""Per-arch smoke tests: reduced config, one train step + decode on CPU,
asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import steps
from repro.core.partition import ShardingPlan

B, S = 2, 64
PLAN = ShardingPlan(tp=1)


def _batch(cfg, rng):
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.frontend == "vision_patches":
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_frontend_embeds, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ASSIGNED + PAPER_MODELS)
def test_train_step(name, mesh1):
    cfg = reduced(get_config(name))
    rng = np.random.RandomState(0)
    state = steps.init_train_state(cfg, PLAN)
    ts, _ = steps.make_train_step(cfg, PLAN, mesh1,
                                  shape=ShapeConfig("t", "train", S, B))
    with mesh1:
        state2, stats = jax.jit(ts)(state, _batch(cfg, rng))
    loss = float(stats["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    # params actually changed
    l0 = jax.tree_util.tree_leaves(state["params"])[1]
    l1 = jax.tree_util.tree_leaves(state2["params"])[1]
    assert l0.shape == l1.shape


@pytest.mark.parametrize("name", [n for n in ASSIGNED + PAPER_MODELS
                                  if get_config(n).has_decode])
def test_decode_step(name, mesh1):
    cfg = reduced(get_config(name))
    params = steps.init_train_state(cfg, PLAN)["params"]
    shape = ShapeConfig("d", "decode", S, B)
    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh1, shape)
    cache = steps.zero_cache_for(cfg, PLAN, mesh1, B, S)
    with mesh1:
        logits, cache2 = jax.jit(dec)(params, cache,
                                      jnp.zeros((B, 1), jnp.int32),
                                      jnp.zeros((B,), jnp.int32))
    assert logits.shape[0] == B
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_two_steps_decrease_loss_possible(mesh1):
    """A few steps on structured synthetic data should reduce loss."""
    from repro.data import DataConfig, PackedBatches
    cfg = reduced(get_config("tinyllama-42m"))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B)
    it = iter(PackedBatches(dc))
    state = steps.init_train_state(cfg, PLAN)
    from repro.optim import AdamWConfig
    ts, _ = steps.make_train_step(cfg, PLAN, mesh1,
                                  opt_cfg=AdamWConfig(lr=3e-3),
                                  shape=ShapeConfig("t", "train", S, B))
    jitted = jax.jit(ts)
    losses = []
    for _ in range(8):
        b = next(it)
        with mesh1:
            state, stats = jitted(state, {k: jnp.asarray(v)
                                          for k, v in b.items()})
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0]
