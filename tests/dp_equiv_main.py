"""dp-serving equivalence runner (launched in a subprocess, 2 host devices).

Asserts the replica-sharded page pool is *exact* on a real (data=2,
model=1) mesh: dp=2 serving — each data shard holding only its own
replica's pages — produces greedy outputs token-identical to the
single-device dp=1 oracle, with per-replica leak-freedom.  Run directly:
XLA flags are set below before jax imports.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=2")

import sys  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.core import model  # noqa: E402
from repro.core.partition import ShardingPlan  # noqa: E402
from repro.serving import Request, ServingEngine  # noqa: E402


def main():
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    plan = ShardingPlan(tp=1, kv_cache_dtype="float32")
    m1 = compat.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    m2 = compat.make_mesh((2, 1), ("data", "model"))
    params = model.init_params(cfg, plan)
    rng = np.random.RandomState(0)
    spec = [(rid,
             rng.randint(2, cfg.vocab_size,
                         int(rng.randint(4, 18))).astype(np.int32),
             int(rng.randint(2, 8))) for rid in range(8)]

    def run(mesh, dp):
        eng = ServingEngine.build_paged(
            cfg, plan, mesh, 2, 64, params, page_size=8, prefill_chunk=16,
            prefix_cache=True, dp=dp)
        rs = [Request(rid=r, prompt=p.copy(), max_new_tokens=m)
              for r, p, m in spec]
        for r in rs:
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert all(r.done for r in rs), [r.rid for r in rs if not r.done]
        return eng, {r.rid: tuple(r.out_tokens) for r in rs}

    _, oracle = run(m1, 1)
    eng, got = run(m2, 2)
    assert got == oracle, "dp=2 on a 2-device data mesh diverged from dp=1"
    assert eng.stats.replicas[0].routed > 0 and \
        eng.stats.replicas[1].routed > 0, "router used only one replica"
    for rr in range(2):
        a, c = eng.allocators[rr], eng.prefix_caches[rr]
        assert a.n_free + c.n_cached_pages == a.n_pages - a.n_reserved, \
            f"replica {rr} leaked pages"
    print("dp-equivalence OK: 2-device dp=2 == 1-device dp=1 oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main())
