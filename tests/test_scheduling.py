"""Scheduling subsystem: priority + aging admission, DRR fairness,
preemption (page donation to the prefix cache, requeue, resume-as-hit),
drain leak-freedom, the first-token emission fix, and schedule-invariance
properties — greedy outputs are token-identical across fcfs/priority/fair
and invariant to forced preemption points; sampled outputs are invariant
to admission order via per-request RNG streams."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import model, steps
from repro.core.kvcache import PageAllocator
from repro.core.partition import ShardingPlan
from repro.serving.policies import FairScheduler, PriorityScheduler
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.scheduler import FCFSScheduler, effective_prompt

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")
PSZ = 4


class _Req:
    def __init__(self, rid, prompt, max_new=4, priority=0, client_id=0):
        self.rid, self.prompt, self.max_new_tokens = rid, prompt, max_new
        self.priority, self.client_id = priority, client_id
        self.out_tokens = []


def toks(*ids):
    return np.asarray(ids, np.int32)


# ---------------------------------------------------------------------------
# priority: ordering + aging
# ---------------------------------------------------------------------------

def test_priority_admission_order():
    sched = PriorityScheduler(seq_budget=64)
    for rid, p in enumerate([0, 5, 1, 5]):
        sched.submit(_Req(rid, toks(1, 2, 3), priority=p))
    order = [a.req.rid for a in sched.plan([0, 1, 2, 3])]
    # descending priority; ties in submission order
    assert order == [1, 3, 2, 0]


def test_priority_aging_prevents_starvation():
    """A continuous high-priority stream must not starve a low-priority
    request: its aged effective priority eventually wins the round."""
    sched = PriorityScheduler(seq_budget=64, aging_rate=0.25)
    low = _Req(0, toks(1, 2, 3), priority=0)
    sched.submit(low)
    admitted_round = None
    for r in range(1, 100):
        sched.submit(_Req(100 + r, toks(4, 5, 6), priority=5))
        (adm,) = sched.plan([0])
        sched.on_finish(adm)
        if adm.req is low:
            admitted_round = r
            break
    assert admitted_round is not None and admitted_round <= 30

    # and with aging off, it starves forever
    sched0 = PriorityScheduler(seq_budget=64, aging_rate=0.0)
    low0 = _Req(0, toks(1, 2, 3), priority=0)
    sched0.submit(low0)
    for r in range(1, 100):
        sched0.submit(_Req(100 + r, toks(4, 5, 6), priority=5))
        (adm,) = sched0.plan([0])
        sched0.on_finish(adm)
        assert adm.req is not low0


# ---------------------------------------------------------------------------
# fairness: deficit round-robin
# ---------------------------------------------------------------------------

def test_fair_drr_interleaves_clients():
    """A flooding client shares the slot evenly with a light client."""
    sched = FairScheduler(seq_budget=64, quantum=16)
    for rid in range(6):                           # client 0 floods
        sched.submit(_Req(rid, toks(*range(8)), max_new=4, client_id=0))
    for rid in (10, 11):                           # client 1: two requests
        sched.submit(_Req(rid, toks(*range(8)), max_new=4, client_id=1))
    order = []
    while sched.has_pending():
        (adm,) = sched.plan([0])
        order.append(adm.req.rid)
        sched.on_finish(adm)
    # interleaved while both are backlogged, FIFO within each client
    assert order == [0, 10, 1, 11, 2, 3, 4, 5]


def test_fair_drr_charges_by_cost():
    """A client with 3x-heavier requests gets ~1/3 the admission rate."""
    sched = FairScheduler(seq_budget=64, quantum=12)
    for rid in range(4):                           # heavy: cost 36
        sched.submit(_Req(rid, toks(*range(30)), max_new=6, client_id=0))
    for rid in range(10, 22):                      # light: cost 12
        sched.submit(_Req(rid, toks(*range(8)), max_new=4, client_id=1))
    order = []
    while sched.has_pending():
        (adm,) = sched.plan([0])
        order.append(adm.req.rid)
        sched.on_finish(adm)
    # in any window where both clients are backlogged, the light client is
    # admitted ~3x as often: 9 light requests precede the 3rd heavy one
    assert sum(1 for r in order[:order.index(2)] if r >= 10) >= 8


# ---------------------------------------------------------------------------
# fairness: preemptive DRR (a running client must not starve a waiting one)
# ---------------------------------------------------------------------------

def test_fair_drr_preempts_long_running_client():
    """Client 0's long-running requests occupy every slot; client 1 arrives
    and accrues deficit until it evicts client 0's most recent admission.
    Without ``preemption=True`` the same setup never preempts (the ROADMAP
    starvation bug)."""
    for preemption in (True, False):
        sched = FairScheduler(seq_budget=64, quantum=16, preemption=preemption,
                              preempt_after=3)
        for rid in range(4):               # client 0 floods both slots
            sched.submit(_Req(rid, toks(*range(8)), max_new=8, client_id=0))
        adms = sched.plan([0, 1])
        assert [a.req.client_id for a in adms] == [0, 0]
        sched.submit(_Req(10, toks(*range(8)), max_new=8, client_id=1))
        victims = []
        for _ in range(20):                # no free slots: decode-only ticks
            victims = sched.plan_preemptions(adms, 0)
            if victims:
                break
        if not preemption:
            assert victims == []
            continue
        assert len(victims) == 1
        victim = victims[0]
        assert victim.req.client_id == 0
        # the most recently admitted of client 0's slots: least sunk work
        assert victim.seq == max(a.seq for a in adms)
        sched.on_preempt(victim, effective_prompt(victim.req)[:0])
        active = [a for a in adms if a is not victim]
        (adm1,) = sched.plan([victim.slot])
        assert adm1.req.client_id == 1     # the starved client gets the slot
        # no immediate ping-pong: client 0 was just served and client 1's
        # deficit was charged at admission, so the next tick evicts nobody
        assert sched.plan_preemptions(active + [adm1], 0) == []


def test_fair_drr_preemption_respects_free_slots():
    """A usable free slot serves the waiting client without eviction."""
    sched = FairScheduler(seq_budget=64, quantum=16, preemption=True,
                          preempt_after=1)
    sched.submit(_Req(0, toks(*range(8)), max_new=8, client_id=0))
    (adm,) = sched.plan([0, 1])
    sched.submit(_Req(1, toks(*range(8)), max_new=8, client_id=1))
    for _ in range(10):                    # slot 1 stays free throughout
        assert sched.plan_preemptions([adm], 1) == []


# ---------------------------------------------------------------------------
# preemption: victim choice, no ping-pong, page donation + resume-as-hit
# ---------------------------------------------------------------------------

def test_preemption_victim_choice_and_no_ping_pong():
    sched = PriorityScheduler(seq_budget=64, preemption=True)
    lo_a = _Req(0, toks(1, 2, 3), priority=1)
    lo_b = _Req(1, toks(4, 5, 6), priority=0)
    sched.submit(lo_a)
    sched.submit(lo_b)
    adms = sched.plan([0, 1])
    assert [a.req.rid for a in adms] == [0, 1]
    assert sched.plan_preemptions(adms, 0) == []   # nothing pending
    hi = _Req(2, toks(7, 8, 9), priority=5)
    sched.submit(hi)
    victims = sched.plan_preemptions(adms, 0)
    assert [v.req.rid for v in victims] == [1]     # lowest base priority
    sched.on_preempt(victims[0], effective_prompt(lo_b)[:0])
    (adm_hi,) = sched.plan([victims[0].slot])
    assert adm_hi.req is hi
    # the requeued victim (base 0) must NOT preempt back: active bases are
    # 1 and 5, both >= its own
    assert sched.plan_preemptions([adms[0], adm_hi], 0) == []
    # with a free slot available, pending work is served without eviction
    sched.submit(_Req(3, toks(1,), priority=9))
    assert sched.plan_preemptions([adms[0], adm_hi], 1) == []


def test_preemption_resets_victim_aging_no_ping_pong():
    """An aged-up victim must not out-rank the urgent request that
    displaced it: preemption resets its aging credit."""
    sched = PriorityScheduler(seq_budget=64, preemption=True, aging_rate=1.0)
    low = _Req(0, toks(1, 2, 3), priority=0)
    sched.submit(low)
    for _ in range(20):                  # age low well past priority 10
        sched.plan([])
    (adm_low,) = sched.plan([0])         # the aged request wins a FREE slot
    assert adm_low.req is low
    hi = _Req(1, toks(4, 5, 6), priority=10)
    sched.submit(hi)
    (victim,) = sched.plan_preemptions([adm_low], 0)
    assert victim.req is low
    sched.on_preempt(victim, effective_prompt(low)[:0])
    (adm_hi,) = sched.plan([0])          # the freed slot goes to hi...
    assert adm_hi.req is hi
    assert sched.plan_preemptions([adm_hi], 0) == []   # ...and stays there


def test_preemption_scans_past_aged_low_priority_head():
    """A fresh high-priority request behind an aged low-priority one in
    the pending order must still trigger preemption."""
    sched = PriorityScheduler(seq_budget=64, preemption=True, aging_rate=1.0)
    running = _Req(0, toks(1,), priority=0)
    sched.submit(running)
    (adm,) = sched.plan([0])
    aged = _Req(1, toks(2,), priority=0)
    sched.submit(aged)
    for _ in range(20):                  # aged's effective priority ~20
        sched.plan([])
    sched.submit(_Req(2, toks(3,), priority=10))
    (victim,) = sched.plan_preemptions([adm], 0)
    assert victim.req is running


def test_preemption_fires_under_page_pressure_despite_free_slot():
    """A free slot whose pool is exhausted must not suppress preemption —
    evicting the victim is what frees the pages."""
    alloc = PageAllocator(9)             # 8 usable
    sched = PriorityScheduler(seq_budget=32, allocator=alloc, page_size=PSZ,
                              prefix_cache=None, stats=None, preemption=True)
    low = _Req(0, toks(*range(16)), max_new=8, priority=0)   # 6 pages
    sched.submit(low)
    (adm,) = sched.plan([0, 1])
    assert len(adm.pages) == 6           # 2 pages left, slot 1 free
    hi = _Req(1, toks(*range(8)), max_new=8, priority=10)    # needs 4
    sched.submit(hi)
    (victim,) = sched.plan_preemptions([adm], 1)
    assert victim.req is low
    sched.on_preempt(victim, effective_prompt(low)[:0])
    (adm_hi,) = sched.plan([0, 1])       # low re-blocks; hi admitted
    assert adm_hi.req is hi and len(adm_hi.pages) == 4
    sched.on_finish(adm_hi)


def test_preemption_donates_pages_and_resumes_as_prefix_hit():
    alloc = PageAllocator(16)                      # 15 usable
    cache = RadixPrefixCache(alloc, PSZ)
    sched = PriorityScheduler(seq_budget=64, allocator=alloc, page_size=PSZ,
                              prefix_cache=cache, stats=None,
                              preemption=True)
    req = _Req(0, toks(*range(10, 18)), max_new=8)   # 8 + 8 -> 4 pages
    sched.submit(req)
    (adm,) = sched.plan([0])
    assert len(adm.pages) == 4 and adm.cached_len == 0
    sched.on_prefill_complete(adm)                 # prompt pages cached
    req.out_tokens = [91, 92, 93, 94, 95]          # decode progress: pos 12
    resident = effective_prompt(req)[:12]          # 3 full pages resident
    sched.on_preempt(adm, resident)
    # slot refs dropped; 3 pages survive cache-held, the partial tail freed
    assert alloc.n_free == 15 - 3
    assert cache.n_cached_pages == 3
    assert sched.has_pending()                     # requeued
    (adm2,) = sched.plan([0])
    assert adm2.req is req
    # resume is a prefix hit on the donated pages — prompt AND generated
    # KV reused, only the partial tail re-prefilled
    assert adm2.cached_len == 12 and adm2.cow is None
    assert adm2.pages[:3] == adm.pages[:3]
    sched.on_finish(adm2)
    cache.evict(10 ** 6)
    assert alloc.n_free == 15                      # leak-free


# ---------------------------------------------------------------------------
# randomized property: conservation + allocator leak-freedom under random
# admission, prefill completion, finish, and forced preemption, per policy
# ---------------------------------------------------------------------------

def _policies():
    return [
        ("fcfs", lambda **kw: FCFSScheduler(**kw)),
        ("priority", lambda **kw: PriorityScheduler(preemption=True, **kw)),
        ("fair", lambda **kw: FairScheduler(quantum=8, **kw)),
    ]


@pytest.mark.parametrize("name,mk", _policies(),
                         ids=[p[0] for p in _policies()])
def test_policies_conserve_requests_and_pages_randomized(name, mk):
    for seed in range(4):
        rng = np.random.RandomState(seed)
        alloc = PageAllocator(33)                  # 32 usable
        cache = RadixPrefixCache(alloc, PSZ)
        sched = mk(seq_budget=64, allocator=alloc, page_size=PSZ,
                   prefix_cache=cache, stats=None)
        reqs = [_Req(rid, toks(*rng.randint(2, 50, rng.randint(1, 13))),
                     max_new=int(rng.randint(1, 7)),
                     priority=int(rng.randint(0, 4)),
                     client_id=int(rng.randint(0, 3)))
                for rid in range(20)]
        for r in reqs:
            sched.submit(r)
        active, finished, preempts = {}, set(), 0
        for _step in range(5000):
            if len(finished) == len(reqs):
                break
            free = [s for s in range(3) if s not in active]
            for adm in sched.plan(free):
                if adm.cow is not None:            # engine copies, then:
                    sched.on_cow_done(adm)
                active[adm.slot] = [adm, False]    # prefill still pending
            for slot in list(active):
                adm, prefilled = active[slot]
                req = adm.req
                act = rng.rand()
                if act < 0.15 and preempts < 60:   # forced preemption
                    n = (len(req.prompt) + len(req.out_tokens) - 1
                         if prefilled and req.out_tokens else
                         int(rng.randint(0, len(req.prompt) + 1)))
                    sched.on_preempt(adm, effective_prompt(req)[:max(n, 0)])
                    del active[slot]
                    preempts += 1
                elif not prefilled:
                    sched.on_prefill_complete(adm)
                    active[slot][1] = True
                    req.out_tokens.append(int(rng.randint(2, 50)))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        sched.on_finish(adm)
                        finished.add(req.rid)
                        del active[slot]
                else:
                    req.out_tokens.append(int(rng.randint(2, 50)))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        sched.on_finish(adm)
                        finished.add(req.rid)
                        del active[slot]
        # conservation: every request finished exactly once, none lost
        # across preemptions/requeues
        assert finished == {r.rid for r in reqs}, (name, seed)
        # leak-freedom: every page is either free or cache-held
        assert alloc.n_free + cache.n_cached_pages == 32, (name, seed)
        cache.evict(10 ** 6)
        assert alloc.n_free == 32, (name, seed)


# ---------------------------------------------------------------------------
# engine level
# ---------------------------------------------------------------------------

def _reqs_mixed(cfg, n=7, seed=0):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    prios = [0, 3, 1, 0, 5, 2, 0]
    out = []
    for rid in range(n):
        L = int(rng.randint(4, 20))
        out.append(Request(rid=rid,
                           prompt=rng.randint(2, cfg.vocab_size,
                                              L).astype(np.int32),
                           max_new_tokens=int(rng.randint(2, 7)),
                           priority=prios[rid % len(prios)],
                           client_id=rid % 3))
    return out


def _run_paged(cfg, params, mesh1, scheduler=None, reqs=None, sampler=None,
               prefix_cache=False, slots=2):
    from repro.serving import ServingEngine
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, slots, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    prefix_cache=prefix_cache,
                                    scheduler=scheduler, sampler=sampler)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5000)
    return eng


@pytest.mark.slow
def test_greedy_token_identical_across_policies(mesh1):
    """fcfs / priority / fair reorder admissions, never tokens."""
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    outs = []
    for sched in (None,
                  lambda **kw: PriorityScheduler(**kw),
                  lambda **kw: FairScheduler(**kw)):
        reqs = _reqs_mixed(cfg)
        _run_paged(cfg, params, mesh1, scheduler=sched, reqs=reqs)
        assert all(r.done for r in reqs)
        outs.append({r.rid: tuple(r.out_tokens) for r in reqs})
    assert outs[0] == outs[1] == outs[2]


@pytest.mark.slow
def test_forced_preemption_identity_and_kv_reuse(mesh1):
    """A preempted-and-resumed request emits exactly the uncontended
    continuation, and its KV (prompt AND generated) is reused via the
    prefix cache, not recomputed."""
    from repro.serving import Request, ServingEngine

    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(5)
    p1 = rng.randint(2, cfg.vocab_size, 12).astype(np.int32)
    p2 = rng.randint(2, cfg.vocab_size, 20).astype(np.int32)

    def mk():
        return [Request(rid=0, prompt=p1.copy(), max_new_tokens=8),
                Request(rid=1, prompt=p2.copy(), max_new_tokens=4)]

    ref = mk()
    ref_eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 1, 64, params,
                                        page_size=8, prefill_chunk=8,
                                        prefix_cache=True)
    for r in ref:
        ref_eng.submit(r)
    ref_eng.run(max_ticks=5000)
    ref_out = {r.rid: tuple(r.out_tokens) for r in ref}

    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 1, 64, params,
                                    page_size=8, prefill_chunk=8,
                                    prefix_cache=True)
    r1, r2 = mk()
    eng.submit(r1)
    eng.submit(r2)
    # preempt r1 mid-decode, after its output spills into a generated page
    for _ in range(200):
        if len(r1.out_tokens) >= 6:
            break
        eng.tick()
    assert eng.admissions[0].req is r1 and not r1.done
    eng.preempt(0)
    # preempt r2 mid-prefill (its 20-token prompt spans 3 chunks)
    for _ in range(500):
        adm = eng.admissions[0]
        if adm is not None and adm.req is r2 and \
                eng.slot_state[0] == "prefill" and eng.prefill_done[0] > 0 \
                and not r2.done:
            break
        eng.tick()
    assert eng.admissions[0].req is r2
    eng.preempt(0)
    stats = eng.run(max_ticks=5000)
    assert r1.done and r2.done
    assert {0: tuple(r1.out_tokens), 1: tuple(r2.out_tokens)} == ref_out
    assert stats.preemptions == 2
    # r1 was preempted at pos 12+6-1=17 -> 2 full pages donated; resume
    # skipped at least those 16 tokens instead of recomputing them
    assert stats.prefill_tokens_skipped >= 16
    # leak-freedom: every page free or cache-held
    usable = eng.allocator.n_pages - eng.allocator.n_reserved
    assert eng.allocator.n_free + eng.prefix_cache.n_cached_pages == usable


@pytest.mark.slow
def test_sampled_outputs_schedule_invariant(mesh1):
    """Per-request RNG streams: non-greedy outputs are identical even when
    the policy reverses admission order."""
    from repro.serving import SamplerConfig
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    sampler = SamplerConfig(temperature=0.7, top_k=8)
    rng = np.random.RandomState(9)
    prompts = [rng.randint(2, cfg.vocab_size, int(rng.randint(4, 14))
                           ).astype(np.int32) for _ in range(5)]

    def mk(prio_by_rid):
        from repro.serving import Request
        return [Request(rid=i, prompt=p.copy(), max_new_tokens=5,
                        priority=prio_by_rid(i))
                for i, p in enumerate(prompts)]

    a = mk(lambda i: 0)                           # FCFS: submission order
    _run_paged(cfg, params, mesh1, reqs=a, sampler=sampler)
    b = mk(lambda i: i)                           # priority: reversed order
    _run_paged(cfg, params, mesh1,
               scheduler=lambda **kw: PriorityScheduler(**kw), reqs=b,
               sampler=sampler)
    assert {r.rid: tuple(r.out_tokens) for r in a} == \
           {r.rid: tuple(r.out_tokens) for r in b}


@pytest.mark.slow
def test_first_token_from_prefill_logits_and_exact_budget(mesh1):
    """The token sampled from the prompt's final logits is the first output
    token (not silently dropped), and max_new_tokens is exact."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("qwen3-0.6b"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    SB = 32
    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh1,
                                       ShapeConfig("ft_d", "decode", SB, 1))
    pre, _, _ = steps.make_prefill_step(cfg, PLAN, mesh1,
                                        ShapeConfig("ft_p", "decode", SB, 1))
    pre = jax.jit(pre)
    prompt = np.arange(2, 11, dtype=np.int32)
    lane = steps.zero_cache_for(cfg, PLAN, mesh1, 1, SB)
    with mesh1:
        logits, _ = pre(params, jnp.asarray(prompt[None]), lane)
    t0 = int(np.argmax(np.asarray(logits[0])[:cfg.vocab_size]))

    eng = ServingEngine(cfg, PLAN, mesh1, 1, SB, params, pre, jax.jit(dec))
    req = Request(rid=0, prompt=prompt, max_new_tokens=3)
    eng.submit(req)
    stats = eng.run(max_ticks=50)
    assert req.done
    assert req.out_tokens[0] == t0
    assert len(req.out_tokens) == 3               # exact, not off by one
    assert 0 in stats.request_ttft                # TTFT at prefill complete

    # a max_new_tokens=1 request completes at prefill, no decode tick needed
    req1 = Request(rid=1, prompt=prompt.copy(), max_new_tokens=1)
    eng.submit(req1)
    eng.run(max_ticks=50)
    assert req1.done and req1.out_tokens == [t0]


@pytest.mark.slow
def test_drain_releases_stranded_pages(mesh1):
    """run(max_ticks) exhaustion strands admitted slots; drain() routes
    them through on_finish and the allocator ends leak-free."""
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    for prefix_cache in (False, True):
        eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, params,
                                        page_size=8, prefill_chunk=16,
                                        prefix_cache=prefix_cache)
        rng = np.random.RandomState(0)
        reqs = [Request(rid=i,
                        prompt=rng.randint(2, cfg.vocab_size,
                                           12).astype(np.int32),
                        max_new_tokens=8) for i in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=2)                      # strands work mid-flight
        assert any(a is not None for a in eng.admissions)
        usable = eng.allocator.n_pages - eng.allocator.n_reserved
        assert eng.allocator.n_free < usable      # pages genuinely held
        n = eng.drain()
        assert n > 0 and all(a is None for a in eng.admissions)
        cached = eng.prefix_cache.n_cached_pages if prefix_cache else 0
        assert eng.allocator.n_free + cached == usable
