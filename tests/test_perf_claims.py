"""Regression guard for the EXPERIMENTS.md §Perf claims.

Reads the committed dry-run records under results_perf/ and asserts the
hillclimb improvements hold (so a regression in sharding, analytics or the
ledger shows up as a test failure, not silent doc rot)."""
import json
import os

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..", "results_perf")


def _load(name):
    path = os.path.join(ROOT, name + ".txt")
    if not os.path.exists(path):
        pytest.skip(f"{name} not present (dry-run artifacts not generated)")
    lines = [ln for ln in open(path) if ln.startswith("RESULT ")]
    assert lines, path
    rec = json.loads(lines[-1][len("RESULT "):])
    assert rec["status"] == "ok", rec
    return rec


def test_h1_int8_halves_decode_memory_term():
    base = _load("h1_base")["roofline"]
    opt = _load("h1_kv8_w8")["roofline"]
    assert opt["t_memory"] < 0.55 * base["t_memory"]
    # and sits near the bandwidth floor for int8 weights+KV
    floor = (7.7e9 + 5.9e9) / 819e9
    assert opt["t_memory"] < 1.10 * floor


def test_h1_int8_fits_closer_to_hbm():
    base = _load("h1_base")["memory"]["peak_est_bytes_per_device"]
    opt = _load("h1_kv8_w8")["memory"]["peak_est_bytes_per_device"]
    assert opt < 0.35 * base


def test_h2_selective_remat_cuts_compute():
    base = _load("h2_base")["roofline"]
    opt = _load("h2_split_sel")["roofline"]
    assert opt["t_compute"] < 0.82 * base["t_compute"]
    assert opt["mfu_upper_bound"] > 0.85


def test_h2_grad_accum_contains_memory():
    sel = _load("h2_split_sel")["memory"]["peak_est_bytes_per_device"]
    ga = _load("h2_split_sel_ga8")["memory"]["peak_est_bytes_per_device"]
    assert ga < 0.4 * sel


def test_h3_context_parallel_kills_collectives():
    base = _load("h3_base")["roofline"]
    cp = _load("h3_cp")["roofline"]
    cpb = _load("h3_cp_bf16")["roofline"]
    assert cp["t_collective"] < 0.15 * base["t_collective"]
    assert cpb["t_collective"] < 0.07 * base["t_collective"]
    assert cpb["bound"] == "compute"
    assert cpb["mfu_upper_bound"] > 0.8


def test_extra_moe_ep_halves_memory_term():
    ep = _load("x_deepseek_ep")["roofline"]
    assert ep["mfu_upper_bound"] > 0.45


def test_block_sync_contract_in_perf_records():
    """Even optimized variants keep the audited per-block sync structure."""
    rec = _load("h1_kv8_w8")
    # mistral-large: 88 layers x 2 syncs
    assert rec["block_syncs_per_step"] == 176


def test_zero1_cuts_peak_memory():
    ga = _load("h2_split_sel_ga8")["memory"]["peak_est_bytes_per_device"]
    z1 = _load("h2_final_z1")["memory"]["peak_est_bytes_per_device"]
    assert z1 < 0.85 * ga
