"""dp>1 serving: replica-sharded page pools, replica-local allocators /
prefix caches / schedulers, the request router (prefix affinity + least
page load), and the dp=2 engine's equivalence to the dp=1 oracle —
token-identical greedy outputs, per-replica conservation / leak-freedom
under forced preemption across all three policies, and replica-aware
drain."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import kvcache, model
from repro.core.kvcache import PageAllocator
from repro.core.partition import ShardingPlan, model_layout
from repro.serving.policies import FairScheduler, PriorityScheduler
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.router import Router
from repro.serving.scheduler import FCFSScheduler, effective_prompt

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")
PSZ = 4


class _Req:
    def __init__(self, rid, prompt, max_new=4, priority=0, client_id=0):
        self.rid, self.prompt, self.max_new_tokens = rid, prompt, max_new
        self.priority, self.client_id = priority, client_id
        self.out_tokens = []


def toks(*ids):
    return np.asarray(ids, np.int32)


# ---------------------------------------------------------------------------
# sharded template: the pool carries a replica dim on the data axes
# ---------------------------------------------------------------------------

def test_paged_template_shards_replicas_over_data_axes():
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    lay = model_layout(cfg, PLAN)
    tmpl = kvcache.paged_cache_template(cfg, PLAN, lay, n_pages=8,
                                        page_size=PSZ, n_replicas=2)
    trips = jax.tree_util.tree_leaves(
        tmpl, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and isinstance(x[0], tuple))
    assert trips
    for shape, _, spec in trips:
        # (reps, n_replicas, n_pages, G, psz, D), replicas on the dp axes
        assert shape[1] == 2 and shape[2] == 8
        assert tuple(spec)[1] == ("data",)


def test_fold_replica_pools_roundtrip():
    import jax.numpy as jnp
    pool = jnp.arange(2 * 3 * 4 * 5).reshape(1, 2, 3 * 4 * 5) \
        .reshape(1, 2, 3, 4, 5).astype(jnp.float32)
    folded = kvcache.fold_replica_pools(pool)
    assert folded.shape == (1, 6, 4, 5)
    # replica i's page p lands at folded id i*n_pages + p
    np.testing.assert_array_equal(np.asarray(folded[0, 3 + 2]),
                                  np.asarray(pool[0, 1, 2]))
    back = kvcache.unfold_replica_pools(folded, 2)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(pool))


# ---------------------------------------------------------------------------
# allocator: free() refuses shared pages (satellite bugfix)
# ---------------------------------------------------------------------------

def test_free_refuses_shared_pages():
    a = PageAllocator(8)
    pages = a.alloc(3)
    a.incref(pages)                       # now shared (e.g. prefix cache)
    with pytest.raises(AssertionError, match="decref"):
        a.free(pages)
    assert a.refcount(pages[0]) == 2      # nothing was dropped
    a.decref(pages)                       # the legitimate multi-ref release
    a.free(pages)                         # sole owner: fine
    assert a.n_free == 7


# ---------------------------------------------------------------------------
# router: prefix affinity first, then least page load
# ---------------------------------------------------------------------------

def _mk_replicas(n, n_pages=33, prefix=True, mk_sched=None):
    allocs = [PageAllocator(n_pages) for _ in range(n)]
    caches = [RadixPrefixCache(a, PSZ) if prefix else None for a in allocs]
    mk = mk_sched or (lambda **kw: FCFSScheduler(**kw))
    scheds = [mk(seq_budget=64, allocator=a, page_size=PSZ, prefix_cache=c,
                 stats=None) for a, c in zip(allocs, caches, strict=True)]
    return scheds, allocs, caches


def test_router_prefix_affinity_wins():
    scheds, allocs, caches = _mk_replicas(2)
    router = Router(scheds, allocs, caches, PSZ)
    # replica 1 holds an 8-token prefix; replica 0 is emptier
    pages = allocs[1].alloc(2)
    caches[1].insert(toks(*range(10, 18)), pages)
    allocs[1].decref(pages)               # cache-owned now
    req = _Req(0, toks(*range(10, 18), 99))
    assert router.route(req) == 1         # affinity beats load
    assert router.affinity_routed == 1
    # no affinity anywhere -> least loaded (replica 0: no cached pin,
    # but replica 1's cached pages are evictable so loads tie -> lowest idx)
    assert router.route(_Req(1, toks(7, 7, 7))) == 0


def test_router_least_loaded_counts_backlog_and_pins():
    scheds, allocs, caches = _mk_replicas(2)
    router = Router(scheds, allocs, caches, PSZ)
    # replica 0 gets a queued backlog; no prefix hits anywhere
    big = _Req(0, toks(*range(16)), max_new=8)        # 6 pages of demand
    scheds[0].submit(big)
    assert router.page_load(0) == 6 and router.page_load(1) == 0
    assert router.route(_Req(1, toks(1, 2, 3))) == 1
    # live-slot pins count too: admit on replica 1
    scheds[1].submit(_Req(2, toks(*range(8)), max_new=8))  # 4 pages
    (adm,) = scheds[1].plan([0])
    assert router.page_load(1) == 4
    scheds[1].on_finish(adm)
    assert router.page_load(1) == 0       # released pages drop the load


def test_router_sticky_resume_after_preemption():
    """A preempted request's donation lands in its own replica's cache, so
    re-routing it (hypothetically) would pick the same replica."""
    mk = lambda **kw: PriorityScheduler(preemption=True, **kw)  # noqa: E731
    scheds, allocs, caches = _mk_replicas(2, mk_sched=mk)
    router = Router(scheds, allocs, caches, PSZ)
    req = _Req(0, toks(*range(20, 28)), max_new=8)
    r = router.route(req)
    scheds[r].submit(req)
    (adm,) = scheds[r].plan([0])
    scheds[r].on_prefill_complete(adm)
    req.out_tokens = [1, 2, 3, 4]
    scheds[r].on_preempt(adm, effective_prompt(req)[:12])
    assert router.route(req) == r         # donated pages pull it back home


# ---------------------------------------------------------------------------
# randomized property: conservation + leak-freedom, dp x policy, with
# forced preemption — totals hold PER REPLICA
# ---------------------------------------------------------------------------

def _policies():
    return [
        ("fcfs", lambda **kw: FCFSScheduler(**kw)),
        ("priority", lambda **kw: PriorityScheduler(preemption=True, **kw)),
        ("fair", lambda **kw: FairScheduler(quantum=8, preemption=True,
                                            **kw)),
    ]


@pytest.mark.parametrize("dp", [1, 2])
@pytest.mark.parametrize("name,mk", _policies(),
                         ids=[p[0] for p in _policies()])
def test_dp_policies_conserve_requests_and_pages(name, mk, dp):
    for seed in range(3):
        rng = np.random.RandomState(seed)
        scheds, allocs, caches = _mk_replicas(dp, n_pages=33, mk_sched=mk)
        router = Router(scheds, allocs, caches, PSZ)
        reqs = [_Req(rid, toks(*rng.randint(2, 50, rng.randint(1, 13))),
                     max_new=int(rng.randint(1, 7)),
                     priority=int(rng.randint(0, 4)),
                     client_id=int(rng.randint(0, 3)))
                for rid in range(20)]
        homes = {}
        for r in reqs:
            homes[r.rid] = router.route(r)
            scheds[homes[r.rid]].submit(r)
        # slots are replica-local: 2 per replica
        active = {rr: {} for rr in range(dp)}
        finished, preempts = set(), 0
        for _step in range(5000):
            if len(finished) == len(reqs):
                break
            for rr in range(dp):
                sched, act = scheds[rr], active[rr]
                free = [s for s in range(2) if s not in act]
                for adm in sched.plan(free):
                    if adm.cow is not None:        # engine copies, then:
                        sched.on_cow_done(adm)
                    act[adm.slot] = [adm, False]
                for slot in list(act):
                    adm, prefilled = act[slot]
                    req = adm.req
                    if rng.rand() < 0.15 and preempts < 60:
                        n = (len(req.prompt) + len(req.out_tokens) - 1
                             if prefilled and req.out_tokens else
                             int(rng.randint(0, len(req.prompt) + 1)))
                        sched.on_preempt(adm,
                                         effective_prompt(req)[:max(n, 0)])
                        del act[slot]
                        preempts += 1
                        continue
                    if not prefilled:
                        sched.on_prefill_complete(adm)
                        act[slot][1] = True
                    req.out_tokens.append(int(rng.randint(2, 50)))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        sched.on_finish(adm)
                        finished.add(req.rid)
                        del act[slot]
        assert finished == {r.rid for r in reqs}, (name, dp, seed)
        # leak-freedom per replica: every page free or cache-held, and the
        # router's O(1) backlog counter drained to zero with the queues
        for rr in range(dp):
            assert not scheds[rr].has_pending()
            assert scheds[rr].backlog_pages == 0, (name, dp, seed, rr)
            assert allocs[rr].n_free + caches[rr].n_cached_pages == 32, \
                (name, dp, seed, rr)
            caches[rr].evict(10 ** 6)
            assert allocs[rr].n_free == 32, (name, dp, seed, rr)


# ---------------------------------------------------------------------------
# engine level: dp=2 == dp=1 oracle (token identity, affinity, drain)
# ---------------------------------------------------------------------------

def _mixed_requests(cfg, n=10, seed=0, shared_prefix=0):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    shared = rng.randint(2, cfg.vocab_size, shared_prefix).astype(np.int32)
    out = []
    for rid in range(n):
        L = int(rng.randint(4, 16))
        p = rng.randint(2, cfg.vocab_size, L).astype(np.int32)
        out.append(Request(rid=rid,
                           prompt=np.concatenate([shared, p]),
                           max_new_tokens=int(rng.randint(2, 7)),
                           priority=int(rng.randint(0, 3)),
                           client_id=rid % 2))
    return out


def _run_engine(cfg, params, mesh1, dp, reqs, scheduler=None,
                prefix_cache=True, max_ticks=5000, plan=PLAN):
    from repro.serving import ServingEngine
    eng = ServingEngine.build_paged(cfg, plan, mesh1, 2, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    prefix_cache=prefix_cache,
                                    scheduler=scheduler, dp=dp)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=max_ticks)
    return eng


@pytest.mark.slow
@pytest.mark.parametrize("mk_sched", [
    None,
    lambda **kw: PriorityScheduler(preemption=True, **kw),
    lambda **kw: FairScheduler(preemption=True, **kw)],
    ids=["fcfs", "priority", "fair"])
def test_dp2_greedy_token_identical_to_dp1_oracle(mesh1, mk_sched):
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    ref = _mixed_requests(cfg)
    _run_engine(cfg, params, mesh1, 1, ref, scheduler=None)
    assert all(r.done for r in ref)
    got = _mixed_requests(cfg)
    eng = _run_engine(cfg, params, mesh1, 2, got, scheduler=mk_sched)
    assert all(r.done for r in got)
    assert {r.rid: tuple(r.out_tokens) for r in got} == \
           {r.rid: tuple(r.out_tokens) for r in ref}
    assert {r.replica for r in got} == {0, 1}      # both replicas used
    # per-replica leak-freedom after a full run
    for rr in range(2):
        a, c = eng.allocators[rr], eng.prefix_caches[rr]
        assert a.n_free + c.n_cached_pages == a.n_pages - a.n_reserved, rr


@pytest.mark.slow
def test_dp2_prefix_affinity_routes_shared_prefix_together(mesh1):
    """On a shared-system-prompt workload, once one replica owns the
    prefix every later request follows it there (nonzero hit rate), while
    distinct-prefix requests still spread over both replicas."""
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    reqs = _mixed_requests(cfg, n=8, shared_prefix=16)
    eng = _run_engine(cfg, params, mesh1, 2, reqs)
    assert all(r.done for r in reqs)
    # the first request seeds one replica's cache; everyone else follows
    home = reqs[0].replica
    followers = [r for r in reqs if r.replica == home]
    assert len(followers) >= len(reqs) - 1
    rs = eng.stats.replicas[home]
    assert rs.prefix_hits > 0 and rs.prefix_hit_rate > 0
    assert eng.router.affinity_routed > 0
    assert eng.stats.prefill_tokens_skipped > 0


@pytest.mark.slow
def test_dp2_drain_releases_both_replicas(mesh1):
    from repro.serving import Request, ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    prefix_cache=True, dp=2)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(2, cfg.vocab_size,
                                              12).astype(np.int32),
                    max_new_tokens=8) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=2)                      # strands work mid-flight
    assert any(a is not None for a in eng.admissions)
    n = eng.drain()
    assert n > 0 and all(a is None for a in eng.admissions)
    for rr in range(2):
        a, c = eng.allocators[rr], eng.prefix_caches[rr]
        assert a.n_free + c.n_cached_pages == a.n_pages - a.n_reserved, rr


def test_dp_requires_paged_and_factory(mesh1):
    import jax
    from repro.configs.base import ShapeConfig
    from repro.core import steps
    from repro.serving import ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    dshape = ShapeConfig("dp_d", "decode", 32, 2)
    pshape = ShapeConfig("dp_p", "decode", 32, 1)
    dec, _, _ = steps.make_decode_step(cfg, PLAN, mesh1, dshape)
    pre, _, _ = steps.make_prefill_step(cfg, PLAN, mesh1, pshape)
    with pytest.raises(AssertionError, match="paged"):
        ServingEngine(cfg, PLAN, mesh1, 2, 32, params, jax.jit(pre),
                      jax.jit(dec), dp=2)


@pytest.mark.slow
def test_dp2_equivalence_on_real_data_mesh_subprocess():
    """dp=2 on a REAL (data=2, model=1) mesh — each device holding only its
    replica's pages — matches the 1-device dp=1 oracle token for token.
    Runs tests/dp_equiv_main.py under 2 host devices."""
    import os
    import subprocess
    import sys
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable,
                        os.path.join(root, "tests", "dp_equiv_main.py")],
                       capture_output=True, text=True, env=env,
                       timeout=1200)
    assert "dp-equivalence OK" in r.stdout, \
        r.stdout[-3000:] + r.stderr[-2000:]


def test_n_replicas_must_cover_data_extent():
    from repro.core import steps as _steps

    class _FakeMesh:
        axis_names = ("data", "model")
        devices = np.zeros((2, 1))
    with pytest.raises(AssertionError, match="multiple"):
        _steps.n_replicas_local(_FakeMesh(), PLAN, 3)
    assert _steps.n_replicas_local(_FakeMesh(), PLAN, 4) == 2


# ---------------------------------------------------------------------------
# quantized pools: dp equivalence and scale-tensor hygiene
# ---------------------------------------------------------------------------

PLAN_I8 = ShardingPlan(tp=1, kv_cache_dtype="int8")


def _assert_scale_hygiene(eng):
    """Every free page either awaits its scale reset (``_scale_dirty``) or
    its device scale rows are exactly zero — a recycled page can never pair
    stale scales with fresh payloads."""
    for rr in range(eng.R):
        a = eng.allocators[rr]
        clean = sorted(a._free_set - a._scale_dirty)
        if not clean:
            continue
        idx = np.asarray(clean, np.int32)
        for pat in eng.cache:
            for d in pat:
                for kind in ("kv", "cross"):
                    leaves = d.get(kind)
                    if not isinstance(leaves, dict):
                        continue
                    for kk, vv in leaves.items():
                        if kk.endswith("sp"):
                            rows = np.asarray(vv[:, rr, idx])
                            assert not rows.any(), (rr, kk, clean)


@pytest.mark.slow
def test_dp2_int8_greedy_token_identical_to_fp_oracle(mesh1):
    """int8 pools under dp: per-row quantization is value-deterministic,
    so routing/interleaving differences between dp=1 and dp=2 cannot
    change any page's bytes — greedy outputs match the fp dp=1 oracle."""
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    ref = _mixed_requests(cfg)
    _run_engine(cfg, params, mesh1, 1, ref)
    assert all(r.done for r in ref)
    want = {r.rid: tuple(r.out_tokens) for r in ref}

    got1 = _mixed_requests(cfg)
    eng1 = _run_engine(cfg, params, mesh1, 1, got1, plan=PLAN_I8)
    assert all(r.done for r in got1)
    assert {r.rid: tuple(r.out_tokens) for r in got1} == want
    _assert_scale_hygiene(eng1)

    got2 = _mixed_requests(cfg)
    eng2 = _run_engine(
        cfg, params, mesh1, 2, got2, plan=PLAN_I8,
        scheduler=lambda **kw: PriorityScheduler(preemption=True, **kw))
    assert all(r.done for r in got2)
    assert {r.rid: tuple(r.out_tokens) for r in got2} == want
    assert {r.replica for r in got2} == {0, 1}
    for rr in range(2):
        a, c = eng2.allocators[rr], eng2.prefix_caches[rr]
        assert a.n_free + c.n_cached_pages == a.n_pages - a.n_reserved, rr
    _assert_scale_hygiene(eng2)


@pytest.mark.slow
def test_dp2_int8_randomized_preemption_scale_hygiene(mesh1):
    """Randomized churn (tight pool, forced preemptions) with int8 pools:
    page conservation holds per replica AND the scale side tensors stay
    hygienic — at every checkpoint each free page is either queued for its
    reset or already zeroed on device."""
    from repro.serving import ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    eng = ServingEngine.build_paged(
        cfg, PLAN_I8, mesh1, 2, 64, params, page_size=8, prefill_chunk=16,
        n_pages=17, prefix_cache=True, dp=2,
        scheduler=lambda **kw: PriorityScheduler(preemption=True, **kw))
    reqs = _mixed_requests(cfg, n=12, seed=5)
    for r in reqs:
        eng.submit(r)
    rng = np.random.RandomState(7)
    tick = 0
    while (eng.has_pending() or
           any(a is not None for a in eng.admissions)) and tick < 2000:
        if tick % 7 == 3:                       # forced preemption churn
            occ = [b for b in range(eng.B) if eng.admissions[b] is not None]
            if occ:
                eng.preempt(int(rng.choice(occ)))
        eng.tick()
        tick += 1
        if tick % 25 == 0:
            _assert_scale_hygiene(eng)
    assert all(r.done for r in reqs)
    for rr in range(2):
        a, c = eng.allocators[rr], eng.prefix_caches[rr]
        assert a.n_free + c.n_cached_pages == a.n_pages - a.n_reserved, rr
    _assert_scale_hygiene(eng)
