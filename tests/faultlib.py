"""Deterministic fault injection for elastic serving tests.

``FaultPlan`` is a seeded schedule of membership events — scale-down,
scale-up, replica crash — applied through ``engine.membership_hook``,
which fires at the top of every paged tick (where ``scale_to`` /
``kill_replica`` barrier the pipeline first), so a schedule replays
exactly from its seed regardless of overlap mode or policy.

``inject_transfer_fault`` wraps the engine's compiled page-transfer step
with a shim that raises BEFORE invoking it.  That ordering is the whole
point: the compiled step donates the cache buffer, so a fault injected
*after* entry could not leave the engine a usable cache to roll back to —
raising first models a replica dying between the migration *plan* (the
destination admission is claimed) and the device copy, the exact window
the engine's rollback arm must cover.
"""
from typing import List, Tuple

import numpy as np


class TransferFault(RuntimeError):
    """Injected failure of a cross-replica page transfer."""


class FaultPlan:
    """Seeded membership-event schedule driven by the engine's tick clock.

    Events are ``(tick, kind, value)`` with kind ``"scale"`` (value = the
    target replica count) or ``"kill"`` (value mod the live replica count
    picks the victim).  Events that cannot apply when their tick arrives —
    scaling to the current width, killing the last replica — are skipped,
    so random schedules never need pre-validation.  ``applied`` records
    what actually fired, for assertions."""

    def __init__(self, events: List[Tuple[int, str, int]]):
        self.events = sorted(events)
        self.applied: List[Tuple[int, str, int]] = []

    @classmethod
    def random(cls, rng: np.random.RandomState, first_tick: int = 2,
               last_tick: int = 16, max_events: int = 3,
               dp_choices=(1, 2, 3)) -> "FaultPlan":
        n = int(rng.randint(1, max_events + 1))
        ticks = sorted(int(t) for t in
                       rng.randint(first_tick, last_tick + 1, n))
        events = []
        for t in ticks:
            if rng.randint(3) == 0:
                events.append((t, "kill", int(rng.randint(8))))
            else:
                events.append((t, "scale",
                               int(dp_choices[rng.randint(
                                   len(dp_choices))])))
        return cls(events)

    def install(self, engine):
        pending = list(self.events)

        def hook(e):
            while pending and e.stats.ticks >= pending[0][0]:
                tick, kind, val = pending.pop(0)
                if kind == "scale":
                    if val != e.R:
                        e.scale_to(val)
                        self.applied.append((tick, kind, val))
                elif e.R >= 2:
                    r = val % e.R
                    e.kill_replica(r)
                    self.applied.append((tick, kind, r))

        engine.membership_hook = hook
        return self


def inject_transfer_fault(engine, fail_calls=(1,)):
    """Replace ``engine.transfer_fn`` with a shim that raises
    ``TransferFault`` on the given (1-based) call numbers, BEFORE the
    compiled step runs — the donated cache buffer is never consumed, so
    the engine's rollback path sees fully intact state.  -> a state dict
    with ``calls`` / ``faults`` counters.  ``engine._wire_steps()``
    restores the real compiled step (membership changes do this
    implicitly)."""
    real = engine.transfer_fn
    fail = set(fail_calls)
    state = {"calls": 0, "faults": 0}

    def shim(*args, **kwargs):
        state["calls"] += 1
        if state["calls"] in fail:
            state["faults"] += 1
            raise TransferFault(
                f"injected fault on transfer call {state['calls']}")
        return real(*args, **kwargs)

    engine.transfer_fn = shim
    return state
