"""Speculative decoding: prompt-lookup draft-source edge cases, the
allocator trim path for partially rejected drafts (shared tail pages are
decref'd, never assert-freed), scheduler draft-headroom budgeting, and
engine-level identity under an empty draft corpus, drafts crossing page
boundaries, forced preemption mid-decode, and page-budget exhaustion
(speculation denied but the request still admitted)."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import model
from repro.core.kvcache import PageAllocator, pages_needed
from repro.core.partition import ShardingPlan
from repro.serving import Request, ServingEngine
from repro.serving.prefix_cache import PromptLookupDraft, RadixPrefixCache
from repro.serving.scheduler import FCFSScheduler

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")


def _cfg():
    return reduced(get_config("tinyllama-42m"), dtype="float32")


def toks(*ids):
    return np.asarray(ids, np.int32)


# ---------------------------------------------------------------------------
# draft source: prompt lookup over context + radix-cache corpus
# ---------------------------------------------------------------------------

def test_prompt_lookup_in_context_ngram():
    d = PromptLookupDraft()
    # trailing trigram [1,2,3] recurs at the start; continuation follows it
    assert d.draft([1, 2, 3, 9, 8, 7, 1, 2, 3], 2) == [9, 8]
    # k clips to what actually follows the match
    assert d.draft([1, 2, 3, 9, 1, 2, 3], 8) == [9, 1, 2, 3]
    # most recent (rightmost) match wins
    assert d.draft([5, 6, 1, 5, 6, 2, 5, 6], 1) == [2]


def test_prompt_lookup_falls_back_to_cache_paths():
    a = PageAllocator(8)
    cache = RadixPrefixCache(a, 4)
    pages = a.alloc(2)
    cache.insert(toks(5, 6, 7, 8, 4, 4, 4, 4), pages)
    a.decref(pages)                       # cache-owned
    d = PromptLookupDraft(cache)
    # no in-context repeat of [9, 5, 6, 7]'s tail; the cached path has it
    assert d.draft([9, 9, 5, 6, 7], 3) == [8, 4, 4]


def test_prompt_lookup_empty_cases():
    d = PromptLookupDraft()
    assert d.draft([], 4) == []           # no context at all
    assert d.draft([1], 4) == []          # too short for any n-gram
    assert d.draft([1, 2, 3, 4], 0) == []   # k = 0
    assert d.draft([1, 2, 3, 4], 4) == []   # distinct tokens: no repeat
    # fresh (empty) radix cache adds nothing
    fresh = PromptLookupDraft(RadixPrefixCache(PageAllocator(4), 4))
    assert fresh.draft([1, 2, 3, 4], 4) == []


# ---------------------------------------------------------------------------
# allocator: trim decrefs (satellite bugfix) — tail pages of a partially
# rejected draft may be shared with the prefix cache
# ---------------------------------------------------------------------------

def test_trim_releases_shared_tail_without_freeing():
    a = PageAllocator(8)
    pages = a.alloc(4)
    a.incref(pages[2:])                   # tail shared (prefix cache ref)
    # free() on the shared tail would be a refcount-corrupting bug
    with pytest.raises(AssertionError, match="decref"):
        a.free(pages[2:])
    a.trim(pages[2:])                     # slot's own ref drops cleanly
    assert a.refcount(pages[2]) == 1      # cache still holds the pages
    assert a.n_free == 3                  # nothing returned to the pool yet
    a.trim(pages[:2])                     # sole-owner tail actually frees
    assert a.n_free == 5
    a.decref(pages[2:])                   # cache lets go -> fully reclaimed
    assert a.n_free == 7


def test_free_decref_trim_mark_scale_rows_dirty():
    """Quantized-pool invariant (satellite bugfix): every release path —
    free, decref, spec-decode trim — marks the page so its per-(page, slot)
    scale rows are invalidated before reuse; shared pages are only marked
    once the LAST reference drops (a live reader must keep its scales)."""
    a = PageAllocator(12)
    p_free = a.alloc(2)
    p_trim = a.alloc(2)
    p_shared = a.alloc(2)
    a.incref(p_shared)
    assert a.take_scale_dirty() == []      # nothing released yet
    a.free(p_free)
    a.trim(p_trim)
    a.decref(p_shared)                     # rc 2 -> 1: still live
    assert a.take_scale_dirty() == sorted(p_free + p_trim)
    assert a.take_scale_dirty() == []      # drained exactly once
    a.decref(p_shared)                     # last ref drops
    assert a.take_scale_dirty() == sorted(p_shared)
    # a dirty page re-allocated before the drain stays marked (not yet
    # reset) but is NOT returned while live — it resurfaces when freed
    p = a.alloc(1)
    a.free(p)
    p2 = a.alloc(1)
    assert p2 == p and a.take_scale_dirty() == []
    a.free(p2)
    assert a.take_scale_dirty() == sorted(p)


def test_scheduler_spec_headroom_and_trim():
    a = PageAllocator(32)
    s = FCFSScheduler(seq_budget=32, allocator=a, page_size=4,
                      spec_tokens=4)
    req = Request(rid=0, prompt=toks(*range(2, 10)), max_new_tokens=8)
    s.submit(req)
    (adm,) = s.plan([0])
    # 8 prompt + 8 new = 4 pages, +4 draft tokens of coverage = 5 pages
    assert adm.spec and len(adm.pages) == pages_needed(8 + 8 + 4, 4)
    free_before = a.n_free
    keep = pages_needed(8 + 8, 4)
    s.on_spec_trim(adm, keep)
    assert not adm.spec and len(adm.pages) == keep
    assert a.n_free == free_before + 1    # the headroom page came back
    s.on_finish(adm)
    assert a.n_free == 31


def test_scheduler_denies_spec_but_still_admits():
    base = pages_needed(8 + 8, 4)
    a = PageAllocator(base + 1)           # exactly base demand (+scratch)
    s = FCFSScheduler(seq_budget=32, allocator=a, page_size=4,
                      spec_tokens=4)
    req = Request(rid=0, prompt=toks(*range(2, 10)), max_new_tokens=8)
    s.submit(req)
    (adm,) = s.plan([0])                  # all-or-nothing extra alloc fails
    assert adm is not None and not adm.spec
    assert len(adm.pages) == base and a.n_free == 0
    s.on_finish(adm)
    assert a.n_free == base


# ---------------------------------------------------------------------------
# engine level: identity against the one-token engine across edge cases
# ---------------------------------------------------------------------------

def _repetitive_prompts(cfg, n=4, seed=11):
    """Shared prefix + tiled motifs: the traffic prompt lookup drafts on."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(2, cfg.vocab_size, 8).astype(np.int32)
    out = []
    for i in range(n):
        motif = rng.randint(2, cfg.vocab_size, 3 + i % 2).astype(np.int32)
        body = np.tile(motif, 4)[: 8 + 2 * (i % 3)]
        out.append(np.concatenate([shared, body]).astype(np.int32))
    return out


def _run(cfg, params, mesh, prompts, *, speculative, max_new=10, slots=2,
         SB=64, page_size=8, n_pages=0, prefix_cache=True, preempt_at=()):
    eng = ServingEngine.build_paged(
        cfg, PLAN, mesh, slots, SB, params, page_size=page_size,
        prefill_chunk=8, n_pages=n_pages, prefix_cache=prefix_cache,
        speculative=speculative)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    tick = 0
    while (eng.has_pending() or
           any(a is not None for a in eng.admissions)) and tick < 3000:
        if tick in preempt_at:
            for b in range(eng.B):
                if eng.admissions[b] is not None:
                    eng.preempt(b)
                    break
        eng.tick()
        tick += 1
    assert all(r.done for r in reqs), \
        [r.rid for r in reqs if not r.done]
    # page accounting: everything free or cache-held, per replica
    for rr in range(eng.R):
        a = eng.allocators[rr]
        cached = (eng.prefix_caches[rr].n_cached_pages
                  if eng.prefix_caches[rr] is not None else 0)
        assert a.n_free + cached == a.n_pages - a.n_reserved, rr
    return {r.rid: tuple(r.out_tokens) for r in reqs}, eng.stats


@pytest.mark.slow
def test_empty_draft_corpus_falls_back_to_one_token(mesh1):
    """Distinct non-repetitive prompts: prompt lookup finds nothing, every
    tick falls through to the plain one-token step, outputs identical."""
    cfg = _cfg()
    params = model.init_params(cfg, PLAN)
    rng = np.random.RandomState(4)
    # sampled WITHOUT replacement: no token ever repeats inside a prompt
    prompts = [rng.choice(np.arange(2, cfg.vocab_size), size=9,
                          replace=False).astype(np.int32)
               for _ in range(3)]
    ref, _ = _run(cfg, params, mesh1, prompts, speculative=0, max_new=4)
    got, st = _run(cfg, params, mesh1, prompts, speculative=4, max_new=4)
    assert got == ref
    # the lookups that did run came back empty (the prompts are unique
    # token sets; greedy continuations could in principle loop, so only
    # the prompt-driven early ticks are asserted draft-free)
    assert st.spec_draft_lookups > 0


@pytest.mark.slow
def test_draft_crossing_page_boundary_identity(mesh1):
    """Small pages force accepted drafts to straddle page boundaries; the
    verify write path must land KV in the right pages."""
    cfg = _cfg()
    params = model.init_params(cfg, PLAN)
    prompts = _repetitive_prompts(cfg)
    ref, _ = _run(cfg, params, mesh1, prompts, speculative=0, page_size=4,
                  max_new=12)
    got, st = _run(cfg, params, mesh1, prompts, speculative=4, page_size=4,
                   max_new=12)
    assert got == ref
    # with 4-token pages and 12 new tokens, accepted k>1 bursts must have
    # crossed page boundaries; vacuous acceptance would hide the bug
    assert st.spec_accepted > 0, "no draft token was ever accepted"


@pytest.mark.slow
def test_forced_preemption_mid_decode_identity(mesh1):
    """Preempting slots between ticks (including between verify steps)
    leaves outputs identical to the undisturbed one-token oracle: resume
    re-prefills only accepted tokens, never speculative tail KV."""
    cfg = _cfg()
    params = model.init_params(cfg, PLAN)
    prompts = _repetitive_prompts(cfg, n=3)
    ref, _ = _run(cfg, params, mesh1, prompts, speculative=0)
    for pts in ({4}, {6}, {4, 5, 6}):
        got, st = _run(cfg, params, mesh1, prompts, speculative=4,
                       preempt_at=pts)
        assert got == ref, pts
        assert st.preemptions == len(pts)


@pytest.mark.slow
def test_page_exhaustion_denies_spec_but_serves(mesh1):
    """A pool with zero headroom beyond base demand: speculation is denied
    at admission (all-or-nothing), requests still run to completion on the
    one-token path, outputs identical."""
    cfg = _cfg()
    params = model.init_params(cfg, PLAN)
    max_new, psz = 8, 8
    # equal-length prompts whose base demand (prompt + max_new = 24 tokens)
    # fills whole pages exactly: after the base alloc the pool is empty, so
    # the all-or-nothing draft-headroom alloc must fail every admission
    rng = np.random.RandomState(11)
    shared = rng.randint(2, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([
        shared, np.tile(rng.randint(2, cfg.vocab_size, 4), 2)]
        ).astype(np.int32) for _ in range(2)]
    assert all(len(p) == 16 for p in prompts)
    base = pages_needed(16 + max_new, psz)
    n_pages = base + 1                    # one slot's base demand + scratch
    ref, _ = _run(cfg, params, mesh1, prompts, speculative=0, slots=1,
                  n_pages=n_pages, prefix_cache=False, max_new=max_new)
    got, st = _run(cfg, params, mesh1, prompts, speculative=4, slots=1,
                   n_pages=n_pages, prefix_cache=False, max_new=max_new)
    assert got == ref
    assert st.spec_denied > 0             # every admission denied headroom
    assert st.spec_steps == 0             # and no verify tick ever ran
