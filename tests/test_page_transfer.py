"""The page-transfer primitive and prefill/decode disaggregation:
``kvcache.handoff_refs`` refcount handoff (source decref exactly once,
destination freshly owned), ``core.steps.make_page_transfer_step``
byte-identity for int8 payloads + scale rows, forced preemption of a slot
queued for handoff, and the dp=2 disaggregated engine's token identity
against the dp=1 serial oracle."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import kvcache, model
from repro.core import steps as _steps
from repro.core.kvcache import PageAllocator, handoff_refs
from repro.core.partition import ShardingPlan

PLAN = ShardingPlan(tp=1, kv_cache_dtype="float32")
PLAN_I8 = ShardingPlan(tp=1, kv_cache_dtype="int8")


# ---------------------------------------------------------------------------
# refcount handoff
# ---------------------------------------------------------------------------

def test_handoff_refs_decrefs_source_once():
    src, dst = PageAllocator(8), PageAllocator(8)
    pages = src.alloc(3)
    src.incref(pages[:2])              # first two shared (prefix cache)
    fresh = dst.alloc(3)
    handoff_refs(src, pages, dst, fresh)
    # source dropped exactly ONE ref per page: shared pages stay resident
    # for the cache, the private tail page frees
    assert src.refcount(pages[0]) == 1
    assert src.refcount(pages[1]) == 1
    assert src.refcount(pages[2]) == 0
    assert src.n_free == 8 - 1 - 2     # scratch reserved + 2 cache-held
    # destination ownership is exactly the fresh allocation
    assert all(dst.refcount(p) == 1 for p in fresh)
    assert src.pages_transferred_out == 3
    assert dst.pages_transferred_in == 3
    dst.decref(fresh)
    assert dst.n_free == 8 - 1


def test_handoff_refs_rejects_shared_destination():
    src, dst = PageAllocator(8), PageAllocator(8)
    pages = src.alloc(2)
    shared = dst.alloc(2)
    dst.incref(shared)                 # destination pages NOT freshly owned
    with pytest.raises(AssertionError, match="freshly allocated"):
        handoff_refs(src, pages, dst, shared)
    # nothing moved: the source still owns its run
    assert all(src.refcount(p) == 1 for p in pages)
    assert src.pages_transferred_out == 0


def test_handoff_refs_rejects_same_allocator_and_length_mismatch():
    a, b = PageAllocator(8), PageAllocator(8)
    pages = a.alloc(2)
    with pytest.raises(AssertionError, match="within one replica"):
        handoff_refs(a, pages, a, pages)
    with pytest.raises(AssertionError):
        handoff_refs(a, pages, b, b.alloc(1))


# ---------------------------------------------------------------------------
# transfer step: int8 payload + scale rows move byte-identically
# ---------------------------------------------------------------------------

def _kv_leaves(cache):
    out = []
    for pat in cache:
        for d in pat:
            if "kv" in d:
                out.extend(jax.tree_util.tree_leaves(d["kv"]))
    return out


def _fill_kv(cache, rep, pids, rng):
    """Write deterministic random values into replica ``rep``'s pages
    ``pids`` on every self-KV leaf (payload and scale tensors alike)."""
    pids = np.asarray(pids, np.int32)

    def leaf(v):
        if v.ndim < 3:
            return v
        fill = rng.randint(-127, 128, (v.shape[0], len(pids))
                           + v.shape[3:]).astype(v.dtype)
        return v.at[:, rep, pids].set(fill)

    return [[{k: (jax.tree_util.tree_map(leaf, sub) if k == "kv" else sub)
              for k, sub in d.items()} for d in pat] for pat in cache]


@pytest.mark.parametrize("plan", [PLAN, PLAN_I8], ids=["fp32", "int8"])
def test_transfer_step_moves_payload_and_scales_byte_identical(mesh1, plan):
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    n_pages, psz, lanes = 6, 4, 2
    fn, _, _ = _steps.make_page_transfer_step(cfg, plan, mesh1, n_pages,
                                              psz, lanes, n_replicas=2)
    cache = _steps.zero_paged_cache_for(cfg, plan, mesh1, n_pages, psz,
                                        n_replicas=2)
    rng = np.random.RandomState(0)
    src_pages, dst_pages = [2, 4], [1, 3]
    bystander = 5
    cache = _fill_kv(cache, 0, src_pages + [bystander], rng)
    before = [np.asarray(v) for v in _kv_leaves(cache)]
    with mesh1:
        out = fn(cache, np.int32(0), np.int32(1),
                 np.asarray(src_pages, np.int32),
                 np.asarray(dst_pages, np.int32))
    after = [np.asarray(v) for v in _kv_leaves(out)]
    quantized = kvcache.kv_pool_is_quantized(plan)
    assert quantized == any(v.dtype == np.int8 for v in after)
    for b4, af in zip(before, after):
        if b4.ndim < 3:
            continue
        # destination replica's pages carry the exact source bytes —
        # int8 payloads and float32 scale rows never round-trip through
        # a dequantize/requantize
        for sp, dp in zip(src_pages, dst_pages):
            np.testing.assert_array_equal(af[:, 1, dp], b4[:, 0, sp])
        # the source pages and untouched pages are bitwise unchanged
        for p in src_pages + [bystander]:
            np.testing.assert_array_equal(af[:, 0, p], b4[:, 0, p])
        np.testing.assert_array_equal(af[:, 1, bystander],
                                      b4[:, 1, bystander])


# ---------------------------------------------------------------------------
# engine level: disaggregated serving
# ---------------------------------------------------------------------------

def _requests(cfg, n=8, seed=0, max_new=(2, 7)):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    return [Request(rid=rid,
                    prompt=rng.randint(2, cfg.vocab_size,
                                       int(rng.randint(4, 20)))
                    .astype(np.int32),
                    max_new_tokens=int(rng.randint(*max_new)))
            for rid in range(n)]


def _run(cfg, params, mesh1, reqs, max_ticks=5000, **kw):
    from repro.serving import ServingEngine
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    prefix_cache=True, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=max_ticks)
    return eng


def _assert_leak_free(eng):
    for rr in range(eng.R):
        a, c = eng.allocators[rr], eng.prefix_caches[rr]
        cached = c.n_cached_pages if c is not None else 0
        assert a.n_free + cached == a.n_pages - a.n_reserved, rr


@pytest.mark.slow
def test_disagg_dp2_matches_serial_dp1_greedy(mesh1):
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    ref = _requests(cfg)
    _run(cfg, params, mesh1, ref, dp=1, overlap=False)
    assert all(r.done for r in ref)
    got = _requests(cfg)
    eng = _run(cfg, params, mesh1, got, dp=2, disagg=(1, 1))
    assert all(r.done for r in got)
    assert {r.rid: tuple(r.out_tokens) for r in got} == \
           {r.rid: tuple(r.out_tokens) for r in ref}
    # every request prefilled on replica 0 and finished on replica 1
    assert all(r.replica == 1 for r in got)
    assert eng.stats.handoffs == len(got)
    assert eng.stats.pages_transferred > 0
    r0, r1 = eng.stats.replicas
    assert (r0.role, r1.role) == ("prefill", "decode")
    assert r0.handoffs_out == len(got) and r1.handoffs_in == len(got)
    assert r0.pages_transferred_out == r1.pages_transferred_in \
        == eng.stats.pages_transferred
    assert r0.routed == len(got) and r1.routed == 0
    _assert_leak_free(eng)


@pytest.mark.slow
def test_handoff_preemption_mid_transfer(mesh1):
    """A slot preempted while queued for handoff (after its first token,
    before the transfer dispatched) must roll back cleanly: the request
    re-prefills via the donated-prefix path, hands off later, and both
    replicas stay leak-free with outputs identical to the undisturbed
    run."""
    from repro.serving import ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    ref = _requests(cfg, n=2, seed=3, max_new=(8, 9))
    _run(cfg, params, mesh1, ref, dp=1, overlap=False)

    reqs = _requests(cfg, n=2, seed=3, max_new=(8, 9))
    eng = ServingEngine.build_paged(cfg, PLAN, mesh1, 1, 64, params,
                                    page_size=8, prefill_chunk=16,
                                    prefix_cache=True, dp=2, disagg=(1, 1))
    for r in reqs:
        eng.submit(r)
    # drive until request 1 sits in the handoff queue (request 0 holds the
    # single decode slot, so the handoff cannot be placed)
    for _ in range(200):
        if eng._pending_handoffs:
            break
        eng.tick()
    assert eng._pending_handoffs, "no slot ever queued for handoff"
    b = eng._pending_handoffs[0]
    victim = eng.admissions[b].req
    assert victim.out_tokens, "handoff queued before the first token"
    eng.preempt(b)
    assert b not in eng._pending_handoffs
    assert eng.admissions[b] is None
    assert eng.stats.preemptions == 1
    eng.run(max_ticks=5000)
    assert all(r.done for r in reqs)
    assert {r.rid: tuple(r.out_tokens) for r in reqs} == \
           {r.rid: tuple(r.out_tokens) for r in ref}
    # the victim was evicted BEFORE its transfer dispatched, so no pages
    # ever moved for the aborted attempt — exactly one executed handoff
    # per request, the victim's coming from its re-prefill
    assert eng.stats.handoffs == len(reqs)
    assert eng.stats.replicas[0].preemptions == 1
    _assert_leak_free(eng)


@pytest.mark.slow
def test_disagg_with_speculation_and_sampling_matches_oracle(mesh1):
    from repro.serving.sampler import SamplerConfig
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    samp = SamplerConfig(temperature=0.8, top_k=40)
    ref = _requests(cfg, seed=1)
    _run(cfg, params, mesh1, ref, dp=1, overlap=False, sampler=samp,
         rng_seed=7)
    got = _requests(cfg, seed=1)
    eng = _run(cfg, params, mesh1, got, dp=2, disagg=(1, 1), sampler=samp,
               rng_seed=7, speculative=4)
    assert all(r.done for r in got)
    assert {r.rid: tuple(r.out_tokens) for r in got} == \
           {r.rid: tuple(r.out_tokens) for r in ref}
    _assert_leak_free(eng)


def test_disagg_validation(mesh1):
    from repro.serving import ServingEngine
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    params = model.init_params(cfg, PLAN)
    with pytest.raises(ValueError, match="P \\+ D == dp"):
        ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, params,
                                  page_size=8, prefill_chunk=16, dp=2,
                                  disagg=(2, 1))
    with pytest.raises(ValueError, match="P \\+ D == dp"):
        ServingEngine.build_paged(cfg, PLAN, mesh1, 2, 64, params,
                                  page_size=8, prefill_chunk=16, dp=2,
                                  disagg=(2, 0))
