"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.RandomState(0)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 384),
                                   (512, 256, 128)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_matmul(m, k, n, dt):
    a = jnp.asarray(RNG.randn(m, k), dt)
    b = jnp.asarray(RNG.randn(k, n), dt)
    out = matmul(a, b, bm=128, bk=128, bn=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.ref_matmul(a, b),
                                                np.float32), **_tol(dt))


@pytest.mark.parametrize("t,e", [(64, 128), (100, 256), (256, 512)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(t, e, dt):
    x = jnp.asarray(RNG.randn(t, e), dt)
    s = jnp.asarray(RNG.randn(e) * 0.1, dt)
    out = rmsnorm(x, s, bs=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.ref_rmsnorm(x, s), np.float32), **_tol(dt))


@pytest.mark.parametrize("sq,skv,causal,win", [
    (256, 256, True, 0), (192, 448, True, 0), (256, 256, True, 64),
    (128, 128, False, 0), (320, 320, True, 100)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention(sq, skv, causal, win, dt):
    q = jnp.asarray(RNG.randn(2, sq, 64), dt)
    k = jnp.asarray(RNG.randn(2, skv, 64), dt)
    v = jnp.asarray(RNG.randn(2, skv, 64), dt)
    o1 = flash_attention(q, k, v, causal=causal, window=win, bq=64, bkv=64,
                         interpret=True)
    o2 = ref.ref_flash_attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **_tol(dt))


@pytest.mark.parametrize("s,lens", [(300, (13, 299, 150)),
                                    (128, (1, 64, 128))])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_decode_attention(s, lens, dt):
    B = len(lens)
    q = jnp.asarray(RNG.randn(B, 4, 64), dt)
    k = jnp.asarray(RNG.randn(B, 4, s, 64), dt)
    v = jnp.asarray(RNG.randn(B, 4, s, 64), dt)
    ln = jnp.asarray(lens, jnp.int32)
    o1 = decode_attention(q, k, v, ln, bkv=64, interpret=True)
    o2 = ref.ref_decode_attention(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **_tol(dt))


@pytest.mark.parametrize("s,h,p,n,chunk", [(256, 4, 16, 32, 64),
                                           (128, 2, 32, 16, 32)])
def test_ssd_scan(s, h, p, n, chunk):
    x = jnp.asarray(RNG.randn(s, h, p), jnp.float32)
    dt_ = jnp.asarray(np.abs(RNG.randn(s, h)) * 0.1, jnp.float32)
    B = jnp.asarray(RNG.randn(s, n), jnp.float32)
    C = jnp.asarray(RNG.randn(s, n), jnp.float32)
    A = -jnp.asarray(np.abs(RNG.rand(h)) * 2 + 0.5, jnp.float32)
    o1 = ssd_scan(x, dt_, B, C, A, chunk=chunk, interpret=True)
    o2, _ = ref.ref_ssd_scan(x, dt_, B, C, A)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_matches_sequential():
    """core.ssm chunked algorithm == sequential reference (exactness)."""
    from repro.core.ssm import ssd_chunked
    S, H, P, N, B = 96, 3, 8, 16, 2
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt_ = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    A = -jnp.asarray(np.abs(RNG.rand(H)) + 0.5, jnp.float32)
    D = jnp.zeros(H)
    y, st = ssd_chunked(x, dt_, Bm, Cm, A, D, chunk=32)
    for b in range(B):
        yr, str_ = ref.ref_ssd_scan(x[b], dt_[b], Bm[b], Cm[b], A)
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(yr),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st[b]), np.asarray(str_),
                                   rtol=2e-3, atol=2e-3)
