"""JAX version compatibility shims.

The repo targets the modern JAX API surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``), but CI and some dev
boxes run JAX 0.4.x where shard_map still lives in ``jax.experimental`` (with
``check_rep``) and ``jax.sharding.AxisType`` does not exist.  Everything that
builds meshes or shard_maps goes through this module.
"""
from __future__ import annotations

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def shard_map(f, mesh, in_specs, out_specs):
    """Unchecked-replication shard_map on any supported JAX version."""
    if _HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def auto_axis_types(n: int):
    """``axis_types`` tuple for ``jax.make_mesh`` (None if unsupported)."""
    if _HAS_AXIS_TYPES:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axt = auto_axis_types(len(axis_names))
    if axt is not None:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axt,
                             devices=devices)
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)
