"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state).  Single pod: (data=16, model=16) = 256 chips; multi-pod adds
a leading ``pod`` axis (2 x 256 = 512 chips).  The ``model`` axis carries
the paper's partitioning; ``data``/``pod`` carry batch / replica
parallelism with hierarchical gradient reduction across the pod boundary
(the paper's groups-of-4 tree, one level up).
"""
from __future__ import annotations

import jax

from repro import compat

AUTO = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes, devices=None):
    return compat.make_mesh(shape, axes, devices=devices)


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def host_mesh(tp: int = 1, dp: int = 1):
    """Mesh over however many host devices exist (tests / examples)."""
    n = len(jax.devices())
    assert tp * dp <= n, (tp, dp, n)
    return make_mesh((dp, tp), ("data", "model"),
                     devices=jax.devices()[: tp * dp])
