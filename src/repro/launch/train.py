"""Training launcher with checkpoint/auto-resume fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --auto-resume

Production shape: the same entry point runs under ``runtime.ft.supervise``
(restart-on-failure); ``--auto-resume`` restores the latest COMMITted
checkpoint (params, optimizer, data-pipeline cursor) so a SIGKILL at any
point loses at most ``--ckpt-every`` steps.  Demonstrated by
tests/test_fault_tolerance.py and examples/train_small.py.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def build(args):
    import jax
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.core import steps
    from repro.core.partition import ShardingPlan
    from repro.data import DataConfig, PackedBatches
    from repro.launch.mesh import host_mesh
    from repro.optim import AdamWConfig, cosine_schedule

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    n_dev = len(jax.devices())
    tp = args.tp or (1 if args.smoke else min(16, n_dev))
    dp = max(1, n_dev // tp) if args.dp == 0 else args.dp
    mesh = host_mesh(tp=tp, dp=dp)
    plan = ShardingPlan(tp=tp, remat=args.remat)
    shape = ShapeConfig("cli", "train", args.seq_len, args.batch)
    opt = AdamWConfig(lr=args.lr,
                      schedule=cosine_schedule(args.warmup, args.steps))
    step_fn, _ = steps.make_train_step(cfg, plan, mesh, opt_cfg=opt,
                                       shape=shape)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch, seed=args.seed)
    return cfg, plan, mesh, step_fn, data_cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--tp", type=int, default=0)
    ap.add_argument("--dp", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--auto-resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at-step", type=int, default=0,
                    help="fault-injection: hard-exit at this step (tests)")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.checkpoint.manager import AsyncCheckpointer, CheckpointManager
    from repro.core import steps as _steps
    from repro.data import PackedBatches
    from repro.runtime.ft import Heartbeat

    cfg, plan, mesh, step_fn, data_cfg = build(args)
    state = _steps.init_train_state(cfg, plan, seed=args.seed)
    start_step = 0
    data_start_doc = 0
    data_buf = []

    ckpt = None
    saver = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        saver = AsyncCheckpointer(ckpt)
        if args.auto_resume and ckpt.latest_step() is not None:
            state, manifest = ckpt.restore(state)
            state = jax.tree_util.tree_map(jnp.asarray, state)
            start_step = manifest["step"]
            data_start_doc = manifest["extra"].get("doc_idx", 0)
            data_buf = manifest["extra"].get("buf", [])
            print(f"[resume] step {start_step} doc {data_start_doc}")

    pipe = PackedBatches(data_cfg, start_doc=data_start_doc, buf=data_buf)
    it = iter(pipe)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    hb = Heartbeat(timeout_s=600).start()

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        with mesh:
            state, stats = jitted(state, batch)
        hb.beat()
        if args.crash_at_step and step + 1 == args.crash_at_step:
            print(f"[fault-injection] hard exit at step {step + 1}",
                  flush=True)
            os._exit(17)
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            loss = float(stats["loss"])
            print(f"step {step + 1:5d} loss {loss:.4f} "
                  f"gnorm {float(stats['grad_norm']):.3f} "
                  f"({(time.time() - t0) / max(step + 1 - start_step, 1):.2f}"
                  f" s/step)", flush=True)
        if saver and ((step + 1) % args.ckpt_every == 0
                      or step + 1 == args.steps):
            saver.save(step + 1, state, extra=pipe.state())
    if saver:
        saver.wait()
    hb.stop()
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
