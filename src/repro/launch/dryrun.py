import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers + compiles the step (train_step for train shapes; prefill/decode
     steps for serving shapes) against ShapeDtypeStruct inputs (no
     allocation),
  3. records memory_analysis / cost_analysis / the HLO collective schedule,
  4. derives the three-term roofline (analytic FLOPs+bytes, CommLedger wire
     bytes) and appends everything to a JSON results file.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
  python -m repro.launch.dryrun --all --subprocess   # one proc per cell

Plan variants (hillclimbing): --moe-mode ep, --remat block, --seq-kv,
--kv-dtype int8, --activations seq.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from collections import Counter  # noqa: E402


def _build_plan(args, cfg, shape):
    from repro.core.partition import ShardingPlan
    dp_axes = ("pod", "data") if args.multi_pod else ("data",)
    seq_kv = shape.name == "long_500k" and cfg.family != "ssm"
    if args.seq_kv:
        seq_kv = True
    remat = args.remat
    if remat == "auto":   # production default: remat train shapes
        remat = "block" if shape.kind == "train" else "none"
    tp, cp_axes = 16, ()
    if args.cp:           # context parallelism over the model axis (tp=1)
        tp, cp_axes = 1, ("model",)
    return ShardingPlan(
        tp=tp, dp_axes=dp_axes, seq_shard_kv=seq_kv, cp_axes=cp_axes,
        cp_state_dtype=args.cp_state_dtype, zero1=args.zero1,
        moe_mode=args.moe_mode, remat=remat,
        kv_cache_dtype=args.kv_dtype, weight_dtype=args.weight_dtype,
        attn_scheme=args.attn_scheme, activations=args.activations)


def run_cell(arch: str, shape_name: str, multi_pod: bool, args):
    import jax
    from repro.configs import SHAPES, get_config, shape_supported
    from repro.core import analytics, collectives as cc, steps
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if getattr(args, "ssm_chunk", 0):
        import dataclasses
        cfg = dataclasses.replace(cfg, ssm_chunk=args.ssm_chunk)
    shape = SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = _build_plan(args, cfg, shape)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
    t0 = time.time()
    cc.LEDGER.start()

    if shape.kind == "train":
        if plan.zero1:
            step, specs = steps.make_train_step_zero1(
                cfg, plan, mesh, shape=shape, grad_accum=args.grad_accum)
            state = steps.abstract_train_state_zero1(cfg, plan, mesh)
        else:
            step, specs = steps.make_train_step(cfg, plan, mesh, shape=shape,
                                                grad_accum=args.grad_accum)
            state = steps.abstract_train_state(cfg, plan)
        batch, _ = steps.train_batch_template(cfg, shape, plan)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
    elif shape.kind == "prefill":
        fn, t, s = steps.make_prefill_step(cfg, plan, mesh, shape)
        from repro.core import model as m
        params = m.abstract_params(cfg, plan)
        with mesh:
            if cfg.is_encdec:
                lowered = jax.jit(fn).lower(params, t["frames"],
                                            t["dec_tokens"], t["cache"])
            elif cfg.frontend == "vision_patches":
                lowered = jax.jit(fn).lower(params, t["prompt"],
                                            t["image_embeds"], t["cache"])
            else:
                lowered = jax.jit(fn).lower(params, t["prompt"], t["cache"])
    else:  # decode
        fn, t, s = steps.make_decode_step(cfg, plan, mesh, shape)
        from repro.core import model as m
        params = m.abstract_params(cfg, plan)
        with mesh:
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(
                params, t["cache"], t["tokens1"], t["pos"])
    t_lower = time.time() - t0
    cc.LEDGER.stop()
    ledger_bytes = cc.LEDGER.total_bytes()
    comm_by_tag = cc.LEDGER.bytes_by_tag()
    block_syncs = cc.LEDGER.sync_count("block/")

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_colls = dict(Counter(
        re.findall(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                   r"collective-permute)", compiled.as_text())))

    cost = analytics.step_cost(cfg, plan, shape, sizes)
    model_flops = analytics.model_flops_ideal(cfg, shape)
    n_chips = int(np.prod(mesh.devices.shape)) if (np := __import__("numpy")) \
        else 0
    roof = rl.build_roofline(arch, shape_name, mesh_name, cost, ledger_bytes,
                             comm_by_tag, model_flops, n_chips)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "plan": {"tp": plan.tp, "dp_axes": list(plan.dp_axes),
                 "seq_shard_kv": plan.seq_shard_kv, "cp_axes": list(plan.cp_axes),
                 "moe_mode": plan.moe_mode, "remat": plan.remat,
                 "kv_cache_dtype": plan.kv_cache_dtype,
                 "weight_dtype": plan.weight_dtype,
                 "attn_scheme": plan.attn_scheme},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_est_bytes_per_device": (mem.argument_size_in_bytes +
                                          mem.output_size_in_bytes +
                                          mem.temp_size_in_bytes -
                                          mem.alias_size_in_bytes),
        },
        "cost_analysis": {k: float(v) for k, v in ca.items()
                          if k in ("flops", "bytes accessed")},
        "hlo_collectives": hlo_colls,
        "block_syncs_per_step": block_syncs,
        "roofline": roof.to_dict(),
    }
    return rec


def all_cells(multi_pod):
    from repro.configs import ASSIGNED, SHAPES
    for arch in ASSIGNED:
        for shape in SHAPES:
            yield arch, shape, multi_pod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each cell in its own process")
    ap.add_argument("--out", default=None)
    ap.add_argument("--moe-mode", default="tp", choices=["tp", "ep"])
    ap.add_argument("--remat", default="auto",
                    choices=["auto", "none", "block", "selective"])
    ap.add_argument("--seq-kv", action="store_true")
    ap.add_argument("--kv-dtype", default="bfloat16")
    ap.add_argument("--weight-dtype", default="")
    ap.add_argument("--attn-scheme", default="scan", choices=["scan", "split"])
    ap.add_argument("--cp", action="store_true",
                    help="context parallelism on the model axis (tp=1)")
    ap.add_argument("--activations", default="replicated")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--cp-state-dtype", default="float32")
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer state over the data axis (ZeRO-1)")
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="override the SSD chunk length")
    args = ap.parse_args()

    results = []
    if args.all:
        cells = []
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            cells += list(all_cells(mp))
        for arch, shape, mp in cells:
            if args.subprocess:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--moe-mode", args.moe_mode, "--remat", args.remat,
                       "--kv-dtype", args.kv_dtype,
                       "--activations", args.activations]
                if mp:
                    cmd.append("--multi-pod")
                if args.seq_kv:
                    cmd.append("--seq-kv")
                r = subprocess.run(cmd, capture_output=True, text=True)
                line = [ln for ln in r.stdout.splitlines()
                        if ln.startswith("RESULT ")]
                if line:
                    rec = json.loads(line[-1][len("RESULT "):])
                else:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": (r.stderr or r.stdout)[-2000:]}
                results.append(rec)
                print(f"[{rec['status']:7s}] {arch} {shape} "
                      f"{'mp' if mp else 'sp'} "
                      f"{rec.get('compile_s', '')}")
            else:
                results.append(_run_and_print(arch, shape, mp, args))
    else:
        results.append(_run_and_print(args.arch, args.shape, args.multi_pod,
                                      args))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out} ({len(results)} records)")


def _run_and_print(arch, shape, mp, args):
    try:
        rec = run_cell(arch, shape, mp, args)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        import traceback
        rec = {"arch": arch, "shape": shape,
               "mesh": "2x16x16" if mp else "16x16", "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-1500:]}
    print("RESULT " + json.dumps(rec))
    return rec


if __name__ == "__main__":
    main()
