"""Three-term roofline model for TPU v5e (per arch x shape x mesh).

    t_compute = flops_per_device / PEAK_FLOPS
    t_memory  = hbm_bytes_per_device / HBM_BW
    t_coll    = wire_bytes_per_device / ICI_BW

Sources (see DESIGN.md §5 and EXPERIMENTS.md §Roofline):
* FLOPs / HBM bytes — the analytic model (``core.analytics``), validated
  against ``compiled.cost_analysis()`` on unrolled modules (cost_analysis
  counts scanned loop bodies ONCE, so it cannot be used directly on deep
  scanned stacks).
* collective wire bytes — the CommLedger (exact trace-time audit with scan
  multipliers; ring-cost wire model), cross-checked against collective ops
  present in the lowered HLO.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


# TPU v5e-class constants (from the assignment spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_dev: float
    hbm_bytes_dev: float
    wire_bytes_dev: float
    model_flops_global: float
    n_chips: int
    flops_breakdown: dict
    bytes_breakdown: dict
    comm_by_tag: dict

    @property
    def t_compute(self):
        return self.flops_dev / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes_dev / HBM_BW

    @property
    def t_collective(self):
        return self.wire_bytes_dev / ICI_BW

    @property
    def bound(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self):
        """MODEL_FLOPS / compiled FLOPs — fraction of compute that is 'useful'."""
        return self.model_flops_global / max(self.flops_dev * self.n_chips, 1)

    @property
    def roofline_fraction(self):
        """Achievable fraction of the compute roofline if the dominant term
        were perfectly overlapped with compute: t_compute / t_bound."""
        return self.t_compute / max(self.t_bound, 1e-30)

    @property
    def mfu_upper_bound(self):
        """Model-FLOPs utilization upper bound implied by the roofline:
        (MODEL_FLOPS / chips / PEAK) / t_bound."""
        per_chip = self.model_flops_global / self.n_chips / PEAK_FLOPS
        return per_chip / max(self.t_bound, 1e-30)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bound=self.bound,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction,
                 mfu_upper_bound=self.mfu_upper_bound)
        return d


def build_roofline(arch, shape_name, mesh_name, cost, ledger_bytes,
                   comm_by_tag, model_flops, n_chips) -> Roofline:
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_dev=cost.total_flops,
        hbm_bytes_dev=cost.total_bytes,
        wire_bytes_dev=ledger_bytes,
        model_flops_global=model_flops,
        n_chips=n_chips,
        flops_breakdown={k: float(v) for k, v in cost.flops.items()},
        bytes_breakdown={k: float(v) for k, v in cost.bytes_hbm.items()},
        comm_by_tag={k: float(v) for k, v in comm_by_tag.items()},
    )


def fmt_seconds(t):
    if t >= 1:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t*1e3:.2f}ms"
    return f"{t*1e6:.1f}us"


def summarize(r: Roofline) -> str:
    return (f"{r.arch:22s} {r.shape:12s} {r.mesh:9s} "
            f"comp={fmt_seconds(r.t_compute):>9s} "
            f"mem={fmt_seconds(r.t_memory):>9s} "
            f"coll={fmt_seconds(r.t_collective):>9s} "
            f"bound={r.bound:10s} useful={r.useful_ratio:5.2f} "
            f"roofline_frac={r.roofline_fraction:5.2f} "
            f"mfu_ub={r.mfu_upper_bound:5.2f}")
