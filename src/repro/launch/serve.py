"""Serving launcher: batched requests through the ServingEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-42m --smoke \
        --requests 16 --slots 4 --max-new 16

Builds the prefill/decode steps for a host mesh, spins up the
continuous-batching engine, pushes synthetic requests, and reports
TTFT / per-token latency / throughput.

``--arch`` accepts any registered decode-capable config
(``repro.configs``), including hybrid/SSM archs (e.g. ``hymba-1.5b``,
``mamba2-370m`` — paged serving gives them per-request recurrent-state
slabs) and encoder-decoder archs (e.g. ``seamless-m4t-large-v2`` — the
launcher synthesizes encoder frame embeddings per request;
``--frame-groups K`` spreads requests over K distinct frame tensors so
the cross-KV cache's shared-encode path is exercised).  Vision-frontend
archs are not servable paged and fail with a precise error.
``--prefix-cache`` is attention-only-decoder territory: SSM state is not
addressable by token-id prefixes and enc-dec self-KV depends on the
frames, so the engine rejects those combinations (cross-KV sharing for
enc-dec is automatic instead).

Scheduling policy is selected with ``--policy {fcfs,priority,fair}``;
``--policy priority --preemption`` additionally evicts low-priority slots
when urgent requests arrive, and ``--policy fair --preemption`` enables
preemptive DRR (paged engine only; see README §Serving).
``--high-priority-every N`` marks every Nth request urgent and the report
then splits TTFT per class; ``--clients N`` spreads requests across N
client ids for the fair policy.

``--speculative K`` (paged engine, attention-only archs) turns on
speculative decoding: prompt-lookup self-drafts of up to K tokens are
verified in one fused K+1-position step per tick, outputs stay
token-identical to the one-token path, and the report adds
accepted-tokens/tick and the draft hit rate.

``--dp N`` (paged engine only) runs N data-parallel replicas, each with
``--slots`` slots and its own replica-local page pool / prefix cache /
scheduler; a router assigns requests by prefix affinity then page load,
and the report splits stats per replica.  Replicas shard over the mesh's
data axis when enough devices exist (they co-locate otherwise).

``--disagg P:D`` (requires ``--dp`` with P + D replicas) disaggregates
prefill from decode: P replicas chunk-prefill fresh requests and hand
each finished KV page run to one of D decode replicas through the
compiled page-transfer step, so long prefills never steal decode ticks
(README §Disaggregated serving).  The paged engine plans one tick ahead
by default (``--overlap``); ``--no-overlap`` restores the serial
plan-dispatch-collect loop for debugging — outputs are token-identical
either way, and the report adds the device-busy fraction plus plan-ahead
/ invalidation counts.

``--scale-events T:N[,T:N...]`` (paged engine, no ``--disagg``) replays
an elastic membership schedule under the live load: at tick T the engine
scales to N replicas (``scale_to`` — leaving replicas drain by migrating
their in-flight KV page runs to survivors), and a ``T:kill:R`` entry
instead injects a replica-R failure (``kill_replica`` — its requests
re-admit elsewhere as re-prefills).  Outputs stay token-identical to an
undisturbed run; the report adds migration / recovery counters
(README §Elastic serving).
"""
from __future__ import annotations

import argparse
import functools
import sys
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="registered config id (repro.configs): dense/MoE "
                         "decoders, hybrid/SSM (paged: recurrent-state "
                         "slabs), enc-dec (paged: cross-KV pages + "
                         "shared-frame encode reuse)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seq-budget", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replicas with replica-local page "
                         "pools and a prefix-affinity router (implies "
                         "--paged)")
    ap.add_argument("--disagg", default=None, metavar="P:D",
                    help="disaggregate prefill from decode: P prefill "
                         "replicas hand finished page runs to D decode "
                         "replicas via the compiled page-transfer step "
                         "(requires --dp P+D)")
    ap.add_argument("--scale-events", default=None, metavar="T:N[,T:N...]",
                    help="elastic membership schedule (paged engine, no "
                         "--disagg): at tick T scale to N replicas; a "
                         "'T:kill:R' entry injects a replica-R failure "
                         "instead (e.g. 8:1,12:kill:0,16:2)")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="plan tick t+1 while tick t's steps run on device "
                         "(paged engine; --no-overlap restores the serial "
                         "loop, token-identical either way)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache + chunked prefill (README §Serving)")
    ap.add_argument("--kv-dtype", choices=("fp32", "fp16", "int8"),
                    default="fp16",
                    help="paged-pool storage dtype; int8 quantizes every "
                         "state pool (self-KV, cross-KV, SSM slabs) with "
                         "per-page scales at ~half the fp16 bytes "
                         "(README §Quantized KV cache)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page pool size (0 = full occupancy + scratch)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix sharing with copy-on-write pages "
                         "(implies --paged)")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="speculative decoding: prompt-lookup self-drafts "
                         "of up to K tokens verified in one fused step "
                         "(attention-only archs; implies --paged; outputs "
                         "stay token-identical)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common system-prompt prefix of this "
                         "many tokens to every request")
    ap.add_argument("--frame-groups", type=int, default=1, metavar="K",
                    help="enc-dec archs: spread requests over K distinct "
                         "synthetic frame tensors (requests in a group "
                         "share one encode's cross-KV pages)")
    ap.add_argument("--policy", choices=("fcfs", "priority", "fair"),
                    default="fcfs", help="admission policy (serving.policies)")
    ap.add_argument("--preemption", action="store_true",
                    help="evict low-priority slots for urgent arrivals "
                         "(requires --policy priority and a paged engine)")
    ap.add_argument("--high-priority-every", type=int, default=0,
                    metavar="N", help="every Nth request gets priority 10 "
                                      "(0 = uniform priority)")
    ap.add_argument("--clients", type=int, default=1,
                    help="spread requests over N client ids (fair policy)")
    args = ap.parse_args(argv)
    if args.shared_prefix + args.prompt_len + args.max_new > args.seq_budget:
        ap.error("--shared-prefix + --prompt-len + --max-new must fit "
                 "--seq-budget")
    if args.preemption and args.policy not in ("priority", "fair"):
        ap.error("--preemption requires --policy priority or fair")
    if args.preemption and not (args.paged or args.prefix_cache
                                or args.dp > 1):
        ap.error("--preemption requires the paged engine (--paged)")
    if args.dp < 1:
        ap.error("--dp must be >= 1")
    if args.speculative < 0:
        ap.error("--speculative must be >= 0")
    disagg = None
    if args.disagg is not None:
        try:
            p, d = (int(x) for x in args.disagg.split(":"))
        except ValueError:
            ap.error("--disagg expects P:D (e.g. --disagg 1:1)")
        if p < 1 or d < 1 or p + d != args.dp:
            ap.error(f"--disagg {args.disagg} needs --dp {p + d} "
                     f"(P + D replicas, both >= 1)")
        disagg = (p, d)
    scale_events = []
    if args.scale_events:
        if not (args.paged or args.prefix_cache or args.dp > 1
                or args.speculative):
            ap.error("--scale-events requires the paged engine (--paged)")
        if disagg is not None:
            ap.error("--scale-events cannot combine with --disagg "
                     "(role sets are static)")
        for part in args.scale_events.split(","):
            bits = part.split(":")
            try:
                if len(bits) == 2:
                    scale_events.append((int(bits[0]), "scale",
                                         int(bits[1])))
                elif len(bits) == 3 and bits[1] == "kill":
                    scale_events.append((int(bits[0]), "kill",
                                         int(bits[2])))
                else:
                    raise ValueError(part)
            except ValueError:
                ap.error("--scale-events expects comma-separated T:N or "
                         "T:kill:R entries")
        scale_events.sort()

    import jax
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.core import model, steps
    from repro.core.partition import ShardingPlan
    from repro.launch.mesh import host_mesh
    from repro.serving import (FairScheduler, PriorityScheduler, Request,
                               SamplerConfig, ServingEngine)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    kvd = {"fp32": "float32", "fp16": "bfloat16", "int8": "int8"}
    plan = ShardingPlan(tp=args.tp, kv_cache_dtype=kvd[args.kv_dtype],
                        ssm_cache_dtype=("int8" if args.kv_dtype == "int8"
                                         else ""))
    # shard replicas over real devices when they exist; otherwise they
    # co-locate on one data shard (n_replicas must cover the mesh evenly)
    mesh_dp = max((d for d in range(1, args.dp + 1)
                   if args.dp % d == 0 and
                   d * args.tp <= len(jax.devices())), default=1)
    mesh = host_mesh(tp=args.tp, dp=mesh_dp)
    params = model.init_params(cfg, plan, seed=args.seed)

    scheduler = None                 # engine default: FCFS
    if args.policy == "priority":
        scheduler = functools.partial(PriorityScheduler,
                                      preemption=args.preemption)
    elif args.policy == "fair":
        scheduler = functools.partial(FairScheduler,
                                      preemption=args.preemption)

    sampler = SamplerConfig(temperature=args.temperature, top_k=40)
    if args.paged or args.prefix_cache or args.dp > 1 or args.speculative:
        engine = ServingEngine.build_paged(
            cfg, plan, mesh, args.slots, args.seq_budget, params,
            page_size=args.page_size, n_pages=args.n_pages,
            prefill_chunk=args.prefill_chunk, sampler=sampler,
            prefix_cache=args.prefix_cache, scheduler=scheduler,
            rng_seed=args.seed, dp=args.dp, speculative=args.speculative,
            overlap=args.overlap, disagg=disagg)
        if scale_events:
            def membership_hook(e, _pending=list(scale_events)):
                while _pending and e.stats.ticks >= _pending[0][0]:
                    _, kind, val = _pending.pop(0)
                    if kind == "scale":
                        e.scale_to(val)
                    else:
                        e.kill_replica(val)
            engine.membership_hook = membership_hook
    else:
        dshape = ShapeConfig("serve", "decode", args.seq_budget, args.slots)
        pshape = ShapeConfig("serve1", "decode", args.seq_budget, 1)
        decode_fn, _, _ = steps.make_decode_step(cfg, plan, mesh, dshape)
        prefill_fn, _, _ = steps.make_prefill_step(cfg, plan, mesh, pshape)
        # donate the lane/engine cache: it is reassigned from the return at
        # every call site (cache arg trails the prompt; enc-dec adds frames)
        cache_arg = 3 if cfg.is_encdec else 2
        engine = ServingEngine(cfg, plan, mesh, args.slots, args.seq_budget,
                               params,
                               jax.jit(prefill_fn,
                                       donate_argnums=(cache_arg,)),
                               jax.jit(decode_fn, donate_argnums=(1,)),
                               sampler=sampler,
                               scheduler=scheduler, rng_seed=args.seed)
    rng = np.random.RandomState(args.seed)
    shared = rng.randint(2, cfg.vocab_size,
                         args.shared_prefix).astype(np.int32)
    frame_groups = [rng.randn(cfg.enc_seq_len, cfg.d_model
                              ).astype(np.float32)
                    for _ in range(max(args.frame_groups, 1))] \
        if cfg.is_encdec else []
    reqs = []
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.randint(2, cfg.vocab_size,
                             rng.randint(4, args.prompt_len + 1)
                             ).astype(np.int32)
        prompt = np.concatenate([shared, prompt]).astype(np.int32)
        hi = args.high_priority_every and rid % args.high_priority_every == 0
        req = Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new,
                      priority=10 if hi else 0,
                      client_id=rid % max(args.clients, 1),
                      frames=(frame_groups[rid % len(frame_groups)]
                              if frame_groups else None))
        reqs.append(req)
        engine.submit(req)
    stats = engine.run()
    dt = time.time() - t0
    print(f"requests={args.requests} ticks={stats.ticks} "
          f"prefills={stats.prefills} tokens={stats.decoded_tokens} "
          f"preemptions={stats.preemptions}")
    if stats.ttft_s:
        print(f"throughput={stats.decoded_tokens / dt:.1f} tok/s "
              f"ttft_p50={np.median(stats.ttft_s) * 1e3:.1f}ms "
              f"ttft_p95={np.percentile(stats.ttft_s, 95) * 1e3:.1f}ms "
              f"tpot_p50={np.median(stats.tpot_s) * 1e3:.1f}ms")
    else:
        print("no tokens emitted")
    if engine.paged:
        print(f"pipeline: overlap={'on' if engine.overlap else 'off'} "
              f"device_busy_fraction={stats.device_busy_fraction:.2f} "
              f"plan_ahead_ticks={stats.plan_ahead_ticks} "
              f"plan_invalidations={stats.plan_invalidations} "
              f"collect_wait={stats.collect_wait_s * 1e3:.1f}ms")
    if disagg is not None:
        print(f"disagg(P={disagg[0]} D={disagg[1]}): "
              f"handoffs={stats.handoffs} "
              f"pages_transferred={stats.pages_transferred}")
    if args.scale_events:
        print(f"elastic: scale_events={stats.scale_events} "
              f"crashes={stats.crashes} migrations={stats.migrations} "
              f"migrated_pages={stats.migrated_pages} "
              f"readmitted={stats.readmitted} dp_final={engine.R}")
    if args.high_priority_every:
        for label, cls in (("high", 10), ("low", 0)):
            ts = [stats.request_ttft[r.rid] for r in reqs
                  if r.priority == cls and r.rid in stats.request_ttft]
            if ts:
                print(f"ttft[{label}]: p50={np.median(ts) * 1e3:.1f}ms "
                      f"p99={np.percentile(ts, 99) * 1e3:.1f}ms "
                      f"n={len(ts)}")
    if args.prefix_cache:
        cached = sum(c.n_cached_pages for c in engine.prefix_caches if c)
        evictions = sum(c.evictions for c in engine.prefix_caches if c)
        print(f"prefix_cache: hit_rate={stats.prefix_hit_rate:.2f} "
              f"({stats.prefix_hits}/{stats.prefix_lookups} lookups) "
              f"prefill_tokens_skipped={stats.prefill_tokens_skipped} "
              f"cow_copies={stats.cow_copies} "
              f"cached_pages={cached} evictions={evictions}")
    if args.speculative:
        print(f"speculative(k={args.speculative}): "
              f"accepted_tokens_per_tick="
              f"{stats.accepted_tokens_per_tick:.2f} "
              f"draft_hit_rate={stats.draft_hit_rate:.2f} "
              f"({stats.spec_draft_hits}/{stats.spec_draft_lookups} "
              f"lookups) accepted={stats.spec_accepted}"
              f"/{stats.spec_drafted} drafted "
              f"spec_denied={stats.spec_denied}")
    if engine.cross_caches:
        print(f"cross_kv: hit_rate={stats.cross_hit_rate:.2f} "
              f"({stats.cross_hits}/{stats.cross_lookups} lookups) "
              f"encodes={stats.cross_encodes} "
              f"cached_entries="
              f"{sum(c.n_entries for c in engine.cross_caches)}")
    if engine.slab_allocators:
        print(f"ssm_slabs: per_replica={engine.n_slabs - 1} "
              f"allocated={sum(s.total_allocated for s in engine.slab_allocators)} "
              f"stash_restores={stats.slab_restores}")
    if args.dp > 1:
        print(f"router: affinity_routed={engine.router.affinity_routed}"
              f"/{args.requests}")
        for r, rs in enumerate(stats.replicas):
            alloc = engine.allocators[r]
            handoff = ""
            if disagg is not None:
                handoff = (f" role={rs.role} "
                           f"handoffs={rs.handoffs_out}out/"
                           f"{rs.handoffs_in}in "
                           f"pages_transferred={rs.pages_transferred_out}"
                           f"out/{rs.pages_transferred_in}in")
            print(f"replica[{r}]: routed={rs.routed} "
                  f"prefills={rs.prefills} tokens={rs.decoded_tokens} "
                  f"preemptions={rs.preemptions} "
                  f"prefix_hit_rate={rs.prefix_hit_rate:.2f} "
                  f"pages_allocated={alloc.total_allocated} "
                  f"pages_free={alloc.n_free}/"
                  f"{alloc.n_pages - alloc.n_reserved}" + handoff)
    slowest = sorted(stats.request_ttft.items(), key=lambda kv: -kv[1])[:3]
    print("ttft_per_request_worst3: " +
          " ".join(f"rid{r}={t * 1e3:.1f}ms" for r, t in slowest))
    return 0


if __name__ == "__main__":
    sys.exit(main())
