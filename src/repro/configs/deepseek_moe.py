"""deepseek-moe-16b — fine-grained MoE: 64 routed experts top-6 + 2 shared.

[arXiv:2401.06066; hf tier] 28L d_model=2048 16H (kv=16) vocab=102400,
per-expert d_ff=1408; first layer uses a dense FFN (width 10944) per the
release.  Shared experts = 2 x 1408.
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                # routed-expert width (pool-specified)
    vocab_size=102_400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_k_dense=1,
    dense_ff_override=10_944,
    rope_theta=10_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=False,
    max_seq_len=16_384,
    source="arXiv:2401.06066; hf tier",
))
