from repro.configs.base import (  # noqa: F401
    ASSIGNED, PAPER_MODELS, SHAPES, LayerGroup, LayerSpec, ModelConfig,
    ShapeConfig, get_config, list_configs, reduced, register, shape_supported,
)
