"""mixtral-8x22b — sparse MoE, 8 experts top-2, SWA. [arXiv:2401.04088; hf tier]"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=32_768,
    n_experts=8,
    top_k=2,
    moe_d_ff=16_384,
    sliding_window=4096,      # pool note: SWA
    rope_theta=1_000_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=False,
    max_seq_len=65_536,
    source="arXiv:2401.04088; hf tier",
))
