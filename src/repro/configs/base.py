"""Model/shape configuration system.

Every architecture in the assigned pool is expressed as a ``ModelConfig``:
a declarative description from which the composable model builder
(``repro.core.model``) derives its layer plan, parameter shapes, sharding
plan and FLOP/byte counts.  Configs are registered by id and selectable via
``--arch <id>`` in every launcher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# Attention kinds
ATTN_FULL = "full"          # global causal (or bidirectional for encoders)
ATTN_WINDOW = "window"      # sliding-window causal
ATTN_NONE = "none"          # attention-free (pure SSM layer)

# Mixer kinds
MIX_ATTN = "attn"           # plain MHSA/GQA
MIX_SSM = "ssm"             # mamba2 SSD block
MIX_HYBRID = "hybrid"       # parallel attn + ssm heads (hymba)

# FFN kinds
FFN_DENSE = "dense"         # (gated) MLP
FFN_MOE = "moe"             # mixture of experts
FFN_NONE = "none"           # no FFN (mamba2 blocks)


@dataclass(frozen=True)
class LayerSpec:
    """One transformer layer's structure."""
    mixer: str = MIX_ATTN                 # attn | ssm | hybrid
    attn: str = ATTN_FULL                 # full | window | none
    ffn: str = FFN_DENSE                  # dense | moe | none
    cross_attn: bool = False              # decoder cross-attention (enc-dec)
    d_ff: int = 0                         # dense FFN width for THIS layer

    def cache_kinds(self):
        kinds = []
        if self.mixer in (MIX_ATTN, MIX_HYBRID) and self.attn != ATTN_NONE:
            kinds.append("kv")
        if self.mixer in (MIX_SSM, MIX_HYBRID):
            kinds.append("ssm")
        if self.cross_attn:
            kinds.append("cross_kv")
        return kinds


@dataclass(frozen=True)
class LayerGroup:
    """``n_reps`` repetitions of a (short) layer pattern, run under lax.scan.

    Stacked parameters for the group have leading axis ``n_reps``; the HLO
    contains the pattern body once => bounded compile time for deep models.
    """
    n_reps: int
    pattern: tuple  # tuple[LayerSpec, ...]

    @property
    def n_layers(self) -> int:
        return self.n_reps * len(self.pattern)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- attention features -------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 -> no SWA anywhere
    local_global_ratio: int = 0       # k -> k local layers per 1 global (gemma3)
    causal: bool = True               # False for encoders
    attn_scale: Optional[float] = None

    # --- FFN / MoE ----------------------------------------------------------
    act: str = "silu"                 # silu (gated) | gelu
    gated_ffn: bool = True
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert intermediate size
    first_k_dense: int = 0            # deepseek: first k layers use dense FFN
    dense_ff_override: int = 0        # width of those dense layers

    # --- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256              # SSD chunk length

    # --- enc-dec / frontends --------------------------------------------------
    n_enc_layers: int = 0             # >0 -> encoder-decoder
    enc_seq_len: int = 0              # fixed encoder memory length for decode shapes
    frontend: Optional[str] = None    # audio_frames | vision_patches (stub per spec)
    n_frontend_embeds: int = 0        # patches/frames provided as precomputed embeds

    # --- misc -----------------------------------------------------------------
    sandwich_norm: bool = False       # gemma3: post-sublayer norms
    scale_embed: bool = False         # gemma3: embeddings scaled by sqrt(E)
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    max_seq_len: int = 131_072
    source: str = ""                  # provenance note

    # ------------------------------------------------------------------ utils
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_encoder_only(self) -> bool:
        return self.family == "encoder"

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder_only

    # ---------------------------------------------------------------- layers
    def layer_specs(self) -> list:
        """Per-layer structure for the decoder (or encoder-only) stack."""
        specs = []
        for i in range(self.n_layers):
            specs.append(self._spec_for_layer(i))
        return specs

    def _spec_for_layer(self, i: int) -> LayerSpec:
        # attention kind
        if self.family == "ssm":
            return LayerSpec(mixer=MIX_SSM, attn=ATTN_NONE, ffn=FFN_NONE)
        if self.local_global_ratio > 0:
            k = self.local_global_ratio
            attn = ATTN_FULL if (i % (k + 1)) == k else ATTN_WINDOW
        elif self.sliding_window > 0:
            attn = ATTN_WINDOW
        else:
            attn = ATTN_FULL
        mixer = MIX_HYBRID if self.family == "hybrid" else MIX_ATTN
        if self.family == "hybrid":
            # hymba: a few strategically-placed full-attention layers
            full_at = {0, self.n_layers // 2, self.n_layers - 1}
            attn = ATTN_FULL if i in full_at else ATTN_WINDOW
        # ffn kind
        if self.family == "ssm":
            ffn, d_ff = FFN_NONE, 0
        elif self.n_experts > 0 and i >= self.first_k_dense:
            ffn, d_ff = FFN_MOE, 0
        elif self.n_experts > 0:
            ffn, d_ff = FFN_DENSE, (self.dense_ff_override or self.d_ff)
        else:
            ffn, d_ff = FFN_DENSE, self.d_ff
        return LayerSpec(mixer=mixer, attn=attn, ffn=ffn, d_ff=d_ff,
                         cross_attn=self.is_encdec)

    def encoder_layer_specs(self) -> list:
        return [LayerSpec(mixer=MIX_ATTN, attn=ATTN_FULL, ffn=FFN_DENSE,
                          d_ff=self.d_ff, cross_attn=False)
                for _ in range(self.n_enc_layers)]

    def layer_groups(self, specs: Optional[Sequence[LayerSpec]] = None) -> list:
        """Factor the layer list into scanned (n_reps x pattern) groups."""
        specs = list(specs if specs is not None else self.layer_specs())
        return factor_layer_groups(specs)

    # ------------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Exact parameter count (matches init_params)."""
        from repro.core import model as _model  # lazy; avoids jax import here
        return _model.param_count(self)

    def window_for(self, spec: LayerSpec) -> int:
        return self.sliding_window if spec.attn == ATTN_WINDOW else 0


def factor_layer_groups(specs) -> list:
    """Greedy periodic factoring: find the shortest repeating pattern prefix,
    emit (reps, pattern) groups; remainder becomes its own group(s)."""
    groups = []
    i = 0
    n = len(specs)
    while i < n:
        # find longest run of a minimal period starting at i
        best = (1, 1)  # (period, reps)
        for period in (1, 2, 3, 4, 6, 8):
            if i + period > n:
                break
            reps = 1
            while i + (reps + 1) * period <= n and \
                    specs[i + reps * period: i + (reps + 1) * period] == specs[i: i + period]:
                reps += 1
            if reps * period > best[0] * best[1] or \
                    (reps * period == best[0] * best[1] and period < best[0]):
                best = (period, reps)
        period, reps = best
        groups.append(LayerGroup(n_reps=reps, pattern=tuple(specs[i:i + period])))
        i += period * reps
    return groups


# ---------------------------------------------------------------------------
# Input shapes ("cells")
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}

# archs with sub-quadratic structure (SSM / SWA-dominant) run long_500k
_SUBQUADRATIC = {"mamba2-370m", "hymba-1.5b", "gemma3-12b", "gemma3-27b",
                 "mixtral-8x22b"}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig):
    """-> (supported, reason_if_not)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and cfg.name not in _SUBQUADRATIC:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, f"duplicate config {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED = [
    "mamba2-370m", "gemma3-12b", "gemma3-27b", "qwen3-0.6b",
    "mistral-large-123b", "deepseek-moe-16b", "mixtral-8x22b",
    "seamless-m4t-large-v2", "hymba-1.5b", "pixtral-12b",
]

PAPER_MODELS = ["tinyllama-42m", "tinyllama-42m-64h", "mobilebert"]


def _ensure_loaded():
    # import every config module exactly once
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        mamba2_370m, gemma3, qwen3, mistral_large, deepseek_moe, mixtral,
        seamless_m4t, hymba, pixtral, paper_models,
    )


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    scale = dict(
        n_layers=min(cfg.n_layers, 2 + (2 if cfg.local_global_ratio else 0)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        max_seq_len=512,
    )
    if cfg.local_global_ratio:
        scale["n_layers"] = cfg.local_global_ratio + 1  # one full pattern
        scale["sliding_window"] = 64
    elif cfg.sliding_window:
        scale["sliding_window"] = 64
    if cfg.n_experts:
        scale.update(n_experts=min(cfg.n_experts, 8),
                     top_k=min(cfg.top_k, 2),
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     moe_d_ff=64, first_k_dense=min(cfg.first_k_dense, 1),
                     dense_ff_override=256 if cfg.first_k_dense else 0)
    if cfg.ssm_state:
        scale.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.is_encdec:
        scale.update(n_enc_layers=2, enc_seq_len=64)
    if cfg.n_frontend_embeds:
        scale.update(n_frontend_embeds=16)
    if cfg.family == "hybrid":
        scale.update(n_layers=4)
    scale.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **scale)
