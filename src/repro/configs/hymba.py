"""hymba-1.5b — hybrid: parallel attention + mamba heads in every block.

[arXiv:2411.13676; hf tier] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Both head groups are sharded head-parallel on
the model axis; their outputs fuse before the single post-mixer psum, so the
paper's two-sync contract holds.  25 Q / 5 KV heads are not divisible by
tp=16 => heads are zero-padded to the next multiple (DESIGN.md deviation:
"head padding for indivisible head counts").  Full attention at layers
{0, 16, 31}; sliding window 1024 elsewhere; meta-tokens omitted (backbone
dims only).
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,          # 50 SSM heads (d_inner=3200)
    ssm_conv=4,
    ssm_chunk=256,
    sliding_window=1024,
    rope_theta=10_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=True,
    max_seq_len=1_048_576,
    source="arXiv:2411.13676; hf tier",
))
