"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio frontend stub).

[arXiv:2308.11596; hf tier] 24L enc + 24L dec, d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206.  Per the assignment spec the modality frontend is a
STUB: ``input_specs()`` provides precomputed frame embeddings (B, S, E) to
the encoder; the decoder consumes tokens and cross-attends to the encoder
memory (fixed 4096 frames for decode shapes).
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,               # decoder layers
    n_enc_layers=24,
    enc_seq_len=4096,          # encoder memory length for decode shapes
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    frontend="audio_frames",
    rope_theta=10_000.0,
    act="gelu",
    gated_ffn=False,
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=65_536,
    source="arXiv:2308.11596; hf tier (backbone dims; frontend stubbed per spec)",
))
