"""pixtral-12b — VLM: pixtral-ViT frontend (stub) + mistral-nemo-like decoder.

[hf:mistralai/Pixtral-12B-2409] Backbone only per the assignment spec: 40L
d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.  The vision frontend is
a STUB — ``input_specs()`` provides 1024 precomputed patch embeddings that
are spliced into the token sequence.
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    frontend="vision_patches",
    n_frontend_embeds=1024,
    rope_theta=1_000_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=False,
    max_seq_len=131_072,
    source="hf:mistralai/Pixtral-12B-2409; unverified tier (backbone only)",
))
