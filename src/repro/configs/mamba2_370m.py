"""mamba2-370m — SSD (state-space duality), attention-free.

[arXiv:2405.21060] 48L d_model=1024, d_ff=0 (no FFN blocks), vocab=50280,
ssm_state=128.  Mamba-2 defaults: expand=2 (d_inner=2048), headdim=64
(=> 32 SSD heads), conv width 4.  n_groups=1 in the release; the B/C/dt
projections (~0.4% of params) are replicated across TP shards (DESIGN.md
deviation note) while z/x/heads are sharded head-parallel.
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,            # SSD heads = d_inner / ssm_head_dim
    n_kv_heads=32,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    norm="rmsnorm",
    rope_theta=0.0,        # no RoPE (SSM positions are implicit)
    max_seq_len=1_048_576,
    source="arXiv:2405.21060 (mamba2-370m); unverified tier",
))
