"""gemma3-12b / gemma3-27b — dense decoders, 5:1 local:global attention.

[hf:google/gemma-3-*-pt] GQA + qk-norm, sliding window 1024 on local layers,
128k context.  head_dim is 256 (12b) / 128 (27b) per the released configs
(decoupled from d_model/n_heads).
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    qk_norm=True,
    sandwich_norm=True,
    scale_embed=True,
    local_global_ratio=5,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    act="gelu",
    gated_ffn=True,
    tie_embeddings=True,
    max_seq_len=131_072,
    source="hf:google/gemma-3-12b-pt; unverified tier",
))

register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    qk_norm=True,
    sandwich_norm=True,
    scale_embed=True,
    local_global_ratio=5,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    act="gelu",
    gated_ffn=True,
    tie_embeddings=True,
    max_seq_len=131_072,
    source="hf:google/gemma-3-27b-pt; unverified tier",
))
