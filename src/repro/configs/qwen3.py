"""qwen3-0.6b — dense decoder with qk-norm + GQA. [hf:Qwen/Qwen3-0.6B; hf tier]"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=True,
    max_seq_len=40_960,
    source="hf:Qwen/Qwen3-0.6B; hf tier",
))
