"""The paper's own workloads: TinyLlama-42M (decoder) and MobileBERT (encoder).

TinyLlama-42M [llama2.c / paper V-A]: E=512, intermediate 2048, 8 layers,
8 heads, vocab 32000; S=128 autoregressive / S=16 prompt.  The scaled-up
variant for the Fig. 6 scalability study has 64 heads, other dims unchanged.

MobileBERT [paper V-A]: encoder-only, E=512, intermediate 512, 4 heads,
S=268.  (The released MobileBERT's bottleneck structure is simplified to a
standard encoder block with the paper's stated dims; the sim's workload
model uses the same dims so repro numbers are self-consistent.)
"""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="tinyllama-42m",
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=32_000,
    rope_theta=10_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=True,
    max_seq_len=1024,
    source="paper §V-A / karpathy llama2.c",
))

register(ModelConfig(
    name="tinyllama-42m-64h",          # Fig. 6 scalability variant
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=64,
    n_kv_heads=64,
    head_dim=8,
    d_ff=2048,
    vocab_size=32_000,
    rope_theta=10_000.0,
    act="silu",
    gated_ffn=True,
    tie_embeddings=True,
    max_seq_len=1024,
    source="paper §V-C (64-head scalability study)",
))

register(ModelConfig(
    name="mobilebert",
    family="encoder",
    n_layers=24,
    d_model=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=128,
    d_ff=512,
    vocab_size=30_522,
    causal=False,
    rope_theta=10_000.0,
    act="gelu",
    gated_ffn=False,
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=512,
    source="paper §V-A (MobileBERT dims)",
))
