from repro.runtime.ft import FTConfig, Heartbeat, supervise  # noqa: F401
from repro.runtime.straggler import HedgedRouter  # noqa: F401
