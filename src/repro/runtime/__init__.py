from repro.runtime.elastic import reshard_replica_pools  # noqa: F401
from repro.runtime.ft import (FTConfig, Heartbeat, RecoveryReport,  # noqa: F401
                              plan_recovery, supervise)
from repro.runtime.straggler import HedgedRouter  # noqa: F401
