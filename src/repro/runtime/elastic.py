"""Elastic scaling: rebuild the mesh from the surviving device set and
reshard the training/serving state onto it.

Contract at fleet scale: when membership changes (node loss, pod added),
the controller picks the largest (dp', tp') grid the survivors support,
every worker restores/reshards via ``checkpoint.resharding``, and training
continues — no manual relayout.  TP changes are exact (canonicalize ->
re-scatter); DP changes only affect batch placement.

Serving-side membership changes reuse the same canonicalize -> re-scatter
shape: ``reshard_replica_pools`` maps the replica axis of every paged-cache
leaf (axis 1 by the ``paged_cache_template`` contract) from the surviving
replica indices onto a fresh pool of the new width, zero-filling joined
replicas.  ``ServingEngine.scale_to`` / ``kill_replica`` drive it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.checkpoint.resharding import reshard_params
from repro.core.partition import ShardingPlan
from repro.launch.mesh import make_mesh


@dataclass
class ElasticDecision:
    dp: int
    tp: int
    n_devices: int

    @property
    def plan(self):
        return ShardingPlan(tp=self.tp)


def choose_layout(n_devices: int, cfg, prefer_tp: int = 16) -> ElasticDecision:
    """Largest usable (dp, tp): tp <= prefer_tp, tp | n_heads-padding works
    for any tp, so the only hard constraint is tp <= n_devices."""
    tp = min(prefer_tp, n_devices)
    while n_devices % tp:
        tp -= 1
    return ElasticDecision(dp=n_devices // tp, tp=tp, n_devices=n_devices)


def rebuild(cfg, params, plan_from: ShardingPlan, devices=None,
            prefer_tp: int = 16):
    """-> (mesh, plan, resharded_params) for the current device set."""
    devices = devices if devices is not None else jax.devices()
    dec = choose_layout(len(devices), cfg, prefer_tp)
    mesh = make_mesh((dec.dp, dec.tp), ("data", "model"),
                     devices=devices[: dec.dp * dec.tp])
    plan_to = dec.plan
    new_params = reshard_params(params, cfg, plan_from, plan_to)
    return mesh, plan_to, new_params


def reshard_replica_pools(cache, keep: Sequence[int], new_n_replicas: int):
    """Re-scatter a paged serving cache onto a new replica count.

    ``keep`` lists the surviving old replica indices in their *new* order:
    survivor ``keep[j]`` becomes replica ``j`` of the new pool.  Replicas
    ``len(keep)..new_n_replicas-1`` are freshly joined and start zeroed
    (their allocators hand out pages into untouched rows, so zeroing is
    only hygiene — it matches ``zero_paged_cache_for``'s starting state).

    Every leaf of a paged cache carries the replica dimension at axis 1
    (``kvcache.paged_cache_template`` stacks replicas there for pools,
    scales, slabs, and slab scales alike), which is what lets one gather /
    scatter handle all state kinds uniformly — the serving twin of
    ``reshard_params``'s canonicalize -> re-scatter.
    """
    if not 0 < len(keep) <= new_n_replicas:
        raise ValueError(f"keep={list(keep)!r} incompatible with "
                         f"new_n_replicas={new_n_replicas}")
    idx = jnp.asarray(list(keep), dtype=jnp.int32)

    def _leaf(v):
        out = jnp.zeros((v.shape[0], new_n_replicas) + v.shape[2:], v.dtype)
        return out.at[:, :idx.shape[0]].set(jnp.take(v, idx, axis=1))

    return jax.tree_util.tree_map(_leaf, cache)
