"""Elastic scaling: rebuild the mesh from the surviving device set and
reshard the training/serving state onto it.

Contract at fleet scale: when membership changes (node loss, pod added),
the controller picks the largest (dp', tp') grid the survivors support,
every worker restores/reshards via ``checkpoint.resharding``, and training
continues — no manual relayout.  TP changes are exact (canonicalize ->
re-scatter); DP changes only affect batch placement.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.checkpoint.resharding import reshard_params
from repro.core.partition import ShardingPlan
from repro.launch.mesh import make_mesh


@dataclass
class ElasticDecision:
    dp: int
    tp: int
    n_devices: int

    @property
    def plan(self):
        return ShardingPlan(tp=self.tp)


def choose_layout(n_devices: int, cfg, prefer_tp: int = 16) -> ElasticDecision:
    """Largest usable (dp, tp): tp <= prefer_tp, tp | n_heads-padding works
    for any tp, so the only hard constraint is tp <= n_devices."""
    tp = min(prefer_tp, n_devices)
    while n_devices % tp:
        tp -= 1
    return ElasticDecision(dp=n_devices // tp, tp=tp, n_devices=n_devices)


def rebuild(cfg, params, plan_from: ShardingPlan, devices=None,
            prefer_tp: int = 16):
    """-> (mesh, plan, resharded_params) for the current device set."""
    devices = devices if devices is not None else jax.devices()
    dec = choose_layout(len(devices), cfg, prefer_tp)
    mesh = make_mesh((dec.dp, dec.tp), ("data", "model"),
                     devices=devices[: dec.dp * dec.tp])
    plan_to = dec.plan
    new_params = reshard_params(params, cfg, plan_from, plan_to)
    return mesh, plan_to, new_params
