"""Host-offload streaming executor — the paper's L3->L2 double buffering,
one level up the TPU hierarchy (host DRAM -> HBM).

The paper streams the NEXT transformer block's weights into on-chip memory
while the current block computes, hiding off-chip latency entirely once
aggregate on-chip memory holds one block.  Here: when a model exceeds
aggregate HBM (or HBM is reserved for KV cache), layer-group weights live
in host memory and are staged with ``jax.device_put`` one group AHEAD of
use.  ``stream_forward`` overlaps the device_put of group i+1 with compute
of group i (JAX dispatch is async; transfers and compute overlap).

Accounting: ``required_bandwidth`` tells you whether streaming can be free
(weights_bytes_per_layer / layer_compute_time <= PCIe/host-link BW) — the
same arithmetic as the paper's §V-C double-buffer analysis.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List

import jax


@dataclass
class StreamStats:
    groups: int = 0
    stage_s: float = 0.0
    compute_s: float = 0.0


class OffloadExecutor:
    """Holds stacked layer-group params on host; stages group i+1 while the
    caller computes group i."""

    def __init__(self, host_groups: List, device=None, sharding=None):
        self.host_groups = host_groups
        self.device = device
        self.sharding = sharding
        self.stats = StreamStats()

    def _put(self, tree):
        tgt = self.sharding if self.sharding is not None else self.device
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(a, tgt) if tgt is not None
            else jax.device_put(a), tree)

    def stream_forward(self, x, group_fns: List[Callable]):
        """x -> group_fns[i](x, params_i) for each i, double-buffered."""
        assert len(group_fns) == len(self.host_groups)
        t0 = time.monotonic()
        staged = self._put(self.host_groups[0])      # prologue
        self.stats.stage_s += time.monotonic() - t0
        for i, fn in enumerate(group_fns):
            nxt = None
            t0 = time.monotonic()
            if i + 1 < len(self.host_groups):
                nxt = self._put(self.host_groups[i + 1])   # async dispatch
            self.stats.stage_s += time.monotonic() - t0
            t0 = time.monotonic()
            x = fn(x, staged)
            self.stats.compute_s += time.monotonic() - t0
            staged = nxt
            self.stats.groups += 1
        return x


def required_bandwidth(bytes_per_group: float, compute_s_per_group: float):
    """Host-link bandwidth needed for free streaming (paper §V-C logic)."""
    return bytes_per_group / max(compute_s_per_group, 1e-12)
