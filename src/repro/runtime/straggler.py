"""Straggler mitigation.

Serving: **hedged execution** — if a replica misses its latency budget,
re-issue the request on another replica and take the first result (Dean's
tail-at-scale recipe).  ``HedgedRouter`` implements deadline + hedge with
pluggable replica backends (tested with synthetic delay distributions; on a
fleet, backends are per-pod serving endpoints).

Training: synchronous SPMD cannot hedge a step, so mitigation is
(a) the Heartbeat watchdog (runtime.ft) turning a wedged step into a
restart-from-checkpoint, and (b) elastic re-layout (runtime.elastic)
excluding the slow node on restart.  Both are wired into launch/train.py.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List


@dataclass
class HedgeStats:
    issued: int = 0
    hedged: int = 0
    wins_primary: int = 0
    wins_hedge: int = 0
    p50_ms: float = 0.0
    latencies: list = field(default_factory=list)


class HedgedRouter:
    """Route a request to replica i; hedge to the next replica if the
    primary hasn't answered within ``hedge_after_s``."""

    def __init__(self, replicas: List[Callable], hedge_after_s: float):
        self.replicas = replicas
        self.hedge_after = hedge_after_s
        self.stats = HedgeStats()
        self._rr = 0

    def __call__(self, request):
        t0 = time.monotonic()
        primary = self.replicas[self._rr % len(self.replicas)]
        backup = self.replicas[(self._rr + 1) % len(self.replicas)]
        self._rr += 1
        self.stats.issued += 1

        result = {}
        done = threading.Event()

        def run(fn, who):
            try:
                r = fn(request)
            except Exception:      # noqa: BLE001 — failed replica = no answer
                return
            if not done.is_set():
                result[who] = r
                done.set()

        t1 = threading.Thread(target=run, args=(primary, "primary"),
                              daemon=True)
        t1.start()
        if not done.wait(self.hedge_after):
            self.stats.hedged += 1
            t2 = threading.Thread(target=run, args=(backup, "hedge"),
                                  daemon=True)
            t2.start()
            done.wait()
        if "primary" in result:
            self.stats.wins_primary += 1
            out = result["primary"]
        else:
            self.stats.wins_hedge += 1
            out = result["hedge"]
        self.stats.latencies.append(time.monotonic() - t0)
        return out
