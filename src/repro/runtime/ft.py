"""Fault tolerance: auto-resume training supervisor + heartbeat monitor.

At fleet scale the recovery path is: a node dies -> the job controller
restarts the process group -> every worker restores the latest COMMITted
checkpoint -> training resumes (data pipeline state included, so sample
order is preserved).  This module implements the single-process slice of
that contract; ``tests/test_fault_tolerance.py`` proves it by SIGKILLing a
training subprocess mid-run and verifying bit-exact continuation.

The serving twin is ``plan_recovery``: when a replica dies without a
drain, its device state (page pools, allocator refcounts) is presumed
lost, but every request's prompt and emitted tokens live host-side in the
``Request`` objects.  ``plan_recovery`` orders the dead replica's orphans
deterministically — active slots by admission sequence, then the queue in
queue order — so ``ServingEngine.kill_replica`` re-admits them elsewhere
as prefix-cache-style re-prefills and recovery is schedule-reproducible.
"""
from __future__ import annotations

import os
import subprocess
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class FTConfig:
    max_restarts: int = 5
    restart_backoff_s: float = 1.0
    heartbeat_interval_s: float = 10.0
    heartbeat_timeout_s: float = 120.0


class Heartbeat:
    """Step-progress watchdog: if no beat arrives within the timeout (a hung
    collective / dead neighbor), ``on_stall`` fires (default: hard-exit so
    the supervisor restarts from the last checkpoint — the standard
    large-scale remedy for wedged NCCL/ICI collectives)."""

    def __init__(self, timeout_s: float, on_stall: Optional[Callable] = None):
        self.timeout = timeout_s
        self.on_stall = on_stall or (lambda: os._exit(42))
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()

    def _watch(self):
        while not self._stop.wait(self.timeout / 4):
            if time.monotonic() - self._last > self.timeout:
                self.on_stall()
                return


@dataclass
class RecoveryReport:
    """What ``plan_recovery`` decided for one dead replica: which requests
    were orphaned in flight vs. still queued, in re-admission order."""
    replica: int
    active_rids: List[int] = field(default_factory=list)
    queued_rids: List[int] = field(default_factory=list)

    @property
    def n_orphans(self) -> int:
        return len(self.active_rids) + len(self.queued_rids)


def plan_recovery(replica: int, active_admissions, queued_requests):
    """-> (requests, RecoveryReport) for a replica that died mid-flight.

    ``active_admissions`` are the replica's in-flight admissions (objects
    with ``.seq`` and ``.req``); ``queued_requests`` its not-yet-admitted
    requests.  Active requests are ordered by admission sequence (oldest
    first — they have emitted the most tokens and re-prefill the most
    state, so they re-enter the queue ahead of everything newer), then the
    queue follows in its own order.  The ordering is a pure function of
    the dead replica's state, never of dict/set iteration, so crash
    recovery replays identically under a fixed fault schedule.
    """
    active = sorted(active_admissions, key=lambda adm: adm.seq)
    requests = [adm.req for adm in active] + list(queued_requests)
    report = RecoveryReport(
        replica=replica,
        active_rids=[adm.req.rid for adm in active],
        queued_rids=[req.rid for req in queued_requests])
    return requests, report


def supervise(cmd: list, cfg: Optional[FTConfig] = None,
              env: Optional[dict] = None):
    """Restart-on-failure supervisor (the per-job controller).  Returns the
    final exit code.  Exit code 0 = done; anything else restarts (with
    backoff) up to max_restarts — resumption correctness is the trainee's
    job via --auto-resume."""
    cfg = cfg if cfg is not None else FTConfig()
    restarts = 0
    while True:
        proc = subprocess.run(cmd, env={**os.environ, **(env or {})})
        if proc.returncode == 0:
            return 0
        restarts += 1
        if restarts > cfg.max_restarts:
            return proc.returncode
        time.sleep(cfg.restart_backoff_s * restarts)
