"""Flash attention kernel (prefill/prompt mode) with causal grid pruning.

HW-codesign notes: the kv axis is the innermost sequential grid dimension;
running (m, l, acc) live in VMEM scratch across kv steps, so the S x S score
matrix never exists in HBM.  ``pl.when`` predicates skip fully-masked
(kv > q) tiles — on TPU this eliminates the 2x upper-triangle overhead the
pure-JAX scan path pays (see attention.py), which is exactly the win the
roofline §Perf log attributes to this kernel.  Sliding windows additionally
skip tiles left of the window — linear cost for SWA layers (gemma3/mixtral/
hymba local layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, bq, bkv, n_kv, seq_q, seq_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + (seq_kv - seq_q)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # tile-level pruning: skip tiles strictly above the diagonal / left of
    # the window.
    q_hi = qi * bq + bq - 1 + (seq_kv - seq_q)
    q_lo = qi * bq + (seq_kv - seq_q)
    run = True
    if causal:
        run = ki * bkv <= q_hi
    if window > 0:
        run = jnp.logical_and(run, (ki + 1) * bkv - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    bq=256, bkv=256, interpret=False):
    """q: (H, Sq, D); k/v: (H, Skv, D).  q positions align to the kv suffix."""
    H, Sq, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    bq, bkv = min(bq, Sq), min(bkv, Skv)
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0)))
    n_kv = (Skv + pkv) // bkv
    grid = (H, (Sq + pq) // bq, n_kv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, n_kv=n_kv,
                          seq_q=Sq, seq_kv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sq + pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
