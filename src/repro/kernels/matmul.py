"""Tiled MXU matmul kernel — the GEMM core of the paper's prompt mode.

HW-codesign notes (TPU v5e): MXU is a 128x128 systolic array; block shapes
are multiples of 128 so tiles map 1:1 onto MXU passes.  The K dimension is
the innermost (sequential) grid axis: partial products accumulate into a
float32 VMEM scratch tile, written back once per (m, n) tile — HBM traffic
is minimal (each A/B tile read once, C written once), the TPU analog of the
paper's "weights stationary in on-chip memory" discipline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(a, b, *, bm=256, bk=256, bn=256, interpret=False):
    """a: (M, K) @ b: (K, N) -> (M, N).  Dims must divide block shapes."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
