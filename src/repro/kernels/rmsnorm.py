"""Fused RMSNorm kernel — one HBM read + one write per row.

Unfused, RMSNorm is 3 passes (square-reduce, rsqrt-scale, multiply); fusing
keeps the row resident in VMEM: memory traffic drops 3x on a purely
bandwidth-bound op.  Rows are tiled (bs, E): E stays whole per tile (the
reduction axis must be local), bs rows amortize grid overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) *
                  (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "eps", "interpret"))
def rmsnorm(x, scale, *, bs=128, eps=1e-6, interpret=False):
    """x: (T, E); scale: (E,) -> (T, E)."""
    T, E = x.shape
    bs = min(bs, T)
    pad = (-T) % bs
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((T + pad) // bs,),
        in_specs=[
            pl.BlockSpec((bs, E), lambda i: (i, 0)),
            pl.BlockSpec((E,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs, E), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T + pad, E), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:T]
