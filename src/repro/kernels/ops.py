"""Jit'd dispatch layer over the Pallas kernels.

``use_pallas`` selects the kernel path (real TPU: compiled Mosaic; CPU
tests: interpret=True).  The default pure-JAX path is what the 512-device
dry-run lowers (Pallas TPU kernels cannot lower on a CPU-only host); on
hardware the kernels are drop-in via ``set_kernel_mode``.
"""
from __future__ import annotations

from contextlib import contextmanager


from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pl_decode
from repro.kernels.flash_attention import flash_attention as _pl_flash
from repro.kernels.matmul import matmul as _pl_matmul
from repro.kernels.rmsnorm import rmsnorm as _pl_rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _pl_ssd

_MODE = {"use_pallas": False, "interpret": True}


def set_kernel_mode(use_pallas: bool, interpret: bool = True):
    _MODE["use_pallas"] = use_pallas
    _MODE["interpret"] = interpret


@contextmanager
def kernel_mode(use_pallas: bool, interpret: bool = True):
    old = dict(_MODE)
    set_kernel_mode(use_pallas, interpret)
    try:
        yield
    finally:
        _MODE.update(old)


def matmul(a, b, **kw):
    if _MODE["use_pallas"]:
        return _pl_matmul(a, b, interpret=_MODE["interpret"], **kw)
    return ref.ref_matmul(a, b)


def rmsnorm(x, scale, **kw):
    if _MODE["use_pallas"]:
        return _pl_rmsnorm(x, scale, interpret=_MODE["interpret"], **kw)
    return ref.ref_rmsnorm(x, scale)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None, **kw):
    if _MODE["use_pallas"]:
        return _pl_flash(q, k, v, causal=causal, window=window, scale=scale,
                         interpret=_MODE["interpret"], **kw)
    return ref.ref_flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)


def decode_attention(q, k, v, length, *, scale=None, **kw):
    if _MODE["use_pallas"]:
        return _pl_decode(q, k, v, length, scale=scale,
                          interpret=_MODE["interpret"], **kw)
    return ref.ref_decode_attention(q, k, v, length, scale=scale)


def ssd_scan(x, dt, B, C, A, *, chunk=128, **kw):
    if _MODE["use_pallas"]:
        return _pl_ssd(x, dt, B, C, A, chunk=chunk,
                       interpret=_MODE["interpret"], **kw)
    y, _ = ref.ref_ssd_scan(x, dt, B, C, A)
    return y
