"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` is the mathematical definition with no tiling/blocking —
tests sweep shapes/dtypes and assert the Pallas kernels (interpret=True on
CPU) match these within dtype-appropriate tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def ref_rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) *
            (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def ref_flash_attention(q, k, v, causal=True, window=0, scale=None):
    """q: (H, Sq, D), k/v: (H, Skv, D) -> (H, Sq, D)."""
    H, Sq, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (q suffix of kv)
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ref_decode_attention(q, k, v, length, scale=None):
    """q: (B, H, D); k/v: (B, H, S, D); length: (B,) valid prefix lengths."""
    B, H, D = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < length[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def ref_verify_attention(q, k, v, length, scale=None):
    """Speculative-verify oracle.  q: (B, H, Q, D); k/v: (B, H, S, D);
    length: (B,) valid tokens ahead of query 0 (query i additionally sees
    the i drafted positions length..length+i-1, mirroring the paged verify
    kernel's ``kpos < length + qpos`` mask)."""
    B, H, Q, D = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < \
        (length[:, None] + jnp.arange(Q)[None, :])[:, :, None]
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ref_dequant_pool(pool, scales):
    """Dequantize an int8 page pool through its per-(page, slot) scales.

    pool: (n_pages, H, psz, D) int8; scales: (n_pages, psz) float32
    -> (n_pages, H, psz, D) float32.  The oracle counterpart of the
    dequant-on-read step inside the int8 paged kernels.
    """
    return pool.astype(jnp.float32) * scales[:, None, :, None]


def ref_dequant_state(state, scales):
    """Dequantize an int8 SSD state slab: (H, P, N) int8 x (H,) float32."""
    return state.astype(jnp.float32) * scales[:, None, None]


def ref_ssd_scan(x, dt, B, C, A, state0=None):
    """Sequential SSD reference.  x: (S, H, P), dt: (S, H), B/C: (S, N),
    A: (H,) negative.  Returns (y (S,H,P), final_state (H,P,N))."""
    S, H, P = x.shape
    N = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dec = jnp.exp(dtt * A)[:, None, None]            # (H,1,1)
        h = h * dec + (dtt[:, None] * xt)[:, :, None] * bt[None, None, :]
        y = jnp.einsum("n,hpn->hp", ct, h)
        return h, y

    h0 = jnp.zeros((H, P, N), jnp.float32) if state0 is None else state0
    hT, ys = jax.lax.scan(step, h0, (xf, dtf, Bf, Cf))
    return ys.astype(x.dtype), hT
