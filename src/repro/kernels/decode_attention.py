"""Flash-decode kernel — the paper's autoregressive (GEMV) hot spot on TPU.

One new token attends to a long KV cache: arithmetic intensity ~1 FLOP/byte,
purely HBM-bandwidth-bound (the TPU analog of the paper's L3-bound GEMV
regime).  The kernel streams K/V through VMEM in (bkv, D) tiles on the
sequential grid axis with online-softmax scratch carries, touching each
cache byte exactly once; batch*heads ride the parallel grid axes.  Length
masking handles ragged prefixes (continuous batching).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale, bkv, n_kv):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]

    @pl.when(ki * bkv < length)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0, 0][None], k_ref[0, 0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (1, bkv)
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bkv", "interpret"))
def decode_attention(q, k, v, length, *, scale=None, bkv=512,
                     interpret=False):
    """q: (B, H, D); k/v: (B, H, S, D); length: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    bkv = min(bkv, S)
    pkv = (-S) % bkv
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    n_kv = (S + pkv) // bkv
    grid = (B, H, n_kv)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bkv=bkv, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k, v)


# ---------------------------------------------------------------------------
# Paged decode: K/V pages streamed through a scalar-prefetched block table
# ---------------------------------------------------------------------------

def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale, psz, n_max):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(ki * psz < length)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0, 0][None], k_ref[0, 0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (1, psz)
        kpos = ki * psz + jax.lax.broadcasted_iota(jnp.int32, (1, psz), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        m_ref[...] = m_new

    @pl.when(ki == n_max - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def _paged_decode_kernel_i8(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref,
                            vs_ref, o_ref, acc_ref, m_ref, l_ref, *, scale,
                            psz, n_max):
    """int8 page variant: dequantize k/v in-register through the page's
    per-row scales ((psz,) each) — the pool still streams off-chip at one
    byte per element, the scales add 4 bytes per row."""
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(ki * psz < length)
    def _compute():
        kf = k_ref[0, 0].astype(jnp.float32) * ks_ref[0][:, None]  # (psz, D)
        vf = v_ref[0, 0].astype(jnp.float32) * vs_ref[0][:, None]
        s = jax.lax.dot_general(
            q_ref[0, 0].astype(jnp.float32)[None], kf,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale            # (1, psz)
        kpos = ki * psz + jax.lax.broadcasted_iota(jnp.int32, (1, psz), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, vf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        m_ref[...] = m_new

    @pl.when(ki == n_max - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_table, length, *,
                           scale=None, interpret=False,
                           k_scale=None, v_scale=None):
    """Decode attention over a paged KV pool.

    q: (B, H, D); k_pages/v_pages: (n_pages, H, psz, D);
    block_table: (B, n_max) int32 page ids; length: (B,) -> (B, H, D).
    ``k_scale``/``v_scale`` ((n_pages, psz) float32) select the int8
    dequant-on-read kernel variant.

    ``length`` counts valid tokens (positions < length attend), matching the
    contiguous kernel above — NOT the inclusive current-position convention
    of ``core.attention`` decode paths.  When driving this from the engine's
    ``pos`` array (position of the just-written token), pass ``pos + 1``.

    The sequential grid axis walks each sequence's block table; the page id
    is scalar-prefetched so the next page's DMA is issued with the gathered
    address — no materialized contiguous copy of the cache (the same
    minimal-off-chip-traffic discipline as the paper's L3-resident GEMV,
    with the pool standing in for on-chip K/V).
    """
    B, H, D = q.shape
    n_pages, Hk, psz, _ = k_pages.shape
    assert Hk == H, (Hk, H)
    n_max = block_table.shape[1]
    scale = scale if scale is not None else D ** -0.5
    grid = (B, H, n_max)
    in_specs = [
        pl.BlockSpec((1, 1, D), lambda b, h, j, bt, ln: (b, h, 0)),
        pl.BlockSpec((1, 1, psz, D),
                     lambda b, h, j, bt, ln: (bt[b, j], h, 0, 0)),
        pl.BlockSpec((1, 1, psz, D),
                     lambda b, h, j, bt, ln: (bt[b, j], h, 0, 0)),
    ]
    inputs = (block_table, length, q, k_pages, v_pages)
    if k_scale is not None:
        assert k_pages.dtype == jnp.int8, k_pages.dtype
        in_specs += [
            pl.BlockSpec((1, psz), lambda b, h, j, bt, ln: (bt[b, j], 0)),
            pl.BlockSpec((1, psz), lambda b, h, j, bt, ln: (bt[b, j], 0)),
        ]
        inputs += (k_scale, v_scale)
        kernel = functools.partial(_paged_decode_kernel_i8, scale=scale,
                                   psz=psz, n_max=n_max)
    else:
        kernel = functools.partial(_paged_decode_kernel, scale=scale,
                                   psz=psz, n_max=n_max)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, bt, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(*inputs)


# ---------------------------------------------------------------------------
# Paged verify: a small query block per slot (speculative decoding)
# ---------------------------------------------------------------------------

def _paged_verify_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale, psz, n_max, nq):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    # query i sees positions < length + i; the deepest query gates the page
    @pl.when(ki * psz < length + nq - 1)
    def _compute():
        s = jax.lax.dot_general(
            q_ref[0, 0], k_ref[0, 0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (nq, psz)
        kpos = ki * psz + jax.lax.broadcasted_iota(jnp.int32, (nq, psz), 1)
        qpos = jax.lax.broadcasted_iota(jnp.int32, (nq, psz), 0)
        mask = kpos < length + qpos
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_max - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


def _paged_verify_kernel_i8(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref,
                            vs_ref, o_ref, acc_ref, m_ref, l_ref, *, scale,
                            psz, n_max, nq):
    """int8 page variant of the verify kernel (see decode's i8 twin)."""
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(ki * psz < length + nq - 1)
    def _compute():
        kf = k_ref[0, 0].astype(jnp.float32) * ks_ref[0][:, None]  # (psz, D)
        vf = v_ref[0, 0].astype(jnp.float32) * vs_ref[0][:, None]
        s = jax.lax.dot_general(
            q_ref[0, 0].astype(jnp.float32), kf, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale          # (nq, psz)
        kpos = ki * psz + jax.lax.broadcasted_iota(jnp.int32, (nq, psz), 1)
        qpos = jax.lax.broadcasted_iota(jnp.int32, (nq, psz), 0)
        mask = kpos < length + qpos
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, vf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_max - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_verify_attention(q, k_pages, v_pages, block_table, length, *,
                           scale=None, interpret=False,
                           k_scale=None, v_scale=None):
    """Verify attention over a paged KV pool: Q queries per slot in one pass.

    q: (B, H, Q, D) — query i of slot b sits at absolute position
    ``length[b] - 1 + i`` (query 0 is the last accepted token, queries
    1..Q-1 are drafted tokens whose KV the caller already wrote);
    k_pages/v_pages: (n_pages, H, psz, D); block_table: (B, n_max);
    length: (B,) valid tokens ahead of query 0 (pass ``pos + 1``, as in
    ``paged_decode_attention``) -> (B, H, Q, D).

    Per-query masking ``kpos < length + qpos`` gives each draft query its
    causal prefix (draft j's KV sits at stream position length - 1 + j).
    Same page streaming as the decode kernel — each cache byte still moves
    off-chip once per step, now amortized over Q scored positions: the
    bandwidth-bound speculation argument.
    """
    B, H, nq, D = q.shape
    n_pages, Hk, psz, _ = k_pages.shape
    assert Hk == H, (Hk, H)
    n_max = block_table.shape[1]
    scale = scale if scale is not None else D ** -0.5
    grid = (B, H, n_max)
    in_specs = [
        pl.BlockSpec((1, 1, nq, D), lambda b, h, j, bt, ln: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, psz, D),
                     lambda b, h, j, bt, ln: (bt[b, j], h, 0, 0)),
        pl.BlockSpec((1, 1, psz, D),
                     lambda b, h, j, bt, ln: (bt[b, j], h, 0, 0)),
    ]
    inputs = (block_table, length, q, k_pages, v_pages)
    if k_scale is not None:
        assert k_pages.dtype == jnp.int8, k_pages.dtype
        in_specs += [
            pl.BlockSpec((1, psz), lambda b, h, j, bt, ln: (bt[b, j], 0)),
            pl.BlockSpec((1, psz), lambda b, h, j, bt, ln: (bt[b, j], 0)),
        ]
        inputs += (k_scale, v_scale)
        kernel = functools.partial(_paged_verify_kernel_i8, scale=scale,
                                   psz=psz, n_max=n_max, nq=nq)
    else:
        kernel = functools.partial(_paged_verify_kernel, scale=scale,
                                   psz=psz, n_max=n_max, nq=nq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, nq, D),
                               lambda b, h, j, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nq, D), jnp.float32),
            pltpu.VMEM((nq,), jnp.float32),
            pltpu.VMEM((nq,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, nq, D), q.dtype),
        interpret=interpret,
    )(*inputs)
