"""Chunked SSD scan kernel (mamba2) — state carried across a sequential grid.

HW-codesign notes: TPU grid dimensions execute sequentially, so the running
SSM state (H, P, N) lives in a float32 VMEM scratch that persists across
chunk steps — the recurrence never round-trips HBM.  Each grid step loads
one (Q, ...) chunk of x/dt/B/C, computes the intra-chunk quadratic term on
the MXU and the inter-chunk term from the carried state, then updates the
state.  This is the TPU adaptation of the paper's "weights/state stationary
on-chip" principle applied to SSD: HBM traffic is exactly one read of the
inputs + one write of y per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_ref, *,
                nc: int):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    _ssd_body(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_ref)


def _ssd_body(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_ref):
    x = x_ref[...].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[...].astype(jnp.float32)        # (Q, H)
    B = b_ref[...].astype(jnp.float32)          # (Q, N)
    C = c_ref[...].astype(jnp.float32)          # (Q, N)
    A = a_ref[...].astype(jnp.float32)          # (H,)
    Q = x.shape[0]

    a = dt * A[None, :]                          # (Q, H)
    cs = jnp.cumsum(a, axis=0)
    cs_last = cs[-1]                             # (H,)

    # intra-chunk (quadratic) term
    G = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    seg = cs[:, None, :] - cs[None, :, :]                     # (Q, Q, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((ii >= jj)[:, :, None], jnp.exp(seg), 0.0)
    W = G[:, :, None] * L                                     # (Q, Q, H)
    xdt = x * dt[:, :, None]                                  # (Q, H, P)
    y = jnp.einsum("ijh,jhp->ihp", W, xdt)

    # inter-chunk term from the carried state
    S_prev = st_ref[...]                                      # (H, P, N)
    y += jnp.einsum("jn,hpn->jhp", C, S_prev) * jnp.exp(cs)[:, :, None]

    # state update
    decay_to_end = jnp.exp(cs_last[None, :] - cs)             # (Q, H)
    contrib = jnp.einsum("jh,jn,jhp->hpn", decay_to_end * dt, B, x)
    st_ref[...] = S_prev * jnp.exp(cs_last)[:, None, None] + contrib

    y_ref[...] = y.astype(y_ref.dtype)


def _ssd_kernel_i8(x_ref, dt_ref, b_ref, c_ref, a_ref, s0_ref, s0s_ref,
                   y_ref, st_ref, *, nc: int):
    """Variant seeded from an int8 state slab: the initial state is
    dequantized in-register through its per-head scale — the slab's HBM
    traffic stays at one byte per element (the quantized-pool serving
    path's state restore)."""
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = (s0_ref[...].astype(jnp.float32) *
                       s0s_ref[...][:, None, None])

    _ssd_body(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_ref)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, B, C, A, *, chunk=128, interpret=False,
             state0=None, state0_scale=None):
    """x: (S, H, P); dt: (S, H); B/C: (S, N); A: (H,) -> y (S, H, P).

    (The D*x skip term and gating are applied by the caller; S % chunk == 0
    is required — pad upstream.)

    ``state0``/``state0_scale`` ((H, P, N) int8 + (H,) float32): seed the
    scan from a quantized state slab, dequantized in-register at init.
    """
    S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    in_specs = [
        pl.BlockSpec((chunk, H, P), lambda c: (c, 0, 0)),
        pl.BlockSpec((chunk, H), lambda c: (c, 0)),
        pl.BlockSpec((chunk, N), lambda c: (c, 0)),
        pl.BlockSpec((chunk, N), lambda c: (c, 0)),
        pl.BlockSpec((H,), lambda c: (0,)),
    ]
    inputs = (x, dt, B, C, A)
    if state0 is not None:
        assert state0.dtype == jnp.int8, state0.dtype
        in_specs += [
            pl.BlockSpec((H, P, N), lambda c: (0, 0, 0)),
            pl.BlockSpec((H,), lambda c: (0,)),
        ]
        inputs += (state0, state0_scale)
        kernel = functools.partial(_ssd_kernel_i8, nc=nc)
    else:
        kernel = functools.partial(_ssd_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((chunk, H, P), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(*inputs)
