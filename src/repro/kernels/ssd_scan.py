"""Chunked SSD scan kernel (mamba2) — state carried across a sequential grid.

HW-codesign notes: TPU grid dimensions execute sequentially, so the running
SSM state (H, P, N) lives in a float32 VMEM scratch that persists across
chunk steps — the recurrence never round-trips HBM.  Each grid step loads
one (Q, ...) chunk of x/dt/B/C, computes the intra-chunk quadratic term on
the MXU and the inter-chunk term from the carried state, then updates the
state.  This is the TPU adaptation of the paper's "weights/state stationary
on-chip" principle applied to SSD: HBM traffic is exactly one read of the
inputs + one write of y per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_ref, *,
                nc: int):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[...].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[...].astype(jnp.float32)        # (Q, H)
    B = b_ref[...].astype(jnp.float32)          # (Q, N)
    C = c_ref[...].astype(jnp.float32)          # (Q, N)
    A = a_ref[...].astype(jnp.float32)          # (H,)
    Q = x.shape[0]

    a = dt * A[None, :]                          # (Q, H)
    cs = jnp.cumsum(a, axis=0)
    cs_last = cs[-1]                             # (H,)

    # intra-chunk (quadratic) term
    G = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    seg = cs[:, None, :] - cs[None, :, :]                     # (Q, Q, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((ii >= jj)[:, :, None], jnp.exp(seg), 0.0)
    W = G[:, :, None] * L                                     # (Q, Q, H)
    xdt = x * dt[:, :, None]                                  # (Q, H, P)
    y = jnp.einsum("ijh,jhp->ihp", W, xdt)

    # inter-chunk term from the carried state
    S_prev = st_ref[...]                                      # (H, P, N)
    y += jnp.einsum("jn,hpn->jhp", C, S_prev) * jnp.exp(cs)[:, :, None]

    # state update
    decay_to_end = jnp.exp(cs_last[None, :] - cs)             # (Q, H)
    contrib = jnp.einsum("jh,jn,jhp->hpn", decay_to_end * dt, B, x)
    st_ref[...] = S_prev * jnp.exp(cs_last)[:, None, None] + contrib

    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, B, C, A, *, chunk=128, interpret=False):
    """x: (S, H, P); dt: (S, H); B/C: (S, N); A: (H,) -> y (S, H, P).

    (The D*x skip term and gating are applied by the caller; S % chunk == 0
    is required — pad upstream.)
    """
    S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    return pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((chunk, H, P), lambda c: (c, 0, 0)),
            pl.BlockSpec((chunk, H), lambda c: (c, 0)),
            pl.BlockSpec((chunk, N), lambda c: (c, 0)),
            pl.BlockSpec((chunk, N), lambda c: (c, 0)),
            pl.BlockSpec((H,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((chunk, H, P), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A)
