"""Gradient compression for the cross-pod (slow-link) reduction hop.

int8 block-quantized all-reduce with error feedback: the quantization
residual is carried into the next step, so compression introduces no
asymptotic bias (Seide et al. / EF-SGD).  Applied ONLY to the outer
(cross-pod) hop of the hierarchical reduction — the in-pod ICI hop stays
full precision, mirroring the paper's "cheap local / expensive global"
traffic split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as cc

BLOCK = 256


def _quantize(x):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def _dequantize(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def _common_scale(x, axes, tag):
    """Per-block scale agreed across the axis (tiny pmax, exact)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    local = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = cc.psum_max(local, axes, tag + "/scale") / 127.0 + 1e-12
    return blocks, scale, pad


def compressed_psum(x, axes, tag: str):
    """Common-scale int8 all-reduce: pmax scales (tiny) -> quantize with the
    SHARED scale -> sum int32 -> dequantize.  Exact up to quantization; wire
    bytes ~1/4 of bf16 (int8 payload dominates, int32 on-wire modeled
    conservatively by the ledger via the int32 dtype)."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    blocks, scale, pad = _common_scale(x, axes, tag)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    qs = cc.psum(q.astype(jnp.int32), axes, tag + "/q8")
    return _dequantize(qs, scale, pad, x.shape)


def make_ef_grad_reducer(inner_axes=("data",), outer_axes=("pod",)):
    """Returns (reduce_fn(grads, error_state) -> (grads, error_state), init).

    In-pod: exact psum_scatter/all_gather.  Cross-pod: int8+EF.
    """
    def init(grads):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def reduce(grads, err):
        def leaf(g, e):
            # in-pod first: exact, fast ICI
            g32 = cc.psum(g.astype(jnp.float32), inner_axes, "dp/inpod") + e
            blocks, scale, pad = _common_scale(g32, outer_axes, "dp/xpod")
            q = jnp.clip(jnp.round(blocks / scale), -127, 127)
            deq_local = _dequantize(q.astype(jnp.int8), scale, pad, g32.shape)
            new_err = g32 - deq_local                     # error feedback
            qs = cc.psum(q.astype(jnp.int32), outer_axes, "dp/xpod_q8")
            return _dequantize(qs, scale, pad, g32.shape).astype(g.dtype), \
                new_err
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(err)
        out = [leaf(g, e) for g, e in zip(flat_g, flat_e, strict=True)]
        return (jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
                jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]))

    return reduce, init
