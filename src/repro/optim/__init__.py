from repro.optim.adamw import AdamWConfig, adamw_leaf, adamw_update, \
    cosine_schedule, global_norm, init_opt_state  # noqa: F401
