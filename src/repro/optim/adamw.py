"""AdamW with decoupled weight decay + global-norm clipping (pure JAX).

Optimizer states mirror parameter sharding exactly (same PartitionSpecs),
so the update is collective-free: every device updates only the shards it
owns — optimizer memory follows the paper's zero-duplication property.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable] = None     # step -> lr multiplier


def init_opt_state(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def adamw_leaf(p, g, m, v, step, scale, lr, cfg: AdamWConfig):
    """One AdamW leaf/chunk update (shared by the replicated and ZeRO-1
    paths; ``scale`` is the global clip factor, ``step`` is post-increment)."""
    g = g.astype(jnp.float32) * scale
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
    vh = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
    delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
        p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2


def adamw_update(params, grads, opt, cfg: AdamWConfig):
    """-> (new_params, new_opt, stats). Elementwise; sharding-preserving."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule else 1.0)

    def upd(p, g, m, v):
        return adamw_leaf(p, g, m, v, step, scale, lr, cfg)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(warmup: int, total: int, min_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return f
