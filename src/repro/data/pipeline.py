"""Data pipeline: deterministic synthetic corpus + packing + host prefetch.

Production-shaped even though the corpus is synthetic (no datasets ship in
this container): documents are sampled from a Zipfian unigram model with
document structure, packed into fixed-length training sequences with EOS
separators, sharded per data-parallel rank, and prefetched on a background
thread (the host-side analog of the paper's double-buffered weight
streaming — batch i+1 is staged while step i runs).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 1
    zipf_a: float = 1.2
    mean_doc_len: int = 384


class SyntheticCorpus:
    """Deterministic, seekable token stream (resume-friendly: state is a
    single document index, saved in checkpoints)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf-ish unigram distribution over the vocab
        ranks = np.arange(2, cfg.vocab_size, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self._p = p / p.sum()
        self._ids = np.arange(2, cfg.vocab_size)

    def document(self, idx: int) -> np.ndarray:
        rng = np.random.RandomState((self.cfg.seed * 1_000_003 + idx)
                                    % (2 ** 31 - 1))
        n = max(8, int(rng.exponential(self.cfg.mean_doc_len)))
        toks = rng.choice(self._ids, size=n, p=self._p)
        # inject local structure (bigram repeats) so loss can actually drop
        rep = rng.randint(2, 8)
        toks[rep::rep] = toks[:-rep:rep]
        return np.concatenate([toks, [self.cfg.eos_id]]).astype(np.int32)


class PackedBatches:
    """Packs documents into (global_batch, seq_len+1) token blocks."""

    def __init__(self, cfg: DataConfig, start_doc: int = 0,
                 buf=None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.doc_idx = start_doc
        self._buf = np.asarray(buf if buf is not None else [], np.int32)

    def state(self) -> dict:
        """Exact resume cursor: document index + the partial-document
        buffer (so a restored run replays the identical token stream)."""
        return {"doc_idx": self.doc_idx, "buf": self._buf.tolist()}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        need = self.cfg.global_batch * (self.cfg.seq_len + 1)
        while self._buf.size < need:
            self._buf = np.concatenate(
                [self._buf, self.corpus.document(self.doc_idx)])
            self.doc_idx += 1
        block = self._buf[:need].reshape(self.cfg.global_batch,
                                         self.cfg.seq_len + 1)
        self._buf = self._buf[need:]
        return {"tokens": block[:, :-1].copy(),
                "labels": block[:, 1:].copy()}


class Prefetcher:
    """Background-thread prefetch with bounded depth."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_pipeline(cfg: DataConfig, start_doc: int = 0, prefetch: int = 2,
                  buf=None):
    src = PackedBatches(cfg, start_doc=start_doc, buf=buf)
    return src, Prefetcher(iter(src), depth=prefetch)
