from repro.data.pipeline import DataConfig, PackedBatches, Prefetcher, \
    SyntheticCorpus, make_pipeline  # noqa: F401
