"""Mamba-2 SSD (state-space duality) blocks — chunked scan + decode step.

Per-shard shapes (heads sharded on the model axis per the paper's
head-parallel partitioning; SSD heads are mutually independent exactly like
attention heads):

    x  : (B, S, H, P)   local heads H, head dim P
    dt : (B, S, H)      softplus-activated step sizes
    Bm, Cm : (B, S, N)  state projections (n_groups=1 -> shared per shard)
    A  : (H,)           negative per-head decay
    state : (B, H, P, N)

The chunked algorithm is exact (not an approximation): intra-chunk quadratic
term + inter-chunk state recurrence under ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_conv(x, w, state=None, tail_idx=None):
    """Depthwise causal conv.  x: (B, S, C), w: (C, K).
    state: (B, K-1, C) previous inputs (decode) or None (prefill).
    tail_idx: scalar index of the last *valid* input row — the returned
    state is the K-1 inputs ending there (inclusive), so a chunk whose
    tail is padding (chunked prefill past the prompt's end) still hands
    the next step the true conv history.  None = S - 1 (all rows valid).
    Returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[1]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + S, :] * w[:, i].astype(x.dtype) for i in range(K))
    if K == 1:
        return y, jnp.zeros((B, 0, C), x.dtype)
    if tail_idx is None:
        new_state = xp[:, -(K - 1):, :]
    else:
        # input row s sits at xp index K-1+s; the K-1 rows ending at
        # tail_idx inclusive are xp[tail_idx+1 : tail_idx+K]
        new_state = jax.lax.dynamic_slice_in_dim(xp, tail_idx + 1, K - 1,
                                                 axis=1)
    return y, new_state


def ssd_chunked(x, dt, Bm, Cm, A, D, chunk: int, state0=None,
                return_extras: bool = False):
    """Exact chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N)).

    With ``return_extras``: also (cum_decay (B,S,H) = exp(prefix-sum of a),
    total_decay (B,H)) — the linear-correction terms context parallelism
    needs to fold an upstream shard's incoming state into local outputs:
        y(state_in) = y(0) + (C_t . state_in) * cum_decay_t
        state_out   = state_local + total_decay * state_in
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc, Q = Sp // chunk, chunk

    xf = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, Q, H)
    Bf = Bm.astype(jnp.float32).reshape(B, nc, Q, N)
    Cf = Cm.astype(jnp.float32).reshape(B, nc, Q, N)
    Af = A.astype(jnp.float32)

    a = dtf * Af                                   # (B,nc,Q,H), <= 0
    cs = jnp.cumsum(a, axis=2)                     # inclusive
    cs_last = cs[:, :, -1]                         # (B,nc,H)

    # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) exp(cs_i-cs_j) dt_j x_j
    G = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)      # (B,nc,Q,Q)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,nc,Q,Q,H) i,j
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    W = G[..., None] * L                           # (B,nc,Q,Q,H)
    xdt = xf * dtf[..., None]                      # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xdt)

    # chunk state contributions: sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cs_last[:, :, None, :] - cs)       # (B,nc,Q,H)
    contrib = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                         decay_to_end * dtf, Bf, xf)          # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cs_last)                            # (B,nc,H)

    def step(S_prev, inp):
        dec, con = inp                                        # (B,H), (B,H,P,N)
        S_new = S_prev * dec[..., None, None] + con
        return S_new, S_prev

    S0 = (jnp.zeros((B, H, P, N), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))
    S_final, S_prevs = jax.lax.scan(
        step, S0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(contrib, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                     # (B,nc,H,P,N)

    # inter-chunk: y[i] += C_i . (exp(cs_i) * S_prev)
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cf, S_prevs) * \
        jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    y = y + xf.reshape(B, Sp, H, P)[:, :S] * D.astype(jnp.float32)[None, None, :, None]
    if return_extras:
        # global prefix-sum of a across the whole local sequence
        chunk_prefix = jnp.cumsum(cs_last, axis=1) - cs_last   # (B,nc,H)
        cum_a = cs + chunk_prefix[:, :, None, :]               # (B,nc,Q,H)
        cum_decay = jnp.exp(cum_a).reshape(B, Sp, H)[:, :S]
        total_decay = jnp.exp(chunk_prefix[:, -1] + cs_last[:, -1])
        return y.astype(x.dtype), S_final, cum_decay, total_decay
    return y.astype(x.dtype), S_final


def ssd_decode_step(x, dt, Bm, Cm, A, D, state):
    """One token.  x: (B,H,P) dt: (B,H) Bm/Cm: (B,N) state: (B,H,P,N)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A.astype(jnp.float32))                # (B,H)
    contrib = jnp.einsum("bh,bn,bhp->bhpn", dtf, Bm.astype(jnp.float32), xf)
    state = state * dec[..., None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), state
