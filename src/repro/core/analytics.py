"""Analytic FLOP/byte model — the roofline's compute & memory terms.

``cost_analysis()`` on a scanned module reports ONE iteration of each
``while`` loop (verified experimentally), so scanned layer stacks would be
undercounted ~n_layers x.  This module therefore derives per-device FLOPs
and HBM bytes *analytically* from (config x shape x plan) — exact for the
ops we emit, including padding waste, the scan-flash causal 2x overhead,
MoE capacity factors and KV traffic.  ``tests/test_analytics.py`` validates
it against ``cost_analysis`` on small UNROLLED modules.

All numbers are PER DEVICE per step.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import (ATTN_WINDOW, FFN_DENSE, FFN_MOE, MIX_ATTN,
                                MIX_HYBRID, MIX_SSM, ModelConfig,
                                ShapeConfig)
from repro.core.partition import ShardingPlan, dim_layout, model_layout

BF16 = 2
F32 = 4

# scan-based flash attention computes all kv chunks for full-causal layers
CAUSAL_SCAN_WASTE = 2.0
MOE_CAPACITY = 1.25


@dataclass
class Cost:
    flops: dict = field(default_factory=dict)    # category -> flops/device
    bytes_hbm: dict = field(default_factory=dict)

    def add_flops(self, cat, n):
        self.flops[cat] = self.flops.get(cat, 0.0) + float(n)

    def add_bytes(self, cat, n):
        self.bytes_hbm[cat] = self.bytes_hbm.get(cat, 0.0) + float(n)

    @property
    def total_flops(self):
        return sum(self.flops.values())

    @property
    def total_bytes(self):
        return sum(self.bytes_hbm.values())

    def merged(self, other, scale=1.0):
        out = Cost(dict(self.flops), dict(self.bytes_hbm))
        for k, v in other.flops.items():
            out.flops[k] = out.flops.get(k, 0.0) + v * scale
        for k, v in other.bytes_hbm.items():
            out.bytes_hbm[k] = out.bytes_hbm.get(k, 0.0) + v * scale
        return out


def _mm(cost, cat, m, k, n, w_dtype=BF16, count=1.0):
    """One matmul (m,k)@(k,n): flops + operand/result HBM traffic.
    The (k,n) operand is the WEIGHT (read at w_dtype); activations at bf16.
    Weight traffic is therefore counted exactly once per use — there is no
    separate blanket weights category."""
    cost.add_flops(cat, 2.0 * m * k * n * count)
    cost.add_bytes(cat, ((m * k + m * n) * BF16 + k * n * w_dtype) * count)


def layer_cost(cfg: ModelConfig, plan: ShardingPlan, spec, B: int, S: int,
               mode: str, kv_len: int) -> Cost:
    """One layer, per device.  B = local batch, S = tokens this step,
    kv_len = attention span (cache length for decode)."""
    lay = model_layout(cfg, plan)
    c = Cost()
    E = cfg.d_model
    d = cfg.head_dim_
    T = B * S
    wdt = 1 if plan.weight_dtype == "int8" else BF16

    # ---- attention ----------------------------------------------------------
    if spec.mixer in (MIX_ATTN, MIX_HYBRID):
        hl = lay.attn
        hq, nkv = hl.hq_loc, hl.n_kv_loc
        _mm(c, "qkvo", T, E, hq * d, w_dtype=wdt)                       # wq
        _mm(c, "qkvo", T, E, nkv * d, w_dtype=wdt, count=2.0)           # wk, wv
        _mm(c, "qkvo", T, hq * d, E, w_dtype=wdt)                       # wo
        if mode == "decode":
            span = min(kv_len, cfg.sliding_window) if \
                spec.attn == ATTN_WINDOW and cfg.sliding_window else kv_len
            c.add_flops("attn", 2.0 * B * hq * span * d * 2)
            kv_bytes = np.dtype(plan.kv_cache_dtype).itemsize
            ndp = 1
            if plan.seq_shard_kv:
                ndp = _ndp(plan)
            c.add_bytes("kv_cache", 2.0 * B * nkv * (span / ndp) * d * kv_bytes)
        else:
            if spec.attn == ATTN_WINDOW and cfg.sliding_window and \
                    S > cfg.sliding_window:
                span = cfg.sliding_window + 512            # + q-block slack
                c.add_flops("attn", 2.0 * B * hq * S * span * d * 2)
            else:
                waste = CAUSAL_SCAN_WASTE if (cfg.causal and S > 1024) else 1.0
                if plan.attn_scheme == "split" and cfg.causal and S > 1024:
                    waste = 4.0 / 3.0
                c.add_flops("attn", 2.0 * B * hq * S * kv_len * d * waste)
            c.add_bytes("attn_io", T * (hq + 2 * nkv) * d * BF16 * 2)
            if mode == "prefill":
                c.add_bytes("kv_cache", 2.0 * T * nkv * d *
                            np.dtype(plan.kv_cache_dtype).itemsize)

    # ---- cross attention ----------------------------------------------------
    if spec.cross_attn:
        hl = lay.attn
        hq, nkv = hl.hq_loc, hl.n_kv_loc
        _mm(c, "qkvo", T, E, hq * d, w_dtype=wdt)
        _mm(c, "qkvo", T, hq * d, E, w_dtype=wdt)
        Senc = cfg.enc_seq_len if mode == "decode" else kv_len
        if mode != "decode":
            _mm(c, "qkvo", B * Senc, E, nkv * d, w_dtype=wdt, count=2.0)
        c.add_flops("attn", 2.0 * B * hq * S * Senc * d * 2)

    # ---- SSD ---------------------------------------------------------------
    if spec.mixer in (MIX_SSM, MIX_HYBRID):
        sl = lay.ssm
        H = sl.hq_loc
        P = cfg.ssm_head_dim
        N = cfg.ssm_state
        _mm(c, "ssm_proj", T, E, 2 * H * P, w_dtype=wdt)                # in_z, in_x
        _mm(c, "ssm_proj", T, E, 2 * N + H, w_dtype=wdt)                # B, C, dt (replicated)
        _mm(c, "ssm_proj", T, H * P, E, w_dtype=wdt)                    # out
        if mode == "decode":
            c.add_flops("ssd", B * H * P * N * 4.0)
            c.add_bytes("ssd_state", B * H * P * N * F32 * 2)
        else:
            Q = cfg.ssm_chunk
            nc_ = -(-S // Q)
            # intra: G (Q^2 N) + W*xdt (Q^2 H P) ; inter: Q N H P
            c.add_flops("ssd", B * nc_ * (2.0 * Q * Q * N +
                                          2.0 * Q * Q * H * P +
                                          4.0 * Q * N * H * P))

    # ---- FFN ----------------------------------------------------------------
    nmat = 3 if cfg.gated_ffn else 2
    if spec.ffn == FFN_DENSE:
        f_loc = dim_layout(spec.d_ff, plan.tp).loc
        _mm(c, "ffn", T, E, f_loc, w_dtype=wdt, count=nmat - 1)
        _mm(c, "ffn", T, f_loc, E, w_dtype=wdt)
    elif spec.ffn == FFN_MOE:
        cap = max(1, int(MOE_CAPACITY * T * cfg.top_k / cfg.n_experts))
        if plan.moe_mode == "ep":
            n_loc = cfg.n_experts // plan.tp
            ftot = cfg.moe_d_ff
            _mm(c, "moe", n_loc * cap, E, ftot, w_dtype=wdt, count=nmat - 1)
            _mm(c, "moe", n_loc * cap, ftot, E, w_dtype=wdt)
        else:
            ef = lay.moe_ffn.loc
            _mm(c, "moe", cfg.n_experts * cap, E, ef, w_dtype=wdt, count=nmat - 1)
            _mm(c, "moe", cfg.n_experts * cap, ef, E, w_dtype=wdt)
        c.add_flops("moe_router", 2.0 * T * E * cfg.n_experts)
        if cfg.n_shared_experts:
            sf = lay.shared_ffn.loc
            _mm(c, "ffn", T, E, sf, w_dtype=wdt, count=nmat - 1)
            _mm(c, "ffn", T, sf, E, w_dtype=wdt)

    # ---- norms / residuals (bandwidth only) ---------------------------------
    c.add_bytes("elementwise", 8.0 * T * E * BF16)
    c.add_flops("elementwise", 10.0 * T * E)
    return c


def _ndp(plan):
    # data-parallel degree is resolved by the caller via mesh sizes; the
    # plan-level fallback assumes the production 16-way data axis.
    return 16 * (2 if len(plan.dp_axes) > 1 else 1)


def step_cost(cfg: ModelConfig, plan: ShardingPlan, shape: ShapeConfig,
              mesh_sizes: dict) -> Cost:
    """Full per-device cost of one step of this cell."""
    ndp = int(np.prod([mesh_sizes.get(a, 1) for a in plan.dp_axes]))
    ncp = int(np.prod([mesh_sizes.get(a, 1) for a in plan.cp_axes]))
    B_glob, S_cell = shape.global_batch, shape.seq_len
    if plan.seq_shard_kv:
        B = B_glob
    else:
        B = max(1, B_glob // ndp)
    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]
    S = 1 if mode == "decode" else S_cell // ncp     # context-parallel slice
    kv_len = S_cell if mode == "decode" else S
    c = Cost()

    specs = cfg.layer_specs()
    for spec in specs:
        c = c.merged(layer_cost(cfg, plan, spec, B, S, mode, kv_len))
    if cfg.is_encdec and mode != "decode":
        for spec in cfg.encoder_layer_specs():
            c = c.merged(layer_cost(cfg, plan, spec, B, S_cell, "train",
                                    S_cell))

    # embed + lm head
    lay = model_layout(cfg, plan)
    T = B * S
    c.add_bytes("embed", T * cfg.d_model * BF16 +
                lay.vocab.loc * cfg.d_model * (1 if plan.weight_dtype ==
                                               "int8" else BF16))
    _mm(c, "lm_head", T, cfg.d_model, lay.vocab.loc,
        w_dtype=1 if plan.weight_dtype == "int8" else BF16)
    w_local = param_bytes_per_device(cfg, plan)

    if mode == "train":
        # backward ~2x forward flops (+1x recompute under block remat);
        # weights re-read + grads written + optimizer (m, v f32 read+write,
        # params read+write)
        mult = 2.0 + {"block": 1.0, "selective": 0.2}.get(plan.remat, 0.0)
        # backward also re-reads weights & activations: scale bytes too
        bwd = Cost({k: mult * v for k, v in c.flops.items()},
                   {k: mult * v for k, v in c.bytes_hbm.items()})
        c = c.merged(bwd)
        # grad write + optimizer traffic (m,v f32 read+write, params f32
        # read+write); ZeRO-1 divides the optimizer share by the data degree
        opt_share = (2 * 2 * 2 + 2)
        if plan.zero1:
            opt_share /= max(ndp, 1)
        c.add_bytes("grads_opt", w_local * (1 + opt_share))
        n_layers = cfg.n_layers + cfg.n_enc_layers
        tensors = {"none": 6.0, "selective": 3.0}.get(plan.remat, 1.0)
        c.add_bytes("activations", tensors * B * S * cfg.d_model * BF16 *
                    n_layers)
    return c


def param_bytes_per_device(cfg: ModelConfig, plan: ShardingPlan) -> float:
    """Per-device weight bytes = sharded layout total / tp (leading-axis
    sharded leaves) + replicated leaves."""
    from repro.core import model as m
    ab = m.abstract_params(cfg, plan)
    import jax
    total = 0.0
    pspecs = m.param_pspecs(cfg, plan)
    for leaf, spec in zip(jax.tree_util.tree_leaves(ab),
                          jax.tree_util.tree_leaves(
                              pspecs, is_leaf=lambda x: isinstance(
                                  x, type(jax.sharding.PartitionSpec()))), strict=True):
        nb = float(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if len(spec) and spec[0] == "model" or \
                (len(spec) > 1 and spec[1] == "model"):
            nb /= plan.tp
        total += nb
    return total


def model_flops_ideal(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The classic 6*N*D (train) / 2*N*D (inference) + exact attention term,
    GLOBAL (all devices).  N = active params."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn = 3.0 * attn_flops_ideal(cfg, shape.global_batch, shape.seq_len)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn = attn_flops_ideal(cfg, shape.global_batch, shape.seq_len)
    else:
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
        attn = decode_attn_flops_ideal(cfg, shape.global_batch, shape.seq_len)
    return base + attn


def attn_flops_ideal(cfg, B, S):
    total = 0.0
    for spec in cfg.layer_specs() + (cfg.encoder_layer_specs()
                                     if cfg.is_encdec else []):
        if spec.mixer not in (MIX_ATTN, MIX_HYBRID):
            continue
        span = min(S, cfg.sliding_window) if spec.attn == ATTN_WINDOW and \
            cfg.sliding_window else S
        eff = S * span if spec.attn == ATTN_WINDOW else S * S / 2
        total += 2.0 * B * cfg.n_heads * eff * cfg.head_dim_ * 2
    return total


def decode_attn_flops_ideal(cfg, B, kv_len):
    total = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer not in (MIX_ATTN, MIX_HYBRID):
            continue
        span = min(kv_len, cfg.sliding_window) if spec.attn == ATTN_WINDOW \
            and cfg.sliding_window else kv_len
        total += 2.0 * B * cfg.n_heads * span * cfg.head_dim_ * 2
    return total


def active_param_count(cfg: ModelConfig) -> float:
    """Params touched per token (MoE: top_k + shared experts only)."""
    from repro.core.model import param_count
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    nmat = 3 if cfg.gated_ffn else 2
    per_expert = nmat * cfg.d_model * cfg.moe_d_ff
    n_moe_layers = sum(1 for s in cfg.layer_specs() if s.ffn == FFN_MOE)
    inactive = (cfg.n_experts - cfg.top_k) * per_expert * n_moe_layers
    return total - inactive
