"""Mixture-of-Experts FFN.

Two distribution modes (``plan.moe_mode``):

* ``tp`` — **paper-faithful**: every expert's intermediate dimension is
  sliced across the model axis exactly like a dense FC layer (the paper's
  F-slicing applied per expert).  No weight duplication, no extra
  collectives: routed partial outputs fold into the block's single post-FFN
  psum.  This is the only zero-duplication option when
  ``n_experts < tp`` (mixtral: 8 experts on 16 shards).
* ``ep`` — beyond-paper expert parallelism: experts sharded whole across the
  model axis (requires ``n_experts % tp == 0``); tokens are exchanged with
  two ``all_to_all``s.  Fewer, larger matmuls (MXU-friendlier) at the cost
  of a different collective pattern — evaluated in the §Perf hillclimb.

Routing uses capacity-bounded dispatch via sort + gather/scatter (no one-hot
dispatch matmuls, so HLO FLOPs stay honest).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import activation


def _capacity(T: int, k: int, n_experts: int, factor: float) -> int:
    """Expert capacity with a decode-safe floor: tiny token counts (decode
    steps) get capacity >= min(T, 16) so adversarial routing cannot drop
    tokens; the statistical capacity bound governs large T (prefill/train)."""
    return max(int(factor * T * k / n_experts), min(T, 16), 1)


def router_topk(x, w_router, top_k: int, n_experts: int):
    """x: (T, E) -> (gates (T,k) f32 normalized, idx (T,k) i32)."""
    logits = jnp.einsum("te,en->tn", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx


def _bucket_by_expert(x, idx, gates, n_experts: int, capacity: int):
    """Scatter tokens into per-expert buckets.

    Returns (buckets (n_exp, cap, E), combine info for scatter-back).
    Tokens over capacity are dropped (standard MoE semantics).
    """
    T, k = idx.shape
    E = x.shape[-1]
    flat_e = idx.reshape(-1)                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    # position of each (token, expert) pair within its expert bucket
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_in_bucket = jnp.arange(T * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                         side="left")
    keep = pos_in_bucket < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos_in_bucket, n_experts * capacity)
    src_t = flat_t[order]
    buckets = jnp.zeros((n_experts * capacity + 1, E), x.dtype)
    buckets = buckets.at[slot].set(x[src_t])
    return (buckets[:-1].reshape(n_experts, capacity, E),
            dict(slot=slot, src_t=src_t, gate=flat_g[order], keep=keep, T=T))


def _combine(buckets_out, info, E):
    """Scatter expert outputs back, weighted by gates."""
    flat = jnp.concatenate(
        [buckets_out.reshape(-1, E),
         jnp.zeros((1, E), buckets_out.dtype)], axis=0)
    picked = flat[jnp.minimum(info["slot"], flat.shape[0] - 1)]
    w = jnp.where(info["keep"], info["gate"], 0.0).astype(picked.dtype)
    out = jnp.zeros((info["T"], E), buckets_out.dtype)
    return out.at[info["src_t"]].add(picked * w[:, None])


def _expert_ffn(buckets, w_gate, w_up, w_down, act, gated):
    """buckets: (n_exp, cap, E); weights: (n_exp, E, F), (n_exp, F, E)."""
    if gated:
        h = activation(jnp.einsum("nce,nef->ncf", buckets, w_gate), act) * \
            jnp.einsum("nce,nef->ncf", buckets, w_up)
    else:
        h = activation(jnp.einsum("nce,nef->ncf", buckets, w_up), act)
    return jnp.einsum("ncf,nfe->nce", h, w_down)


def moe_ffn_tp(x, p, cfg, capacity_factor=1.25):
    """Paper-faithful TP MoE.  x: (B, S, E) replicated; expert weights are
    F-sliced: w_gate/w_up (n_exp, E, f_loc), w_down (n_exp, f_loc, E).
    Returns the PARTIAL output (B, S, E) — summed in the block's post-FFN psum."""
    B, S, E = x.shape
    T = B * S
    xt = x.reshape(T, E)
    gates, idx = router_topk(xt, p["router"]["w"], cfg.top_k, cfg.n_experts)
    capacity = _capacity(T, cfg.top_k, cfg.n_experts, capacity_factor)
    buckets, info = _bucket_by_expert(xt, idx, gates, cfg.n_experts, capacity)
    ex = p["experts"]
    out = _expert_ffn(buckets, ex.get("w_gate"), ex["w_up"], ex["w_down"],
                      cfg.act, cfg.gated_ffn)
    y = _combine(out, info, E)
    return y.reshape(B, S, E)


def moe_ffn_ep(x, p, cfg, shard_idx, tp, capacity_factor=1.25):
    """Expert-parallel MoE (beyond-paper variant).

    Expert weights are stored whole, ``n_experts/tp`` per shard:
    w_* (n_exp_loc, E, F_full).  With replicated activations (the paper's
    layout) no all_to_all is needed: every shard buckets all tokens, runs
    only its LOCAL experts, and emits a partial combine that folds into the
    block's existing post-FFN psum — the two-sync contract is preserved
    while matmuls become tp x larger per expert (MXU-friendlier than the
    paper-faithful F=88 slices of deepseek-moe)."""
    B, S, E = x.shape
    T = B * S
    n_loc = cfg.n_experts // tp
    xt = x.reshape(T, E)
    gates, idx = router_topk(xt, p["router"]["w"], cfg.top_k, cfg.n_experts)
    capacity = _capacity(T, cfg.top_k, cfg.n_experts, capacity_factor)
    buckets, info = _bucket_by_expert(xt, idx, gates, cfg.n_experts, capacity)
    local = jax.lax.dynamic_slice_in_dim(buckets, shard_idx * n_loc, n_loc,
                                         axis=0)
    ex = p["experts"]
    out_local = _expert_ffn(local, ex.get("w_gate"), ex["w_up"], ex["w_down"],
                            cfg.act, cfg.gated_ffn)
    out_full = jnp.zeros((cfg.n_experts, capacity, E), out_local.dtype)
    out_full = jax.lax.dynamic_update_slice_in_dim(
        out_full, out_local, shard_idx * n_loc, axis=0)
    y = _combine(out_full, info, E)
    return y.reshape(B, S, E)
