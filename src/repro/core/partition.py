"""The paper's partitioning scheme as a first-class object: ``ShardingPlan``.

Maps §IV of the paper onto a TPU mesh:

* head-parallel split of W_Q/W_K/W_V (and SSD heads) on the ``model`` axis,
* W_O split along its input (head*P) dimension,
* FFN weights sliced along the intermediate F dimension (per-expert for MoE),
* embedding / LM head sliced along vocab,
* **zero weight duplication** across the TP group (audited; documented
  exceptions: GQA KV-head replication when tp > n_kv, SSD B/C/dt
  projections with n_groups=1, and zero-padding for indivisible head
  counts — all quantified by ``duplication_report``),
* exactly **two synchronizations per block** (one post-attention, one
  post-FFN), enforced via explicit ledger-instrumented psums.

Every TP-sharded parameter carries an explicit leading ``tp`` shard axis,
sharded ``P(plan.tp_axis)``; inside ``shard_map`` each device sees its
``(1, ...)`` slice.  This makes "which chip holds what" a static, auditable
property — the on-chip-stationary invariant of the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


from repro.configs.base import ModelConfig


def ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingPlan:
    """How the model is laid out on the mesh (paper-faithful by default)."""
    tp: int = 1                       # model-axis size (the paper's Num_Chips)
    tp_axis: str = "model"
    dp_axes: tuple = ("data",)        # batch axes (("pod","data") multi-pod)
    seq_shard_kv: bool = False        # long-context decode: shard KV seq on dp
    activations: str = "replicated"   # replicated (paper) | seq (RS+AG, beyond-paper)
    moe_mode: str = "tp"              # tp (paper-faithful F-slice) | ep (all_to_all)
    moe_capacity: float = 1.25        # per-DP-shard expert capacity factor
    remat: str = "none"               # none | block (training)
    kv_cache_dtype: str = "bfloat16"  # "int8": quantized page pools with
                                      #   per-(page, slot) scales (paged) /
                                      #   fixed-point lanes (contiguous)
    kv_quant_scale: float = 16.0      # fixed-point scale for int8 KV
    ssm_cache_dtype: str = ""         # "" -> float32 slabs; "int8": quantized
                                      #   state slabs with per-slab-head scales
    weight_dtype: str = ""            # "" -> cfg.dtype; "int8" for deployment
    attn_scheme: str = "scan"         # scan (baseline) | split (4/3 causal)
    cp_axes: tuple = ()               # context parallelism: shard S over these
    cp_state_dtype: str = "float32"   # SSD state-gather precision (bf16: half wire)
    dp_hierarchical: bool = True      # grads: RS in-pod + AR cross-pod + AG
    zero1: bool = False               # shard optimizer state over the data axis

    @property
    def all_data_axes(self) -> tuple:
        return self.dp_axes

    @property
    def tp_axes(self) -> tuple:
        """Axes carrying the paper's tensor parallelism (empty when tp=1,
        e.g. under pure context parallelism)."""
        return (self.tp_axis,) if self.tp > 1 else ()

    @property
    def grad_axes(self) -> tuple:
        return tuple(self.dp_axes) + tuple(self.cp_axes)

    def axis_sizes(self, mesh) -> dict:
        return {name: size for name, size in
                zip(mesh.axis_names, mesh.devices.shape, strict=True)}

    def with_(self, **kw) -> "ShardingPlan":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Head layout (handles GQA replication + indivisible head padding)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HeadLayout:
    n_q: int                 # real q heads
    n_kv: int                # real kv heads
    tp: int
    hq_pad: int              # padded q heads (multiple of tp)
    hq_loc: int              # q heads per shard
    r: int                   # q heads per local kv slot (uniform)
    n_kv_loc: int            # kv slots per shard
    kv_map: tuple            # (tp, n_kv_loc) global kv head per slot
    q_valid: tuple           # (tp, hq_loc) 1.0 for real q heads

    @property
    def kv_slots_total(self) -> int:
        return self.tp * self.n_kv_loc

    @property
    def kv_duplication(self) -> float:
        """Stored kv-head slots / real kv heads (1.0 = zero duplication)."""
        return self.kv_slots_total / self.n_kv


def head_layout(n_q: int, n_kv: int, tp: int) -> HeadLayout:
    assert n_q % n_kv == 0, (n_q, n_kv)
    group = n_q // n_kv
    hq_pad = ceil_to(n_q, tp)
    hq_loc = hq_pad // tp

    def kv_of(h):  # padded q heads borrow the last real kv head (weights are 0)
        return min(h, n_q - 1) // group

    # largest r dividing hq_loc s.t. each slot's r consecutive q heads share a kv
    r = hq_loc
    while r > 1:
        ok = all(
            len({kv_of(i * hq_loc + s * r + j) for j in range(r)}) == 1
            for i in range(tp) for s in range(hq_loc // r)
        )
        if ok:
            break
        r //= 2
    n_kv_loc = hq_loc // r
    kv_map = tuple(tuple(kv_of(i * hq_loc + s * r) for s in range(n_kv_loc))
                   for i in range(tp))
    q_valid = tuple(tuple(1.0 if i * hq_loc + j < n_q else 0.0
                          for j in range(hq_loc)) for i in range(tp))
    return HeadLayout(n_q, n_kv, tp, hq_pad, hq_loc, r, n_kv_loc, kv_map, q_valid)


@dataclass(frozen=True)
class DimLayout:
    """A plain dimension sliced across tp with zero-padding (FFN F, vocab V)."""
    n: int
    tp: int
    n_pad: int
    loc: int

    @property
    def pad_waste(self) -> float:
        return (self.n_pad - self.n) / self.n


def dim_layout(n: int, tp: int) -> DimLayout:
    n_pad = ceil_to(n, tp)
    return DimLayout(n, tp, n_pad, n_pad // tp)


# ---------------------------------------------------------------------------
# Whole-model layout
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelLayout:
    attn: HeadLayout
    ssm: Optional[HeadLayout]
    ffn: DimLayout                  # dense FFN F
    moe_ffn: Optional[DimLayout]    # per-expert F (tp mode)
    shared_ffn: Optional[DimLayout]
    dense_override_ffn: Optional[DimLayout]
    vocab: DimLayout
    experts: Optional[DimLayout]    # expert count split (ep mode)


def model_layout(cfg: ModelConfig, plan: ShardingPlan) -> ModelLayout:
    tp = plan.tp
    attn = head_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    ssm = None
    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * cfg.d_model
        n_ssm_heads = d_inner // cfg.ssm_head_dim
        ssm = head_layout(n_ssm_heads, n_ssm_heads, tp)
    moe_ffn = dim_layout(cfg.moe_d_ff, tp) if cfg.n_experts else None
    shared = (dim_layout(cfg.moe_d_ff * cfg.n_shared_experts, tp)
              if cfg.n_shared_experts else None)
    dense_override = (dim_layout(cfg.dense_ff_override, tp)
                      if cfg.dense_ff_override else None)
    experts = dim_layout(cfg.n_experts, tp) if (cfg.n_experts and
                                                plan.moe_mode == "ep") else None
    return ModelLayout(
        attn=attn,
        ssm=ssm,
        ffn=dim_layout(cfg.d_ff, tp) if cfg.d_ff else dim_layout(0, 1),
        moe_ffn=moe_ffn,
        shared_ffn=shared,
        dense_override_ffn=dense_override,
        vocab=dim_layout(cfg.vocab_size, tp),
        experts=experts,
    )


# ---------------------------------------------------------------------------
# Zero-duplication audit (paper Table I property, enforced in tests)
# ---------------------------------------------------------------------------

def duplication_report(cfg: ModelConfig, plan: ShardingPlan) -> dict:
    """Bytes stored beyond one copy of the real weights, per category."""
    lay = model_layout(cfg, plan)
    d = cfg.head_dim_
    E = cfg.d_model
    per_layer_pad = 0.0
    specs = cfg.layer_specs()
    n_attn = sum(1 for s in specs if s.mixer in ("attn", "hybrid"))
    n_ssm = sum(1 for s in specs if s.mixer in ("ssm", "hybrid"))
    # KV replication + q padding (attention)
    hl = lay.attn
    kv_extra_heads = hl.kv_slots_total - hl.n_kv
    q_extra_heads = hl.hq_pad - hl.n_q
    attn_dup = n_attn * kv_extra_heads * E * d * 2 * 2      # wk+wv, bf16
    attn_pad = n_attn * q_extra_heads * E * d * 2 * 2       # wq+wo
    # SSD B/C/dt replicated (n_groups=1)
    ssm_dup = 0.0
    if lay.ssm is not None:
        N = cfg.ssm_state
        ssm_dup = n_ssm * (plan.tp - 1) * (2 * E * N + 2 * N * cfg.ssm_conv) * 2
        ssm_pad = n_ssm * (lay.ssm.hq_pad - lay.ssm.n_q) * (
            2 * E * cfg.ssm_head_dim + cfg.ssm_head_dim * E) * 2
        per_layer_pad += ssm_pad
    # FFN/vocab padding
    ffn_pad = sum((dim_layout(s.d_ff, plan.tp).n_pad - s.d_ff) * 3 * E * 2
                  for s in specs if s.ffn == "dense" and s.d_ff)
    vocab_pad = (lay.vocab.n_pad - lay.vocab.n) * E * 2 * (1 if cfg.tie_embeddings else 2)
    from repro.core import model as _m
    total = _m.param_count(cfg) * 2  # bf16 bytes, single copy
    dup = attn_dup + ssm_dup
    pad = attn_pad + per_layer_pad + ffn_pad + vocab_pad
    return {
        "single_copy_bytes": total,
        "duplicated_bytes": dup,
        "padded_bytes": pad,
        "dup_fraction": dup / total,
        "pad_fraction": pad / total,
        "zero_dup_core": dup == 0.0,
    }
