"""Composable transformer: parameter templates, sharding, forward passes.

Single source of truth is ``model_template(cfg, plan)``: a pytree of
``ParamSpec(kind, full_shape, init)`` describing every parameter's canonical
(unsharded, unpadded) shape.  From it we derive:

* ``init_params``     — deterministic canonical init + ``shard_full`` scatter
                        (so tp=1 and tp=N initializations are bit-identical
                        up to layout: the TP-equivalence tests rely on this),
* ``abstract_params`` — ShapeDtypeStructs for the 512-device dry-run,
* ``param_pspecs``    — PartitionSpecs for shard_map in_specs,
* ``param_count``     — exact parameter count.

Forward passes are written per-shard (called inside shard_map) and run the
layer stack as ``lax.scan`` over stacked layer-group params (bounded compile
time at depth 88+); the CommLedger multiplier makes scanned collectives
count exactly n_reps times.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (FFN_DENSE, FFN_MOE, FFN_NONE,
                                MIX_ATTN, MIX_HYBRID, MIX_SSM, ModelConfig)
from repro.core import collectives as cc
from repro.core.blocks import _lo, layer_forward, tp_index
from repro.core.layers import apply_norm, sharded_embed, sharded_logits, \
    sharded_xent
from repro.core.partition import ModelLayout, ShardingPlan, dim_layout, \
    model_layout


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    kind: str                 # sharding kind (see shard_full)
    full: tuple               # canonical full shape
    init: str = "normal"      # normal | zeros | ones | a_log | dt_bias
    scale: float = 0.02
    ffn_dim: int = 0          # per-layer F (dense layers with overrides)

    @property
    def is_leaf(self):
        return True


def _is_spec(x):
    return isinstance(x, ParamSpec)


def _norm_t(cfg):
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec("replicated", (cfg.d_model,), "ones"),
                "bias": ParamSpec("replicated", (cfg.d_model,), "zeros")}
    return {"scale": ParamSpec("replicated", (cfg.d_model,), "zeros")}


def _attn_t(cfg, n_layers_total):
    E, d = cfg.d_model, cfg.head_dim_
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    out_scale = 0.02 / math.sqrt(2 * n_layers_total)
    t = {
        "wq": ParamSpec("col_heads", (E, Hq, d)),
        "wk": ParamSpec("kv_heads", (E, Hkv, d)),
        "wv": ParamSpec("kv_heads", (E, Hkv, d)),
        "wo": ParamSpec("row_heads", (Hq, d, E), scale=out_scale),
    }
    if cfg.qk_norm:
        t["q_norm"] = ParamSpec("replicated", (d,), "zeros")
        t["k_norm"] = ParamSpec("replicated", (d,), "zeros")
    return t


def _ssm_t(cfg, n_layers_total):
    E = cfg.d_model
    d_inner = cfg.ssm_expand * E
    Pd, N = cfg.ssm_head_dim, cfg.ssm_state
    H = d_inner // Pd
    K = cfg.ssm_conv
    out_scale = 0.02 / math.sqrt(2 * n_layers_total)
    return {
        "in_z": ParamSpec("ssm_col_heads", (E, H, Pd)),
        "in_x": ParamSpec("ssm_col_heads", (E, H, Pd)),
        "in_dt": ParamSpec("ssm_col_head_vec", (E, H)),
        "in_B": ParamSpec("replicated", (E, N)),
        "in_C": ParamSpec("replicated", (E, N)),
        "conv_x": ParamSpec("ssm_conv_heads", (H, Pd, K), scale=0.2),
        "conv_B": ParamSpec("replicated", (N, K), scale=0.2),
        "conv_C": ParamSpec("replicated", (N, K), scale=0.2),
        "A_log": ParamSpec("ssm_head_vec", (H,), "a_log"),
        "D": ParamSpec("ssm_head_vec", (H,), "ones"),
        "dt_bias": ParamSpec("ssm_head_vec", (H,), "dt_bias"),
        "norm_scale": ParamSpec("ssm_flat_heads", (H, Pd), "zeros"),
        "out": ParamSpec("ssm_row_heads", (H, Pd, E), scale=out_scale),
    }


def _dense_ffn_t(cfg, d_ff, n_layers_total):
    E = cfg.d_model
    out_scale = 0.02 / math.sqrt(2 * n_layers_total)
    t = {"w_up": ParamSpec("col_dim", (E, d_ff), ffn_dim=d_ff),
         "w_down": ParamSpec("row_dim", (d_ff, E), scale=out_scale,
                             ffn_dim=d_ff)}
    if cfg.gated_ffn:
        t["w_gate"] = ParamSpec("col_dim", (E, d_ff), ffn_dim=d_ff)
    return t


def _moe_ffn_t(cfg, n_layers_total):
    E = cfg.d_model
    F = cfg.moe_d_ff
    out_scale = 0.02 / math.sqrt(2 * n_layers_total)
    ex = {"w_up": ParamSpec("moe_col", (cfg.n_experts, E, F)),
          "w_down": ParamSpec("moe_row", (cfg.n_experts, F, E),
                              scale=out_scale)}
    if cfg.gated_ffn:
        ex["w_gate"] = ParamSpec("moe_col", (cfg.n_experts, E, F))
    t = {"router": {"w": ParamSpec("replicated", (E, cfg.n_experts))},
         "experts": ex}
    if cfg.n_shared_experts:
        t["shared"] = _dense_ffn_t(cfg, F * cfg.n_shared_experts,
                                   n_layers_total)
    return t


def layer_template(cfg, spec, n_layers_total):
    t = {"ln1": _norm_t(cfg)}
    if spec.mixer in (MIX_ATTN, MIX_HYBRID):
        t["attn"] = _attn_t(cfg, n_layers_total)
    if spec.mixer in (MIX_SSM, MIX_HYBRID):
        t["ssm"] = _ssm_t(cfg, n_layers_total)
    if cfg.sandwich_norm:
        t["post_ln1"] = _norm_t(cfg)
    if spec.cross_attn:
        t["ln_x"] = _norm_t(cfg)
        t["xattn"] = _attn_t(cfg, n_layers_total)
    if spec.ffn == FFN_DENSE:
        t["ln2"] = _norm_t(cfg)
        t["ffn"] = _dense_ffn_t(cfg, spec.d_ff, n_layers_total)
    elif spec.ffn == FFN_MOE:
        t["ln2"] = _norm_t(cfg)
        t["ffn"] = _moe_ffn_t(cfg, n_layers_total)
    if cfg.sandwich_norm and spec.ffn != FFN_NONE:
        t["post_ln2"] = _norm_t(cfg)
    return t


def model_template(cfg: ModelConfig):
    E, V = cfg.d_model, cfg.vocab_size
    nl = cfg.n_layers + cfg.n_enc_layers
    t = {
        "embed": {"table": ParamSpec("vocab", (V, E), scale=0.02)},
        "stacks": [[layer_template(cfg, s, nl) for s in g.pattern]
                   for g in cfg.layer_groups()],
        "final_norm": _norm_t(cfg),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = {"w": ParamSpec("vocab", (V, E))}
    if cfg.is_encdec:
        t["encoder"] = {
            "stacks": [[layer_template(cfg, s, nl) for s in g.pattern]
                       for g in cfg.layer_groups(cfg.encoder_layer_specs())],
            "final_norm": _norm_t(cfg),
        }
    return t


def param_count(cfg: ModelConfig) -> int:
    tmpl = model_template(cfg)
    total = 0

    def walk(node, reps=1):
        nonlocal total
        if _is_spec(node):
            total += reps * int(np.prod(node.full))
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v, reps)
        elif isinstance(node, list):
            for v in node:
                walk(v, reps)

    for key, val in tmpl.items():
        if key == "stacks":
            for g, sub in zip(cfg.layer_groups(), val, strict=True):
                for pat_t in sub:
                    walk(pat_t, g.n_reps)
        elif key == "encoder":
            for g, sub in zip(cfg.layer_groups(cfg.encoder_layer_specs()),
                              val["stacks"], strict=True):
                for pat_t in sub:
                    walk(pat_t, g.n_reps)
            walk(val["final_norm"])
        else:
            walk(val)
    return total


# ---------------------------------------------------------------------------
# Sharding of canonical tensors (scatter) — numpy/jnp, deterministic
# ---------------------------------------------------------------------------

def shard_full(spec: ParamSpec, full, cfg, plan: ShardingPlan,
               lay: ModelLayout):
    """Canonical full tensor -> sharded layout with leading tp axis."""
    kind, tp = spec.kind, plan.tp
    if kind == "replicated":
        return full
    hl = lay.ssm if kind.startswith("ssm_") else lay.attn
    k = kind[4:] if kind.startswith("ssm_") else kind

    def pad_axis(x, axis, to):
        padw = [(0, 0)] * x.ndim
        padw[axis] = (0, to - x.shape[axis])
        return jnp.pad(x, padw) if to > x.shape[axis] else x

    if k == "col_heads":      # (E,H,D) -> (tp, E, hq_loc, D)
        x = pad_axis(full, 1, hl.hq_pad)
        x = x.reshape(x.shape[0], tp, hl.hq_loc, x.shape[2])
        return jnp.moveaxis(x, 1, 0)
    if k == "col_head_vec":   # (E,H) -> (tp, E, hq_loc)
        x = pad_axis(full, 1, hl.hq_pad)
        return jnp.moveaxis(x.reshape(x.shape[0], tp, hl.hq_loc), 1, 0)
    if k == "row_heads":      # (H,D,E) -> (tp, hq_loc, D, E)
        x = pad_axis(full, 0, hl.hq_pad)
        return x.reshape(tp, hl.hq_loc, x.shape[1], x.shape[2])
    if k == "head_vec":       # (H,) -> (tp, hq_loc)
        return pad_axis(full, 0, hl.hq_pad).reshape(tp, hl.hq_loc)
    if k == "flat_heads":     # (H,P) -> (tp, hq_loc*P)
        x = pad_axis(full, 0, hl.hq_pad)
        return x.reshape(tp, hl.hq_loc * x.shape[1])
    if k == "conv_heads":     # (H,P,K) -> (tp, hq_loc, P, K)
        x = pad_axis(full, 0, hl.hq_pad)
        return x.reshape(tp, hl.hq_loc, x.shape[1], x.shape[2])
    if k == "kv_heads":       # (E,n_kv,D) -> gather kv_map -> (tp,E,n_kv_loc,D)
        kvm = np.asarray(hl.kv_map)                    # (tp, n_kv_loc)
        x = jnp.take(full, jnp.asarray(kvm.reshape(-1)), axis=1)
        x = x.reshape(full.shape[0], tp, hl.n_kv_loc, full.shape[2])
        return jnp.moveaxis(x, 1, 0)
    if k == "col_dim":        # (E,F) -> (tp, E, f_loc)
        dl = dim_layout(full.shape[1], tp)
        x = pad_axis(full, 1, dl.n_pad)
        return jnp.moveaxis(x.reshape(x.shape[0], tp, dl.loc), 1, 0)
    if k == "row_dim":        # (F,E) -> (tp, f_loc, E)
        dl = dim_layout(full.shape[0], tp)
        x = pad_axis(full, 0, dl.n_pad)
        return x.reshape(tp, dl.loc, x.shape[1])
    if k == "vocab":          # (V,E) -> (tp, v_loc, E)
        dl = lay.vocab
        x = pad_axis(full, 0, dl.n_pad)
        return x.reshape(tp, dl.loc, x.shape[1])
    if k == "moe_col":        # (n_exp,E,F)
        if plan.moe_mode == "ep":
            n_loc = cfg.n_experts // tp
            return full.reshape(tp, n_loc, *full.shape[1:])
        dl = dim_layout(full.shape[2], tp)
        x = pad_axis(full, 2, dl.n_pad)
        x = x.reshape(*x.shape[:2], tp, dl.loc)
        return jnp.moveaxis(x, 2, 0)
    if k == "moe_row":        # (n_exp,F,E)
        if plan.moe_mode == "ep":
            n_loc = cfg.n_experts // tp
            return full.reshape(tp, n_loc, *full.shape[1:])
        dl = dim_layout(full.shape[1], tp)
        x = pad_axis(full, 1, dl.n_pad)
        x = x.reshape(x.shape[0], tp, dl.loc, x.shape[2])
        return jnp.moveaxis(x, 1, 0)
    raise ValueError(kind)


def _mask_invalid_heads(spec, sharded, cfg, plan, lay):
    """Zero the q-padding slots so padded heads contribute exactly 0."""
    kind = spec.kind
    hl = lay.ssm if kind.startswith("ssm_") else lay.attn
    k = kind[4:] if kind.startswith("ssm_") else kind
    if k not in ("col_heads", "row_heads", "col_head_vec"):
        return sharded
    valid = jnp.asarray(np.asarray(hl.q_valid))          # (tp, hq_loc)
    if k == "col_heads":
        return sharded * valid[:, None, :, None]
    if k == "col_head_vec":
        return sharded * valid[:, None, :]
    return sharded * valid[:, :, None, None]             # row_heads


def sharded_shape(spec: ParamSpec, cfg, plan, lay):
    fake = jax.eval_shape(
        lambda: shard_full(spec, jnp.zeros(spec.full, jnp.bfloat16), cfg,
                           plan, lay))
    return fake.shape


# ---------------------------------------------------------------------------
# Template -> (abstract params, pspecs, init)
# ---------------------------------------------------------------------------

def _map_template(tmpl, fn_spec, reps_stack=None):
    """Map over template leaves; ``stacks`` entries get a leading reps dim."""
    def walk(node, reps):
        if _is_spec(node):
            return fn_spec(node, reps)
        if isinstance(node, dict):
            return {k: walk(v, reps) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, reps) for v in node]
        raise TypeError(type(node))

    out = {}
    for key, val in tmpl.items():
        if key == "stacks":
            out[key] = [ [walk(pt, rep) for pt in sub]
                         for rep, sub in val ]
        elif key == "encoder":
            out[key] = {
                "stacks": [[walk(pt, rep) for pt in sub]
                           for rep, sub in val["stacks"]],
                "final_norm": walk(val["final_norm"], 0),
            }
        else:
            out[key] = walk(val, 0)
    return out


def _with_reps(cfg, tmpl):
    """Pair each stacks entry with its group rep count (helper for mapping)."""
    t = dict(tmpl)
    t["stacks"] = list(zip([g.n_reps for g in cfg.layer_groups()],
                           tmpl["stacks"], strict=True))
    if "encoder" in tmpl:
        enc_groups = cfg.layer_groups(cfg.encoder_layer_specs())
        t["encoder"] = dict(tmpl["encoder"])
        t["encoder"]["stacks"] = list(zip([g.n_reps for g in enc_groups],
                                          tmpl["encoder"]["stacks"], strict=True))
    return t


def abstract_params(cfg, plan, dtype=None):
    lay = model_layout(cfg, plan)
    dt = jnp.dtype(dtype or plan.weight_dtype or cfg.dtype)

    def mk(spec, reps):
        shape = sharded_shape(spec, cfg, plan, lay)
        if reps:
            shape = (reps,) + shape
        if spec.init in ("a_log", "dt_bias"):
            d = jnp.float32
        elif spec.kind == "replicated":
            d = jnp.dtype(cfg.dtype)     # norms/routers stay high precision
        else:
            d = dt
        return jax.ShapeDtypeStruct(shape, d)

    return _map_template(_with_reps(cfg, model_template(cfg)), mk)


def param_pspecs(cfg, plan):
    lay = model_layout(cfg, plan)

    tpax = plan.tp_axis if plan.tp > 1 else None

    def mk(spec, reps):
        if spec.kind == "replicated":
            ndim = len(spec.full)
            base = P(*([None] * ndim))
        else:
            ndim = len(sharded_shape(spec, cfg, plan, lay))
            base = P(*([tpax] + [None] * (ndim - 1)))
        if reps:
            base = P(*((None,) + tuple(base)))
        return base

    return _map_template(_with_reps(cfg, model_template(cfg)), mk)


def init_params(cfg, plan, seed=0, dtype=None):
    """Deterministic init: canonical full tensors (independent of plan),
    then scatter.  Heavy for full-size configs — use on reduced/paper models."""
    lay = model_layout(cfg, plan)
    dt = jnp.dtype(dtype or cfg.dtype)
    counter = [0]

    def mk(spec, reps):
        leaves = []
        for _ in range(max(reps, 1)):
            counter[0] += 1
            key = jax.random.fold_in(jax.random.PRNGKey(seed), counter[0])
            full = _init_full(spec, key)
            sh = shard_full(spec, full, cfg, plan, lay)
            sh = _mask_invalid_heads(spec, sh, cfg, plan, lay)
            keep_f32 = spec.init in ("a_log", "dt_bias")
            leaves.append(sh.astype(jnp.float32 if keep_f32 else dt))
        return jnp.stack(leaves) if reps else leaves[0]

    return _map_template(_with_reps(cfg, model_template(cfg)), mk)


def _init_full(spec: ParamSpec, key):
    if spec.init == "zeros":
        return jnp.zeros(spec.full, jnp.float32)
    if spec.init == "ones":
        return jnp.ones(spec.full, jnp.float32)
    if spec.init == "a_log":
        n = spec.full[0]
        return jnp.log(jnp.linspace(1.0, 16.0, n))
    if spec.init == "dt_bias":
        n = spec.full[0]
        dts = jnp.exp(jnp.linspace(math.log(1e-3), math.log(0.1), n))
        return jnp.log(jnp.expm1(dts))            # inverse softplus
    return spec.scale * jax.random.normal(key, spec.full, jnp.float32)


# ---------------------------------------------------------------------------
# Forward passes (per-shard; call inside shard_map)
# ---------------------------------------------------------------------------

def _run_stack(x, stack_params, groups, cfg, plan, lay, mode, positions,
               pos=None, enc_memory=None, cache=None, causal_specs=None,
               pages=None):
    """Scan every layer group.  cache: list aligned with groups (or None)."""
    new_cache = [] if cache is not None else None
    for gi, (group, gparams) in enumerate(zip(groups, stack_params, strict=True)):
        gcache = cache[gi] if cache is not None else None

        def body(xc, per_rep, group=group):   # bind the loop var (B023)
            p_rep, c_rep = per_rep
            nc_rep = []
            for pi, spec in enumerate(group.pattern):
                ci = c_rep[pi] if c_rep is not None else None
                xc, nc = layer_forward(xc, p_rep[pi], ci, cfg, plan, lay,
                                       spec, mode, positions, pos, enc_memory,
                                       pages)
                nc_rep.append(nc if nc is not None else {})
            return xc, (nc_rep if c_rep is not None else None)

        if mode == "train" and plan.remat == "block":
            body = jax.checkpoint(body)
        elif mode == "train" and plan.remat == "selective":
            # save matmul outputs, recompute only elementwise ops
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        with cc.LEDGER.scaled(group.n_reps):
            if group.n_reps == 1:
                p_rep = jax.tree_util.tree_map(lambda a: a[0], gparams)
                c_rep = (jax.tree_util.tree_map(lambda a: a[0], gcache)
                         if gcache is not None else None)
                x, nc = body(x, (p_rep, c_rep))
                nc = (jax.tree_util.tree_map(lambda a: a[None], nc)
                      if nc is not None else None)
            else:
                x, nc = jax.lax.scan(body, x, (gparams, gcache))
        if new_cache is not None:
            new_cache.append(nc)
    return x, new_cache


def embed_tokens(params, tokens, cfg, plan, lay):
    emb = sharded_embed(tokens, _lo(params["embed"]["table"]),
                        tp_index(plan), lay.vocab.loc, plan.tp_axes)
    if cfg.scale_embed:
        emb = emb * jnp.asarray(math.sqrt(cfg.d_model), emb.dtype)
    return emb


def final_logits(params, x, cfg, lay):
    head = params.get("lm_head", {}).get("w", params["embed"]["table"])
    return sharded_logits(x, _lo(head))


def encode(params, frames, cfg, plan, lay):
    """Encoder stack over precomputed frame embeddings (audio stub)."""
    groups = cfg.layer_groups(cfg.encoder_layer_specs())
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])
    x, _ = _run_stack(frames, params["encoder"]["stacks"], groups, cfg, plan,
                      lay, "train", pos)
    return apply_norm(x, params["encoder"]["final_norm"], cfg)


def forward_cross_kv(params, enc_memory, cfg, plan, lay):
    """Every cross-attention layer's K/V of the encoder memory.

    -> list aligned with ``cfg.layer_groups()``: per group, per pattern
    entry, either None (no cross-attention) or ``{"k", "v"}`` of shape
    (reps, B, G, S_enc, D) — the grouped-GQA layout ``cross_attn_mixer``
    attends over.  Computed once per encode; the paged serving path
    scatters these into the cross page pools
    (``steps.make_cross_kv_write_step``), after which they are immutable.
    """
    from repro.core.layers import rmsnorm

    def one_layer(pa):
        k = jnp.einsum("bse,ehd->bshd", enc_memory, _lo(pa["wk"]))
        v = jnp.einsum("bse,ehd->bshd", enc_memory, _lo(pa["wv"]))
        if cfg.qk_norm:
            k = rmsnorm(k, pa["k_norm"], cfg.norm_eps)
        return {"k": k.swapaxes(1, 2), "v": v.swapaxes(1, 2)}

    out = []
    for group, gparams in zip(cfg.layer_groups(), params["stacks"], strict=True):
        per_pat = []
        for pi, spec in enumerate(group.pattern):
            if not spec.cross_attn:
                per_pat.append(None)
                continue
            per_pat.append(jax.vmap(one_layer)(gparams[pi]["xattn"]))
        out.append(per_pat)
    return out


def _cp_positions(B, S, plan):
    """Absolute positions for this shard's sequence slice (context parallel:
    the local S is a contiguous slice at offset cp_index * S)."""
    off = 0
    if plan.cp_axes:
        from repro.core.blocks import dp_linear_index
        off = dp_linear_index(plan.cp_axes) * S
    return jnp.broadcast_to(off + jnp.arange(S), (B, S))


def forward_train(params, batch, cfg, plan, lay):
    """-> mean NLL (per-shard scalar; psum'd over dp axes by the caller)."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    positions = _cp_positions(B, S, plan)
    x = embed_tokens(params, tokens, cfg, plan, lay)
    if cfg.frontend == "vision_patches" and "image_embeds" in batch:
        n = batch["image_embeds"].shape[1]
        x = jnp.concatenate([batch["image_embeds"].astype(x.dtype),
                             x[:, n:]], axis=1)
    enc_memory = None
    if cfg.is_encdec:
        enc_memory = encode(params, batch["frames"].astype(x.dtype), cfg,
                            plan, lay)
    groups = cfg.layer_groups()
    x, _ = _run_stack(x, params["stacks"], groups, cfg, plan, lay, "train",
                      positions, enc_memory=enc_memory)
    x = apply_norm(x, params["final_norm"], cfg)
    if cfg.is_encoder_only:
        # masked-token style objective: predict every position's token
        labels = tokens
    logits = final_logits(params, x, cfg, lay)
    nll = sharded_xent(logits, labels, tp_index(plan), lay.vocab.loc,
                       cfg.vocab_size, plan.tp_axes)
    return jnp.mean(nll)


def forward_prefill(params, tokens_or_frames, cache0, cfg, plan, lay,
                    extra=None):
    """Prefill: run full prompt, fill the cache.  -> (last_logits, cache)."""
    extra = extra or {}
    if cfg.is_encdec:
        frames = tokens_or_frames            # (B, S, E) stub embeddings
        enc_memory = encode(params, frames.astype(jnp.dtype(cfg.dtype)),
                            cfg, plan, lay)
        tokens = extra["dec_tokens"]
    else:
        enc_memory = None
        tokens = tokens_or_frames
    B, S = tokens.shape
    positions = _cp_positions(B, S, plan)
    x = embed_tokens(params, tokens, cfg, plan, lay)
    if cfg.frontend == "vision_patches" and "image_embeds" in extra:
        n = extra["image_embeds"].shape[1]
        x = jnp.concatenate([extra["image_embeds"].astype(x.dtype),
                             x[:, n:]], axis=1)
    groups = cfg.layer_groups()
    x, cache = _run_stack(x, params["stacks"], groups, cfg, plan, lay,
                          "prefill", positions, enc_memory=enc_memory,
                          cache=cache0)
    x = apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = final_logits(params, x, cfg, lay)[:, 0]
    if plan.cp_axes and cc.axis_size(plan.cp_axes) > 1:
        # the true last token lives on the last CP shard: masked broadcast
        from repro.core.blocks import dp_linear_index
        n_cp = cc.axis_size(plan.cp_axes)
        last = dp_linear_index(plan.cp_axes) == n_cp - 1
        logits = cc.psum(jnp.where(last, logits, jnp.zeros_like(logits)),
                         plan.cp_axes, "prefill/cp_logits")
    return logits, cache


def forward_decode(params, cache, tokens, pos, cfg, plan, lay, pages=None):
    """One decode step.  tokens: (B, 1); pos: (B,) -> (logits, cache)."""
    positions = pos[:, None]
    x = embed_tokens(params, tokens, cfg, plan, lay)
    groups = cfg.layer_groups()
    x, cache = _run_stack(x, params["stacks"], groups, cfg, plan, lay,
                          "decode", positions, pos=pos, cache=cache,
                          pages=pages)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = final_logits(params, x, cfg, lay)[:, 0]
    return logits, cache


def forward_verify(params, cache, tokens, pos, qlen, cfg, plan, lay,
                   pages=None):
    """Speculative verify: score Q consecutive positions per slot at once.

    tokens: (B, Q) — column 0 is the slot's last accepted token, columns
    1..Q-1 are drafted continuations; pos: (B,) absolute position of
    column 0; qlen: (B,) live columns per row (columns >= qlen are
    padding — their positions are set to -1 so their KV lands on the
    scratch page and their logits are garbage the caller ignores).
    -> (logits (B, Q, V_loc), cache): row i is the next-token distribution
    after consuming tokens[:, :i+1] — token-equivalent to feeding them to
    ``forward_decode`` one at a time, in one fused pass over the cache.
    """
    B, Q = tokens.shape
    positions = pos[:, None] + jnp.broadcast_to(jnp.arange(Q), (B, Q))
    positions = jnp.where(jnp.arange(Q)[None, :] < qlen[:, None],
                          positions, -1)
    x = embed_tokens(params, tokens, cfg, plan, lay)
    groups = cfg.layer_groups()
    x, cache = _run_stack(x, params["stacks"], groups, cfg, plan, lay,
                          "verify", positions, pos=pos, cache=cache,
                          pages=pages)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = final_logits(params, x, cfg, lay)
    return logits, cache


def forward_prefill_chunk(params, cache, tokens, chunk_start, last_idx, cfg,
                          plan, lay, pages):
    """One fixed-size prefill chunk against the paged cache.

    tokens: (B, C) chunk of the prompt (zero-padded past its end);
    chunk_start: () absolute position of the chunk's first token;
    last_idx: () in-chunk index of the prompt's final token (only meaningful
    on the chunk that contains it — callers use the returned logits then).
    -> (logits (B, V_loc), cache).  One compiled step serves every prompt
    length: length variation lives entirely in the (chunk_start, last_idx,
    block_table) inputs, never in shapes.
    """
    B, C = tokens.shape
    positions = chunk_start + jnp.broadcast_to(jnp.arange(C), (B, C))
    x = embed_tokens(params, tokens, cfg, plan, lay)
    groups = cfg.layer_groups()
    x, cache = _run_stack(x, params["stacks"], groups, cfg, plan, lay,
                          "prefill", positions, cache=cache, pages=pages)
    x = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = final_logits(params, x, cfg, lay)[:, 0]
    return logits, cache
