"""Per-layer decode caches (KV, SSM state, cross-attention KV).

Global (pre-shard_map) layouts — heads carry an explicit tp*local dim
sharded on the model axis, mirroring the weight convention:

    kv.k / kv.v : (reps, B, tp * n_kv_loc, W, D)    W = window or seq budget
    kv.pos      : (reps, B, W)  absolute position per slot (-1 = empty);
                  ring-indexed (pos % W) for windowed layers
    ssm.state   : (reps, B, tp * h_loc, P, N)  float32
    ssm.conv_*  : (reps, B, K-1, channels)
    cross.k/v   : (reps, B, tp * n_kv_loc, S_enc, D)

For ``plan.seq_shard_kv`` (long-context decode) the W dim is additionally
sharded over the data axes — each data shard holds a contiguous slice of the
sequence and attention merges partials via LSE psums (attention.py).

Paged-serving clauses (machine-checked by scripts/check_static.py; the
block-pool layouts are further down):

Invariant: one static allocation — every pool/slab is a fixed array
    whose placement never changes; request lengths appear only as data
    (block tables, positions, slab ids), never as shapes.
Enforced-by: tests/test_paged_cache.py::test_paged_engine_matches_contiguous_greedy, analysis:jit-stability

Invariant: page 0 / slab 0 are scratch — idle decode lanes point their
    block tables (and slab ids) at the reserved index so the fused
    decode step always runs full-batch; scratch contents are garbage by
    convention and must never be read back.
Enforced-by: tests/test_paged_cache.py::test_paged_steps_match_contiguous_mixed_lengths

Invariant: refcounts own pages — a page returns to the free list exactly
    when its last reference drops (slot block-table entries,
    radix-prefix-cache nodes and cross-KV cache entries each hold one
    ref per page).  Shared pages are immutable; divergence goes through
    a copy-on-write duplicate.
Enforced-by: tests/test_paged_cache.py::test_page_allocator_reuse_and_exhaustion, analysis:refcount-leak, analysis:shared-free, analysis:allocator-internals

Invariant: slabs are exclusive — recurrent SSM state cannot be shared or
    re-derived from pages, so a slab has exactly one owner, is zeroed on
    allocation, and is snapshot/restored through the engine's host-side
    stash across preemption (``serving.engine``).
Enforced-by: tests/test_paged_cache.py::test_ssm_int8_forced_preemption_identity

Invariant: page handoff is an atomic ref transfer — moving a request's
    resident pages to another replica (``handoff_refs``) drops the source
    slot's references exactly once and only after verifying every
    destination page is freshly allocated (refcount 1, no inherited
    sharers); a page is never referenced by two replicas' allocators.
Enforced-by: tests/test_page_transfer.py::test_handoff_refs_decrefs_source_once, analysis:refcount-leak
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN_WINDOW, ModelConfig


def kv_window(cfg: ModelConfig, spec, budget: int) -> int:
    if spec.attn == ATTN_WINDOW and cfg.sliding_window:
        return min(budget, cfg.sliding_window)
    return budget


def layer_cache_template(cfg, plan, lay, spec, batch: int, budget: int,
                         seq_sharded: bool, batch_sharded: bool = True):
    """-> dict of (shape, dtype, pspec) triples for ONE layer (no reps dim)."""
    out = {}
    kvd = jnp.dtype(plan.kv_cache_dtype)
    d = cfg.head_dim_
    batch_axes = tuple(plan.dp_axes) if (batch_sharded and not seq_sharded) \
        else None
    seq_axes = tuple(plan.dp_axes) if seq_sharded else None
    tpax = "model" if plan.tp > 1 else None   # head dims follow TP only
    if "kv" in spec.cache_kinds():
        W = kv_window(cfg, spec, budget)
        wseq = seq_axes if (seq_sharded and W == budget) else None
        out["kv"] = {
            "k": ((batch, plan.tp * lay.attn.n_kv_loc, W, d), kvd,
                  P(batch_axes, tpax, wseq, None)),
            "v": ((batch, plan.tp * lay.attn.n_kv_loc, W, d), kvd,
                  P(batch_axes, tpax, wseq, None)),
            "pos": ((batch, W), jnp.int32, P(batch_axes, wseq)),
        }
    if "ssm" in spec.cache_kinds():
        H, Pdim, N = lay.ssm.hq_loc, cfg.ssm_head_dim, cfg.ssm_state
        K = cfg.ssm_conv
        cx = plan.tp * H * Pdim
        out["ssm"] = {
            "state": ((batch, plan.tp * H, Pdim, N), jnp.float32,
                      P(batch_axes, tpax, None, None)),
            "conv_x": ((batch, K - 1, cx), jnp.dtype(cfg.dtype),
                       P(batch_axes, None, tpax)),
            "conv_B": ((batch, K - 1, N), jnp.dtype(cfg.dtype),
                       P(batch_axes, None, None)),
            "conv_C": ((batch, K - 1, N), jnp.dtype(cfg.dtype),
                       P(batch_axes, None, None)),
        }
    if "cross_kv" in spec.cache_kinds():
        S_enc = cfg.enc_seq_len
        out["cross"] = {
            "k": ((batch, plan.tp * lay.attn.n_kv_loc, S_enc, d), kvd,
                  P(batch_axes, tpax, None, None)),
            "v": ((batch, plan.tp * lay.attn.n_kv_loc, S_enc, d), kvd,
                  P(batch_axes, tpax, None, None)),
        }
    return out


def cache_template(cfg, plan, lay, batch: int, budget: int,
                   batch_sharded: bool = True):
    """Full cache: list (per layer group) of stacked templates."""
    seq_sharded = plan.seq_shard_kv
    groups = cfg.layer_groups()
    tmpl = []
    for g in groups:
        per_pattern = []
        for spec in g.pattern:
            t = layer_cache_template(cfg, plan, lay, spec, batch, budget,
                                     seq_sharded, batch_sharded)
            per_pattern.append(_stack_template(t, g.n_reps))
        tmpl.append(per_pattern)
    return tmpl


def _stack_template(t, reps):
    return jax.tree_util.tree_map(
        lambda trip: ((reps,) + trip[0], trip[1], P(*((None,) + tuple(trip[2])))),
        t, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and isinstance(x[0], tuple))


def abstract_cache(tmpl):
    def mk(trip):
        shape, dtype, _ = trip
        return jax.ShapeDtypeStruct(shape, dtype)
    return _map_tmpl(tmpl, mk)


def cache_pspecs(tmpl):
    return _map_tmpl(tmpl, lambda trip: trip[2])


def zero_cache(tmpl):
    def mk(trip):
        shape, dtype, _ = trip
        if dtype == jnp.int32:       # pos slots start empty
            return jnp.full(shape, -1, dtype)
        return jnp.zeros(shape, dtype)
    return _map_tmpl(tmpl, mk)


def _map_tmpl(tmpl, fn):
    return jax.tree_util.tree_map(
        fn, tmpl,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# Paged KV cache (vLLM-style block pool)
# ---------------------------------------------------------------------------
#
# Instead of one exact-length lane per slot, K/V live in a fixed pool of
# fixed-size pages; each serving slot owns a host-managed list of page ids
# (its block table).  Token t of a slot lives at page block_table[t // psz],
# offset t % psz.  This keeps the paper's residency discipline — the pool is
# one static allocation whose placement never changes — while letting a
# single compiled decode/prefill-chunk pair serve arbitrary request mixes.
#
# Pool layout keeps the contiguous convention with the page pool standing in
# for the batch dim:   kp / vp : (reps, n_replicas, n_pages, tp*n_kv_loc,
# psz, D) sharded P(None, dp_axes, None, tpax, None, None): heads follow
# TP, and the leading replica dim is sharded over the data axes so each
# data shard holds only its own replicas' pages — the paper's
# stationary-local-memory discipline at serving scale.  Block tables stay
# replica-relative (ids in [0, n_pages)); ``core.steps`` folds each
# shard's local replicas into one larger pool and offsets the tables
# row-wise, so attention/kernels never see the replica dim.  With
# n_replicas == 1 (the default) the layout degenerates to the old
# replicated-pool dp=1 behavior.
#
# Page 0 of every replica is reserved as a scratch page: idle decode lanes
# point their block tables at it, so the fused decode step can always run
# full-batch without masking writes.

SCRATCH_PAGE = 0
SCRATCH_SLAB = 0


def kv_pool_is_quantized(plan) -> bool:
    """True when the paged self-KV / cross-KV pools store int8 payloads with
    per-(page, slot) float scales (``plan.kv_cache_dtype == "int8"``)."""
    return jnp.dtype(plan.kv_cache_dtype) == jnp.int8


def ssm_pool_is_quantized(plan) -> bool:
    """True when the SSM state slabs store int8 payloads with per-(slab,
    head) float scales (``plan.ssm_cache_dtype == "int8"``)."""
    return bool(plan.ssm_cache_dtype) and \
        jnp.dtype(plan.ssm_cache_dtype) == jnp.int8


def cache_profile(cfg) -> set:
    """Union of decode-cache kinds across the decoder stack:
    subset of {"kv", "ssm", "cross_kv"}."""
    kinds = set()
    for spec in cfg.layer_specs():
        kinds.update(spec.cache_kinds())
    return kinds


def paged_cache_supported(cfg) -> tuple:
    """-> (ok, reason).  Paged serving covers every decode-capable arch
    whose serving inputs are tokens (+ encoder frames): attention-only and
    hybrid/SSM decoders page (or slab) their self state, and enc-dec
    decoders page the encoder memory's cross-KV."""
    if cfg.is_encoder_only:
        return False, "encoder-only arch has no decode path to serve"
    if cfg.frontend == "vision_patches":
        return False, ("vision frontend needs image-embed injection at "
                       "prefill; the token-only chunked prefill step "
                       "cannot carry it")
    return True, ""


def paged_cache_template(cfg, plan, lay, n_pages: int, page_size: int,
                         n_replicas: int = 1, n_slabs: int = 0):
    """Full paged cache template: list (per layer group) of stacked pools.

    Per layer, by cache kind:

    * ``kv``    — ``kp``/``vp`` page pools (block-table indirection),
    * ``ssm``   — ``statep``/``conv_xp``/``conv_Bp``/``conv_Cp`` slab
      pools: ``n_slabs`` rows of per-request recurrent state, read/written
      by slot-relative slab id (no paging — SSD state is O(1) per request
      and cannot be shared),
    * ``cross_kv`` — ``ckp``/``cvp`` page pools holding the encoder
      memory's K/V.  Cross pages share the self-KV page-id space (one
      allocator covers both) and are immutable after the encode-time
      write, so they are shared by refcount alone — no copy-on-write.

    ``n_replicas`` adds a leading replica dim sharded over ``plan.dp_axes``
    — each data shard stores only its replicas' pages/slabs (dp>1
    serving).

    **Quantized pools** (``plan.kv_cache_dtype == "int8"`` /
    ``plan.ssm_cache_dtype == "int8"``): payloads store int8 and each pool
    gains a small float32 scale side tensor — ``ksp``/``vsp`` (and
    ``cksp``/``cvsp`` for cross) of shape (n_replicas, n_pages, page_size),
    one scale per (page, token slot) so every token row is quantized
    independently of write order (schedule/speculation/preemption
    invariance by construction); ``sscalep`` of shape (n_replicas,
    n_slabs, tp*H), one scale per (slab, head), re-written wholesale on
    every state scatter.  A zero scale dequantizes to exact zeros, so
    ``zero_paged_cache`` leaves the quantized pools indistinguishable
    from zeroed fp pools.  Float dtypes produce the exact pre-quantization
    templates — no scale leaves exist."""
    ok, why = paged_cache_supported(cfg)
    if not ok:
        raise ValueError(f"paged cache unsupported for {cfg.name}: {why}")
    assert n_replicas >= 1, n_replicas
    kvd = jnp.dtype(plan.kv_cache_dtype)
    kv_quant = kv_pool_is_quantized(plan)
    d = cfg.head_dim_
    tpax = "model" if plan.tp > 1 else None
    dpax = tuple(plan.dp_axes)
    pool = ((n_replicas, n_pages, plan.tp * lay.attn.n_kv_loc, page_size, d),
            kvd, P(dpax, None, tpax, None, None))
    scale = ((n_replicas, n_pages, page_size), jnp.float32,
             P(dpax, None, None))
    slab = None
    if "ssm" in cache_profile(cfg):
        assert n_slabs > 1, f"ssm layers need n_slabs > 1, got {n_slabs}"
        H, Pdim, N = lay.ssm.hq_loc, cfg.ssm_head_dim, cfg.ssm_state
        K = cfg.ssm_conv
        sd = jnp.int8 if ssm_pool_is_quantized(plan) else jnp.float32
        slab = {
            "statep": ((n_replicas, n_slabs, plan.tp * H, Pdim, N),
                       sd, P(dpax, None, tpax, None, None)),
            "conv_xp": ((n_replicas, n_slabs, K - 1, plan.tp * H * Pdim),
                        jnp.dtype(cfg.dtype), P(dpax, None, None, tpax)),
            "conv_Bp": ((n_replicas, n_slabs, K - 1, N), jnp.dtype(cfg.dtype),
                        P(dpax, None, None, None)),
            "conv_Cp": ((n_replicas, n_slabs, K - 1, N), jnp.dtype(cfg.dtype),
                        P(dpax, None, None, None)),
        }
        if sd == jnp.int8:
            slab["sscalep"] = ((n_replicas, n_slabs, plan.tp * H),
                               jnp.float32, P(dpax, None, tpax))
    tmpl = []
    for g in cfg.layer_groups():
        per_pattern = []
        for spec in g.pattern:
            kinds = spec.cache_kinds()
            t = {}
            if "kv" in kinds:
                t["kv"] = {"kp": pool, "vp": pool}
                if kv_quant:
                    t["kv"]["ksp"] = scale
                    t["kv"]["vsp"] = scale
            if "ssm" in kinds:
                t["ssm"] = dict(slab)
            if "cross_kv" in kinds:
                t["cross"] = {"ckp": pool, "cvp": pool}
                if kv_quant:
                    t["cross"]["cksp"] = scale
                    t["cross"]["cvsp"] = scale
            per_pattern.append(_stack_template(t, g.n_reps))
        tmpl.append(per_pattern)
    return tmpl


def fold_replica_pools(cache):
    """(reps, R_loc, n_pages, G, psz, D) -> (reps, R_loc*n_pages, G, psz, D).

    Per-shard view: the shard's local replicas become one larger pool, so
    the attention gather/scatter path is replica-agnostic.  Replica ``i``'s
    page ``p`` lives at folded id ``i * n_pages + p`` (see
    ``replica_table_offsets``)."""
    return jax.tree_util.tree_map(
        lambda pool: pool.reshape((pool.shape[0],
                                   pool.shape[1] * pool.shape[2])
                                  + pool.shape[3:]), cache)


def unfold_replica_pools(cache, n_replicas_loc: int):
    """Inverse of ``fold_replica_pools``."""
    return jax.tree_util.tree_map(
        lambda pool: pool.reshape(
            (pool.shape[0], n_replicas_loc, pool.shape[1] // n_replicas_loc)
            + pool.shape[2:]), cache)


def zero_paged_cache(tmpl):
    return _map_tmpl(tmpl, lambda trip: jnp.zeros(trip[0], trip[1]))


class PageAllocator:
    """Host-side refcounted block-pool allocator (page 0 reserved as scratch).

    All-or-nothing allocation: a request either gets every page it needs up
    front (prompt + max_new_tokens worth) or stays queued — admission control
    instead of mid-flight OOM.  Freed pages return to the pool LIFO, so a
    steady-state request mix reuses a small working set.

    Pages carry a reference count so they can be shared: a freshly allocated
    page has one owner; the prefix cache and additional serving slots take
    extra refs via ``incref``.  A page returns to the free list only when its
    last ref drops (``decref``; ``free`` is a synonym for the sole-owner
    case).  Shared pages are immutable by convention — a slot that must
    append into one first takes a private copy (copy-on-write; see
    ``serving.prefix_cache``).

    Quantized pools additionally track **scale-dirty** pages: every page
    whose last ref drops (via ``decref`` — ``free`` and the speculative
    ``trim`` both funnel through it) is marked so the engine can zero its
    per-slot scale rows before the page is recycled, guaranteeing a
    recycled page never pairs stale scales with fresh payloads
    (``take_scale_dirty``)."""

    def __init__(self, n_pages: int, n_reserved: int = 1):
        assert n_pages > n_reserved, (n_pages, n_reserved)
        self.n_pages = n_pages
        self.n_reserved = n_reserved
        self._free = list(range(n_pages - 1, n_reserved - 1, -1))
        self._free_set = set(self._free)     # O(1) double-free detection
        self._rc = [0] * n_pages
        self._scale_dirty: set = set()       # freed pages w/ stale scale rows
        self.total_allocated = 0             # pages ever handed out (stats)
        self.pages_transferred_out = 0       # handed to another replica
        self.pages_transferred_in = 0        # received from another replica

    @property
    def n_free(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return self._rc[page]

    def alloc(self, n: int):
        """-> list of n page ids (each refcount 1), or None if the pool
        can't cover n."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        for p in out:
            self._rc[p] = 1
        self.total_allocated += n
        return out

    def incref(self, pages):
        """Add one ref per page (sharing an already-live page)."""
        for p in pages:
            assert self._rc[p] > 0, f"incref of unallocated page {p}"
            self._rc[p] += 1

    def decref(self, pages):
        """Drop one ref per page; pages whose last ref drops are freed."""
        for p in pages:
            assert p >= self.n_reserved, f"freeing reserved page {p}"
            assert p not in self._free_set, f"double free of page {p}"
            self._rc[p] -= 1
            if self._rc[p] == 0:
                self._free.append(p)
                self._free_set.add(p)
                self._scale_dirty.add(p)

    def free(self, pages):
        """Release sole-owner pages.  Errors on a shared page: silently
        dropping one of several refs here would hand a prefix-cache- or
        slot-shared page back to the free list while it is still mapped —
        use ``decref`` for the multi-ref case."""
        for p in pages:
            assert self._rc[p] == 1, \
                f"free() of shared page {p} (refcount {self._rc[p]}); " \
                f"multi-ref releases must go through decref()"
        self.decref(pages)

    def trim(self, pages):
        """Release a slot's *tail* pages while the slot stays live (the
        speculative-decoding rollback path: draft-headroom pages past the
        block-table keep point).  Unlike ``free``, a trimmed page may
        legitimately be shared by the time the trim runs — a preemption
        donated the slot's resident pages to the prefix cache, or another
        admission mapped them — so trim drops exactly the slot's own
        reference and the page returns to the pool only when its last
        sharer lets go."""
        self.decref(pages)

    def take_scale_dirty(self) -> list:
        """Drain the pages needing a scale reset before reuse: every page
        freed (last ref dropped) since the previous drain that is still on
        the free list.  A dirty page meanwhile re-allocated stays marked —
        resetting it mid-flight would corrupt the new occupant, and its
        stale rows are benign until it is freed again (per-slot scales are
        rewritten atomically with every payload write, and un-rewritten
        slots sit beyond the occupant's length mask)."""
        out = sorted(self._scale_dirty & self._free_set)
        self._scale_dirty.difference_update(out)
        return out


class SlabAllocator:
    """Host-side free-list allocator for SSM state slabs (slab 0 scratch).

    A slab holds one request's recurrent state (SSD ``state`` plus conv
    tails) across every SSM/hybrid layer.  Unlike pages, slabs are never
    shared — recurrent state has exactly one owner and cannot be re-derived
    from donated pages — so there are no refcounts: ``alloc`` hands out one
    slab id (or None when exhausted, for all-or-nothing admission) and
    ``free`` returns it.  The engine zeroes a slab at allocation and
    snapshot/restores it through a host-side stash across preemption."""

    def __init__(self, n_slabs: int, n_reserved: int = 1):
        assert n_slabs > n_reserved, (n_slabs, n_reserved)
        self.n_slabs = n_slabs
        self.n_reserved = n_reserved
        self._free = list(range(n_slabs - 1, n_reserved - 1, -1))
        self.total_allocated = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self):
        """-> one slab id, or None when the pool is exhausted."""
        if not self._free:
            return None
        self.total_allocated += 1
        return self._free.pop()

    def free(self, slab: int):
        assert slab >= self.n_reserved, f"freeing reserved slab {slab}"
        assert slab not in self._free, f"double free of slab {slab}"
        self._free.append(slab)


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def handoff_refs(src_alloc: PageAllocator, src_pages,
                 dst_alloc: PageAllocator, dst_pages):
    """Atomically move ownership of a page run between replica allocators.

    The destination side allocates fresh pages up front (``dst_pages``,
    each refcount 1 — the device transfer copies payload bytes into them);
    this bookkeeping step then drops the source slot's references exactly
    once.  Source pages that the radix prefix cache (or another slot) still
    shares simply lose one ref and stay resident on the source replica —
    the handoff never frees a shared page out from under its sharers.
    """
    assert src_alloc is not dst_alloc, "handoff within one replica"
    assert len(src_pages) == len(dst_pages), (src_pages, dst_pages)
    for p in dst_pages:
        assert dst_alloc.refcount(p) == 1, \
            f"handoff into shared destination page {p} " \
            f"(refcount {dst_alloc.refcount(p)}); destination pages must " \
            f"be freshly allocated"
    src_alloc.decref(src_pages)
    src_alloc.pages_transferred_out += len(src_pages)
    dst_alloc.pages_transferred_in += len(dst_pages)
