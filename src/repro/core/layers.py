"""Normalization, rotary embeddings, activations, sharded embedding/LM head."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives as cc


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6, denom: int = 0):
    """RMSNorm.  ``denom`` overrides the averaging count (masked/padded dims)."""
    xf = x.astype(jnp.float32)
    n = denom or x.shape[-1]
    ms = jnp.sum(xf * xf, axis=-1, keepdims=True) / n
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rmsnorm_from_sumsq(x, sumsq, n, scale, eps=1e-6):
    """RMSNorm given an externally-reduced sum of squares (cross-shard norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(sumsq / n + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, D) with positions (..., S) or (S,)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))
    ang = positions[..., None].astype(jnp.float32) * freqs          # (...,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Vocab-sharded embedding / LM head (zero duplication: paper §IV applied to
# the largest tensors in the model)
# ---------------------------------------------------------------------------

def sharded_embed(tokens, table_local, shard_idx, v_loc, axes=("model",), tag="embed"):
    """tokens: (B, S) int32; table_local: (v_loc, E) — this shard's vocab rows.

    Each shard gathers the rows it owns (out-of-range ids hit a zero row) and
    one psum over the TP axis assembles the full embedding.
    """
    offset = shard_idx * v_loc
    local_ids = tokens - offset
    in_range = (local_ids >= 0) & (local_ids < v_loc)
    safe = jnp.clip(local_ids, 0, v_loc - 1)
    emb = jnp.take(table_local, safe, axis=0)
    emb = jnp.where(in_range[..., None], emb, jnp.zeros_like(emb))
    return cc.psum(emb, axes, tag)


def sharded_logits(x, head_local):
    """x: (B, S, E); head_local: (v_loc, E) -> local logits (B, S, v_loc)."""
    return jnp.einsum("bse,ve->bsv", x, head_local)


def sharded_xent(logits_local, labels, shard_idx, v_loc, n_valid_vocab,
                 axes=("model",), tag="loss"):
    """Cross-entropy with vocab-sharded logits.

    logsumexp needs two tiny psums (max + sum-exp); the label logit is
    recovered with a masked gather + psum.  Padded vocab rows are masked.
    """
    lg = logits_local.astype(jnp.float32)
    # mask padded vocab slots (only the last shard has them)
    col = shard_idx * v_loc + jnp.arange(v_loc)
    lg = jnp.where(col < n_valid_vocab, lg, -1e30)
    # max-shift is for numerical stability only: gradient of lse stays exactly
    # softmax when gmax is treated as a constant (pmax has no JVP rule).
    local_max = jax.lax.stop_gradient(jnp.max(lg, axis=-1))
    gmax = cc.psum_max(local_max, axes, tag + "/max")
    gmax = jax.lax.stop_gradient(gmax)
    sumexp = jnp.sum(jnp.exp(lg - gmax[..., None]), axis=-1)
    gsum = cc.psum(sumexp, axes, tag + "/sumexp")
    lse = gmax + jnp.log(gsum)
    local_ids = labels - shard_idx * v_loc
    in_range = (local_ids >= 0) & (local_ids < v_loc)
    safe = jnp.clip(local_ids, 0, v_loc - 1)
    picked = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    picked = jnp.where(in_range, picked, 0.0)
    label_logit = cc.psum(picked, axes, tag + "/label")
    return lse - label_logit                                  # (B, S) nll
