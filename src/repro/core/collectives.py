"""Collective wrappers with an audited communication ledger.

The paper's contribution is a partitioning that needs *exactly two
synchronizations per transformer block* and never duplicates weights.  We
make that contract explicit: every collective the model issues goes through
these wrappers, which (a) perform the jax.lax collective, and (b) record
(bytes, axis, tag) into a trace-time ``CommLedger``.

Because layer stacks run under ``lax.scan``, a collective inside the scanned
body is *traced once* but *executed n_reps times*; the model code sets the
ledger's ``multiplier`` around scanned regions so recorded byte counts are
exact.  The ledger is the primary source for the roofline collective term
(HLO text parsing cannot see trip counts) and is cross-checked against the
lowered HLO in tests.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------


@dataclass
class CommRecord:
    tag: str                 # call-site label, e.g. "block/attn_out"
    kind: str                # psum | psum_scatter | all_gather | all_to_all | ppermute
    axes: tuple              # mesh axis names reduced/gathered over
    bytes_per_device: float  # payload bytes entering the collective, per device
    count: float             # execution count (scan multipliers applied)


class CommLedger(threading.local):
    """Thread-local trace-time ledger of collective calls."""

    def __init__(self):
        self.records: list = []
        self._mult = 1.0
        self._active = False
        self._sync_counts: dict = defaultdict(float)  # tag prefix -> syncs

    # -- context management --------------------------------------------------
    def start(self):
        self.records = []
        self._mult = 1.0
        self._active = True
        self._sync_counts = defaultdict(float)

    def stop(self):
        self._active = False

    class _Scale:
        def __init__(self, ledger, k):
            self.ledger, self.k = ledger, k

        def __enter__(self):
            self.ledger._mult *= self.k

        def __exit__(self, *exc):
            self.ledger._mult /= self.k

    def scaled(self, k: float):
        """Multiply byte/sync counts recorded inside (use around lax.scan)."""
        return CommLedger._Scale(self, k)

    # -- recording -----------------------------------------------------------
    def record(self, tag, kind, axes, nbytes, syncs=1.0):
        if not self._active:
            return
        self.records.append(CommRecord(tag, kind, tuple(axes), float(nbytes),
                                        self._mult))
        self._sync_counts[tag] += syncs * self._mult

    # -- queries -------------------------------------------------------------
    def total_bytes(self, wire_model: str = "ring") -> float:
        """Per-device bytes crossing links.

        ``ring`` models the standard bidirectional-ring cost actually emitted
        by XLA on TPU tori: all-reduce of payload P over an axis of size n
        moves 2*P*(n-1)/n per device; gather/scatter/all_to_all move
        P*(n-1)/n.
        """
        total = 0.0
        for r in self.records:
            total += r.count * wire_bytes(r.kind, r.bytes_per_device, r.axes)
        return total

    def bytes_by_tag(self):
        out = defaultdict(float)
        for r in self.records:
            out[r.tag] += r.count * wire_bytes(r.kind, r.bytes_per_device, r.axes)
        return dict(out)

    def sync_count(self, prefix: str = "") -> float:
        return sum(v for k, v in self._sync_counts.items() if k.startswith(prefix))

    def summary(self):
        return {
            "total_wire_bytes_per_device": self.total_bytes(),
            "by_tag": self.bytes_by_tag(),
            "n_collectives": sum(r.count for r in self.records),
        }


LEDGER = CommLedger()

_AXIS_SIZES: dict = {}  # set by the model builder before tracing


def set_axis_sizes(sizes: dict):
    _AXIS_SIZES.clear()
    _AXIS_SIZES.update({k: int(v) for k, v in sizes.items()})


def axis_size(axes) -> int:
    n = 1
    for a in axes:
        n *= _AXIS_SIZES.get(a, 1)
    return n


def wire_bytes(kind: str, payload: float, axes) -> float:
    n = axis_size(axes)
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if kind == "psum":            # ring all-reduce = reduce-scatter + all-gather
        return 2.0 * payload * frac
    if kind in ("psum_scatter", "all_gather", "all_to_all"):
        return payload * frac
    if kind == "ppermute":
        return payload
    raise ValueError(kind)


def _nbytes(x) -> int:
    return int(np.prod(x.shape)) * x.dtype.itemsize if hasattr(x, "shape") else 4


def _tree_bytes(tree) -> int:
    return sum(_nbytes(leaf) for leaf in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Collective ops (ledger-instrumented)
# ---------------------------------------------------------------------------

def _live_axes(axes) -> tuple:
    """Axes that exist in the current mesh with size > 1."""
    return tuple(a for a in axes if _AXIS_SIZES.get(a, 1) > 1)


def psum(x, axes, tag: str):
    """All-reduce over ``axes``; identity (and zero wire bytes) if all size-1."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    live = _live_axes(axes)
    LEDGER.record(tag, "psum", live, _tree_bytes(x))
    if not live:
        return x
    return jax.lax.psum(x, live)


def psum_max(x, axes, tag: str):
    """All-reduce-max (same wire cost as psum)."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    live = _live_axes(axes)
    LEDGER.record(tag, "psum", live, _tree_bytes(x))
    if not live:
        return x
    return jax.lax.pmax(x, live)


def psum_scatter(x, axis: str, tag: str, scatter_dimension: int = 0, tiled=True):
    live = _live_axes((axis,))
    LEDGER.record(tag, "psum_scatter", live, _tree_bytes(x))
    if not live:
        return x
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_dimension,
                                tiled=tiled)


def all_gather(x, axis: str, tag: str, gather_dimension: int = 0, tiled=True):
    live = _live_axes((axis,))
    # payload for ring all-gather accounting = the *output* size
    LEDGER.record(tag, "all_gather", live,
                  _tree_bytes(x) * axis_size(live))
    if not live:
        return x
    return jax.lax.all_gather(x, axis, axis=gather_dimension, tiled=tiled)


def all_to_all(x, axis: str, tag: str, split_axis: int = 0, concat_axis: int = 0):
    live = _live_axes((axis,))
    LEDGER.record(tag, "all_to_all", live, _tree_bytes(x))
    if not live:
        return x
    return jax.lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def ppermute(x, axis: str, perm, tag: str):
    live = _live_axes((axis,))
    LEDGER.record(tag, "ppermute", live, _tree_bytes(x))
    if not live:
        return x
    return jax.lax.ppermute(x, axis, perm)


# ---------------------------------------------------------------------------
# Hierarchical reduction (paper Fig. 1 adapted: in-pod ring, then cross-pod)
# ---------------------------------------------------------------------------

def hierarchical_psum(tree, inner_axes, outer_axes, tag: str):
    """Two-level all-reduce mirroring the paper's groups-of-4 tree.

    On the MCU system the tree bounds MIPI contention; on a TPU fleet the
    same structure separates the fast in-pod ICI reduction from the slow
    cross-pod (DCN-class) hop: reduce-scatter in-pod -> tiny cross-pod
    all-reduce on 1/n of the payload -> in-pod all-gather.  For flat meshes
    (no outer axis) it degrades to a single psum.
    """
    inner = _live_axes((inner_axes,) if isinstance(inner_axes, str) else tuple(inner_axes))
    outer = _live_axes((outer_axes,) if isinstance(outer_axes, str) else tuple(outer_axes))
    if not outer:
        return psum(tree, inner, tag) if inner else tree
    if not inner:
        return psum(tree, outer, tag)

    def _reduce_leaf(x):
        flat = x.reshape(-1)
        n = axis_size(inner)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        shard = psum_scatter(flat, inner[0], tag + "/rs")       # in-pod RS
        shard = psum(shard, outer, tag + "/xpod")               # cross-pod AR (1/n payload)
        full = all_gather(shard, inner[0], tag + "/ag")         # in-pod AG
        return full[: x.size].reshape(x.shape) if pad else full.reshape(x.shape)

    # note: inner[0] — multi-inner-axis trees reduce over the first live axis
    # per level; remaining inner axes are folded into a final psum.
    out = jax.tree_util.tree_map(_reduce_leaf, tree)
    if len(inner) > 1:
        out = psum(out, inner[1:], tag + "/rest")
    return out
