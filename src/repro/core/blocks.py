"""Per-shard transformer layer forward — the paper's §IV contract in code.

Every layer issues **exactly one psum per weight-partitioned sublayer**:
one after the mixer (attention / SSD / hybrid fusion), one after the FFN
(enc-dec adds one for cross-attention).  All head/F/expert compute is local.
The residual is added around the reduced value — the paper's "skip folded
into the all-reduce".  All collectives go through the CommLedger so the
contract is audited by tests and the roofline.

All functions here run INSIDE shard_map: tp-sharded params carry a leading
local axis of size 1 (``_lo`` strips it), replicated params arrive whole.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FFN_MOE, FFN_NONE, MIX_ATTN, MIX_SSM
from repro.core import collectives as cc
from repro.core import ssm as ssd
from repro.core.attention import decode_attention, flash_attention, \
    gather_pages, gather_pages_dequant, paged_decode_attention, \
    paged_verify_attention
from repro.core.layers import activation, apply_norm, apply_rope, rmsnorm, \
    rmsnorm_from_sumsq
from repro.core.moe import moe_ffn_ep, moe_ffn_tp


W8_SCALE = 64.0        # per-tensor int8 weight scale (deployment experiments;
                       # production would carry per-channel scales)
KVQ = {"scale": 16.0}  # fixed-point int8 KV scale (set from plan at trace)


def _lo(w):
    x = w[0]
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) * (1.0 / W8_SCALE)).astype(jnp.bfloat16)
    return x


def _kv_q(x, dtype):
    """Quantize k/v for the cache (int8 fixed-point or plain cast)."""
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * KVQ["scale"]),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def _kv_dq(x, compute_dtype):
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) * (1.0 / KVQ["scale"])
                ).astype(compute_dtype)
    return x.astype(compute_dtype)


def _row_quant(x):
    """Per-token-row int8 quantization for the paged pools.

    x: (..., G, D) — one token row per leading index.  Each row gets its
    own scale ``amax / 127`` over its (G, D) values, so the stored bytes
    are a pure function of the row's value: write order, speculation
    rollbacks and preemption/resume chunking cannot change them (the
    schedule-invariance the identity gates rely on).  A zero row gets
    scale 0 and dequantizes to exact zeros.  -> (int8 like x, scale
    (...,) float32)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    inv = jnp.where(amax > 0, 127.0 / jnp.maximum(amax, 1e-30), 0.0)
    q = jnp.clip(jnp.round(xf * inv[..., None, None]),
                 -127, 127).astype(jnp.int8)
    return q, amax * (1.0 / 127.0)


def shard_index(axis="model"):
    return jax.lax.axis_index(axis) if cc.axis_size((axis,)) > 1 else 0


def tp_index(plan):
    """This device's tensor-parallel shard index (0 when tp == 1)."""
    return shard_index(plan.tp_axis) if plan.tp > 1 else 0


def dp_linear_index(dp_axes):
    idx = 0
    for a in dp_axes:
        n = cc.axis_size((a,))
        idx = idx * n + (jax.lax.axis_index(a) if n > 1 else 0)
    return idx


# ---------------------------------------------------------------------------
# Attention mixer
# ---------------------------------------------------------------------------

def _project_qkv(xn, pa, cfg, lay):
    q = jnp.einsum("bse,ehd->bshd", xn, _lo(pa["wq"]))
    k = jnp.einsum("bse,ehd->bshd", xn, _lo(pa["wk"]))
    v = jnp.einsum("bse,ehd->bshd", xn, _lo(pa["wv"]))
    if cfg.qk_norm:
        q = rmsnorm(q, pa["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, pa["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(q, k, positions, cfg):
    if cfg.rope_theta > 0:
        q = _rope_heads(q, positions, cfg)
        k = _rope_heads(k, positions, cfg)
    return q, k


def _rope_heads(x, positions, cfg):
    # x: (B, S, H, D); positions: (B, S)
    xt = x.swapaxes(1, 2)                           # (B, H, S, D)
    xt = apply_rope(xt, positions[:, None, :], cfg.rope_theta)
    return xt.swapaxes(1, 2)


def _group_q(q, lay):
    """(B,S,hq_loc,D) -> (B, G, R, S, D)"""
    B, S, _, D = q.shape
    hl = lay.attn
    q = q.reshape(B, S, hl.n_kv_loc, hl.r, D)
    return q.transpose(0, 2, 3, 1, 4)


def _ungroup(o, lay):
    """(B,G,R,S,D) -> (B,S,hq_loc*D)"""
    B, G, R, S, D = o.shape
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, G * R * D)


def attn_mixer(xn, pa, cfg, plan, lay, spec, mode, kv_cache, positions, pos,
               pages=None):
    """-> (partial_out (B,S,E), new_kv_cache)."""
    B, S, E = xn.shape
    hl = lay.attn
    d = cfg.head_dim_
    window = cfg.window_for(spec)
    q, k, v = _project_qkv(xn, pa, cfg, lay)
    q, k = _rope_qk(q, k, positions, cfg)
    qg = _group_q(q, lay)                            # (B,G,R,S,D)
    kg = k.swapaxes(1, 2)                            # (B,G,S,D)
    vg = v.swapaxes(1, 2)
    new_cache = kv_cache

    if kv_cache is not None and "kp" in kv_cache:    # paged path
        out, new_cache = _paged_attn(qg, kg, vg, kv_cache, pages, mode,
                                     positions, pos, window, cfg)
    elif mode == "decode":
        new_cache = _kv_write(kv_cache, kg, vg, pos, plan)
        out = decode_attention(
            qg[:, :, :, 0], _kv_dq(new_cache["k"], qg.dtype),
            _kv_dq(new_cache["v"], qg.dtype), new_cache["pos"], pos,
            window=window, scale=cfg.attn_scale,
            seq_axes=tuple(plan.dp_axes) if plan.seq_shard_kv else ())
        out = out[:, :, :, None, :]                  # (B,G,R,1,D)
    else:
        from repro.core.attention import flash_attention_split
        if plan.attn_scheme == "split" and cfg.causal and window == 0:
            out = flash_attention_split(qg, kg, vg, scale=cfg.attn_scale)
        else:
            out = flash_attention(qg, kg, vg, causal=cfg.causal,
                                  window=window, scale=cfg.attn_scale)
        if mode == "prefill" and kv_cache is not None:
            new_cache = _kv_fill(kv_cache, kg, vg, positions, plan)

    o = _ungroup(out, lay)                           # (B,S,hq_loc*D)
    return jnp.einsum("bsx,xe->bse", o,
                      _lo(pa["wo"]).reshape(hl.hq_loc * d, E)), new_cache


def cross_attn_mixer(xn, pa, cfg, plan, lay, mode, cross_cache, enc_memory,
                     pages=None):
    """Cross-attention: q from x, kv from encoder memory (or cross cache).

    Paged path (``cross_cache`` holds ``ckp``/``cvp`` pools): K/V were
    written once at admission by ``steps.make_cross_kv_write_step`` and are
    READ-ONLY here — both decode and chunked prefill gather them through
    the slot's cross block table (``pages["cross_block_table"]``) and slice
    to the static encoder length, so shared cross pages are never written.
    """
    B, S, E = xn.shape
    hl = lay.attn
    d = cfg.head_dim_
    q = jnp.einsum("bse,ehd->bshd", xn, _lo(pa["wq"]))
    if cfg.qk_norm:
        q = rmsnorm(q, pa["q_norm"], cfg.norm_eps)
    qg = _group_q(q, lay)
    if cross_cache is not None and "ckp" in cross_cache:   # paged, read-only
        cbt = pages["cross_block_table"]
        S_enc = cfg.enc_seq_len
        if "cksp" in cross_cache:                          # int8 + scales
            kg = gather_pages_dequant(cross_cache["ckp"], cross_cache["cksp"],
                                      cbt, qg.dtype)[:, :, :S_enc]
            vg = gather_pages_dequant(cross_cache["cvp"], cross_cache["cvsp"],
                                      cbt, qg.dtype)[:, :, :S_enc]
        else:
            kg = gather_pages(_kv_dq(cross_cache["ckp"], qg.dtype),
                              cbt)[:, :, :S_enc]
            vg = gather_pages(_kv_dq(cross_cache["cvp"], qg.dtype),
                              cbt)[:, :, :S_enc]
        if mode == "decode":
            out = decode_attention(
                qg[:, :, :, 0], kg, vg,
                jnp.broadcast_to(jnp.arange(S_enc), (B, S_enc)),
                jnp.full((B,), S_enc, jnp.int32), window=0,
                scale=cfg.attn_scale)
            out = out[:, :, :, None, :]
        else:
            out = flash_attention(qg, kg, vg, causal=False, window=0,
                                  scale=cfg.attn_scale)
        o = _ungroup(out, lay)
        return jnp.einsum("bsx,xe->bse", o,
                          _lo(pa["wo"]).reshape(hl.hq_loc * d, E)), None
    if mode == "decode":
        kg = cross_cache["k"].astype(qg.dtype)
        vg = cross_cache["v"].astype(qg.dtype)
        S_enc = kg.shape[2]
        out = decode_attention(
            qg[:, :, :, 0], kg, vg,
            jnp.broadcast_to(jnp.arange(S_enc), (B, S_enc)),
            jnp.full((B,), S_enc, jnp.int32), window=0, scale=cfg.attn_scale)
        out = out[:, :, :, None, :]
    else:
        k = jnp.einsum("bse,ehd->bshd", enc_memory, _lo(pa["wk"]))
        v = jnp.einsum("bse,ehd->bshd", enc_memory, _lo(pa["wv"]))
        if cfg.qk_norm:
            k = rmsnorm(k, pa["k_norm"], cfg.norm_eps)
        kg, vg = k.swapaxes(1, 2), v.swapaxes(1, 2)
        out = flash_attention(qg, kg, vg, causal=False, window=0,
                              scale=cfg.attn_scale)
        if mode == "prefill" and cross_cache is not None:
            cross_cache = {"k": kg.astype(cross_cache["k"].dtype),
                           "v": vg.astype(cross_cache["v"].dtype)}
    o = _ungroup(out, lay)
    return jnp.einsum("bsx,xe->bse", o,
                      _lo(pa["wo"]).reshape(hl.hq_loc * d, E)), cross_cache


def _kv_write(kv, kg, vg, pos, plan):
    """Decode-step cache write.  kg/vg: (B, G, 1, D); pos: (B,)."""
    B, G, W, D = kv["k"].shape
    if plan.seq_shard_kv:
        W_glob = W * cc.axis_size(plan.dp_axes)
        slot = pos % W_glob
        me = dp_linear_index(plan.dp_axes)
        owner = slot // W
        local_slot = jnp.clip(slot - owner * W, 0, W - 1)
        own = (owner == me)
        bidx = jnp.arange(B)
        k_new = kv["k"].at[bidx, :, local_slot].set(
            jnp.where(own[:, None, None], _kv_q(kg[:, :, 0], kv["k"].dtype),
                      kv["k"][bidx, :, local_slot]))
        v_new = kv["v"].at[bidx, :, local_slot].set(
            jnp.where(own[:, None, None], _kv_q(vg[:, :, 0], kv["v"].dtype),
                      kv["v"][bidx, :, local_slot]))
        p_new = kv["pos"].at[bidx, local_slot].set(
            jnp.where(own, pos, kv["pos"][bidx, local_slot]))
        return {"k": k_new, "v": v_new, "pos": p_new}
    slot = pos % W
    bidx = jnp.arange(B)
    return {
        "k": kv["k"].at[bidx, :, slot].set(_kv_q(kg[:, :, 0], kv["k"].dtype)),
        "v": kv["v"].at[bidx, :, slot].set(_kv_q(vg[:, :, 0], kv["v"].dtype)),
        "pos": kv["pos"].at[bidx, slot].set(pos),
    }


def _paged_attn(qg, kg, vg, kv, pages, mode, positions, pos, window, cfg):
    """Paged-cache attention (decode token or prefill chunk).

    kv: {"kp","vp"} page pools (n_pages, G, psz, D); pages: {"block_table"}.
    Token t of a slot lives at page block_table[t // psz], offset t % psz;
    the gathered stream therefore holds absolute position s at slot s and
    validity reduces to s <= cur_pos (decode) / causal masking (chunk).
    Garbage between a prompt's end and its chunk boundary is never read:
    every later position is decode-written before it first becomes visible.
    """
    bt = pages["block_table"]
    psz = kv["kp"].shape[2]
    quant = "ksp" in kv
    if mode == "decode":
        new = _page_write(kv, kg, vg, pos[:, None], bt, psz)
        if quant:
            out = paged_decode_attention(
                qg[:, :, :, 0], new["kp"], new["vp"], bt, pos, window=window,
                scale=cfg.attn_scale, k_scale=new["ksp"], v_scale=new["vsp"])
        else:
            out = paged_decode_attention(
                qg[:, :, :, 0], _kv_dq(new["kp"], qg.dtype),
                _kv_dq(new["vp"], qg.dtype), bt, pos, window=window,
                scale=cfg.attn_scale)
        return out[:, :, :, None, :], new
    if mode == "verify":
        # speculative verify: token i of the block sits at position
        # pos + i (token 0 = the slot's last accepted token, the rest are
        # drafts).  Write all Q tokens' KV — padded/overflow rows carry
        # position -1 and land on the scratch page — then score every
        # position against the gathered stream in one pass; acceptance
        # and rollback are host-side pos bookkeeping (rejected KV is
        # masked by validity until the next step overwrites it)
        new = _page_write(kv, kg, vg, positions, bt, psz)
        if quant:
            out = paged_verify_attention(
                qg, new["kp"], new["vp"], bt, pos, window=window,
                scale=cfg.attn_scale, k_scale=new["ksp"], v_scale=new["vsp"])
        else:
            out = paged_verify_attention(
                qg, _kv_dq(new["kp"], qg.dtype), _kv_dq(new["vp"], qg.dtype),
                bt, pos, window=window, scale=cfg.attn_scale)
        return out, new
    # prefill chunk: write the chunk, then attend to the gathered prefix
    new = _page_write(kv, kg, vg, positions, bt, psz)
    if quant:
        k_all = gather_pages_dequant(new["kp"], new["ksp"], bt, qg.dtype)
        v_all = gather_pages_dequant(new["vp"], new["vsp"], bt, qg.dtype)
    else:
        k_all = gather_pages(_kv_dq(new["kp"], qg.dtype), bt)  # (B,G,L,D)
        v_all = gather_pages(_kv_dq(new["vp"], qg.dtype), bt)
    out = flash_attention(qg, k_all, v_all, causal=True, window=window,
                          scale=cfg.attn_scale, q_offset=positions[0, 0])
    return out, new


def _page_write(kv, kg, vg, positions, bt, psz):
    """Scatter new K/V into the page pool.  kg/vg: (B, G, C, D);
    positions: (B, C) absolute token positions (C = 1 for decode).
    Negative positions (padded verify queries) route to the scratch page
    (page 0), whose contents are never read by a live slot.

    Quantized pools (``ksp``/``vsp`` present): each token row is quantized
    independently with its own per-row scale (``_row_quant``), and the
    scale is scattered atomically with the payload into the per-(page,
    slot) scale tensor."""
    B, G, C, D = kg.shape
    safe = jnp.maximum(positions, 0)
    pid = jnp.take_along_axis(bt, safe // psz, axis=1)         # (B, C)
    pid = jnp.where(positions >= 0, pid, 0)
    off = safe % psz
    flat_pid, flat_off = pid.reshape(-1), off.reshape(-1)
    if "ksp" in kv:
        kq, ks = _row_quant(kg.transpose(0, 2, 1, 3))          # (B,C,G,D)
        vq, vs = _row_quant(vg.transpose(0, 2, 1, 3))
        return {
            "kp": kv["kp"].at[flat_pid, :, flat_off].set(
                kq.reshape(B * C, G, D)),
            "vp": kv["vp"].at[flat_pid, :, flat_off].set(
                vq.reshape(B * C, G, D)),
            "ksp": kv["ksp"].at[flat_pid, flat_off].set(ks.reshape(B * C)),
            "vsp": kv["vsp"].at[flat_pid, flat_off].set(vs.reshape(B * C)),
        }
    kq = _kv_q(kg, kv["kp"].dtype).transpose(0, 2, 1, 3)       # (B,C,G,D)
    vq = _kv_q(vg, kv["vp"].dtype).transpose(0, 2, 1, 3)
    return {
        "kp": kv["kp"].at[flat_pid, :, flat_off].set(kq.reshape(B * C, G, D)),
        "vp": kv["vp"].at[flat_pid, :, flat_off].set(vq.reshape(B * C, G, D)),
    }


def _kv_fill(kv, kg, vg, positions, plan):
    """Prefill cache write: keep the last W tokens at ring slots."""
    B, G, W, D = kv["k"].shape
    S = kg.shape[2]
    if plan.seq_shard_kv:
        # each data shard stores its contiguous slice [me*W, (me+1)*W)
        me = dp_linear_index(plan.dp_axes)
        start = me * W
        take = jnp.clip(jnp.arange(W) + start, 0, S - 1)
        valid = (jnp.arange(W) + start) < S
        k_sl = jnp.take(kg, take, axis=2)
        v_sl = jnp.take(vg, take, axis=2)
        p_sl = jnp.where(valid[None, :],
                         jnp.take(positions, take, axis=1), -1)
        return {"k": _kv_q(k_sl, kv["k"].dtype),
                "v": _kv_q(v_sl, kv["v"].dtype), "pos": p_sl}
    n = min(W, S)
    k_tail, v_tail = kg[:, :, S - n:], vg[:, :, S - n:]
    p_tail = positions[:, S - n:]
    slots = p_tail[0] % W                            # same for all batch rows
    k_new = kv["k"].at[:, :, slots].set(_kv_q(k_tail, kv["k"].dtype))
    v_new = kv["v"].at[:, :, slots].set(_kv_q(v_tail, kv["v"].dtype))
    p_new = kv["pos"].at[:, slots].set(p_tail)
    return {"k": k_new, "v": v_new, "pos": p_new}


# ---------------------------------------------------------------------------
# SSD mixer (mamba2 / hymba SSM heads)
# ---------------------------------------------------------------------------

def _cp_halo(x, plan, K):
    """Receive the previous CP shard's last K-1 rows (conv halo).  The first
    shard gets zeros (ppermute leaves unsourced destinations zero), matching
    causal-conv zero padding at sequence start."""
    axis = plan.cp_axes[0]
    n = cc.axis_size(plan.cp_axes)
    tail = x[:, -(K - 1):]
    return cc.ppermute(tail, axis, [(i, i + 1) for i in range(n - 1)],
                       "block/cp_halo")


def _cp_state_prefix(C_loc, D_loc, plan):
    """Incoming SSD state for this CP shard.

    Gather every shard's (total_decay D_i, state contribution C_i), then
    evaluate the prefix recurrence S_j = S_{j-1} * D_{j-1} + C_{j-1} locally
    (identical on all shards; each selects its own entry).  Payload is tiny
    (states, not activations) — this is what makes SSM context parallelism
    collective-cheap (§Perf hillclimb 3).  Returns (S_in, S_global)."""
    axis = plan.cp_axes[0]
    n = cc.axis_size(plan.cp_axes)
    gdt = jnp.dtype(plan.cp_state_dtype)
    Cg = cc.all_gather(C_loc.astype(gdt)[None], axis,
                       "block/cp_state").astype(jnp.float32)     # (n,B,H,P,N)
    Dg = cc.all_gather(D_loc.astype(gdt)[None], axis,
                       "block/cp_decay").astype(jnp.float32)     # (n,B,H)
    running = jnp.zeros_like(C_loc)
    prefixes = []
    for i in range(n):
        prefixes.append(running)
        running = running * Dg[i][..., None, None] + Cg[i]
    me = dp_linear_index(plan.cp_axes)
    return jnp.take(jnp.stack(prefixes), me, axis=0), running


def ssm_mixer(xn, ps, cfg, plan, lay, mode, ssm_cache, chunk_last_idx=None):
    """-> (partial_out (B,S,E), new_cache).  Heads sharded on model axis.

    ``chunk_last_idx`` enables the *chunked-prefill-with-carried-state*
    path (paged serving): the conv tails and SSD state in ``ssm_cache``
    are the running state after the previous chunk, and positions past
    ``chunk_last_idx`` (zero-padding beyond the prompt's end) must not
    touch the recurrence — their dt is zeroed (decay 1, contribution 0)
    and the conv tail is sliced at the last valid row, so the state handed
    to the next chunk/decode step is exact."""
    B, S, E = xn.shape
    H = lay.ssm.hq_loc
    Pd = cfg.ssm_head_dim
    chunked = chunk_last_idx is not None
    cp = bool(plan.cp_axes) and mode != "decode" and not chunked and \
        cc.axis_size(plan.cp_axes) > 1
    z = jnp.einsum("bse,ehp->bshp", xn, _lo(ps["in_z"]))         # (B,S,H,P)
    xi = jnp.einsum("bse,ehp->bshp", xn, _lo(ps["in_x"]))
    dt_raw = jnp.einsum("bse,eh->bsh", xn, _lo(ps["in_dt"]))
    Bm = jnp.einsum("bse,en->bsn", xn, ps["in_B"])               # replicated
    Cm = jnp.einsum("bse,en->bsn", xn, ps["in_C"])

    xi_f = xi.reshape(B, S, H * Pd)
    K = cfg.ssm_conv
    if mode == "decode":
        xi_f, cs_x = ssd.causal_conv(xi_f, _lo(ps["conv_x"]).reshape(H * Pd, -1),
                                     ssm_cache["conv_x"])
        Bm, cs_B = ssd.causal_conv(Bm, ps["conv_B"], ssm_cache["conv_B"])
        Cm, cs_C = ssd.causal_conv(Cm, ps["conv_C"], ssm_cache["conv_C"])
    elif chunked:
        xi_f, cs_x = ssd.causal_conv(xi_f, _lo(ps["conv_x"]).reshape(H * Pd, -1),
                                     ssm_cache["conv_x"],
                                     tail_idx=chunk_last_idx)
        Bm, cs_B = ssd.causal_conv(Bm, ps["conv_B"], ssm_cache["conv_B"],
                                   tail_idx=chunk_last_idx)
        Cm, cs_C = ssd.causal_conv(Cm, ps["conv_C"], ssm_cache["conv_C"],
                                   tail_idx=chunk_last_idx)
    elif cp:
        # conv halo: previous shard's last K-1 pre-conv rows
        xi_f, cs_x = ssd.causal_conv(xi_f, _lo(ps["conv_x"]).reshape(H * Pd, -1),
                                     _cp_halo(xi_f, plan, K))
        Bm, cs_B = ssd.causal_conv(Bm, ps["conv_B"], _cp_halo(Bm, plan, K))
        Cm, cs_C = ssd.causal_conv(Cm, ps["conv_C"], _cp_halo(Cm, plan, K))
    else:
        xi_f, cs_x = ssd.causal_conv(xi_f, _lo(ps["conv_x"]).reshape(H * Pd, -1))
        Bm, cs_B = ssd.causal_conv(Bm, ps["conv_B"])
        Cm, cs_C = ssd.causal_conv(Cm, ps["conv_C"])
    xi = jax.nn.silu(xi_f).reshape(B, S, H, Pd)
    Bm, Cm = jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         _lo(ps["dt_bias"]).astype(jnp.float32))
    A = -jnp.exp(_lo(ps["A_log"]).astype(jnp.float32))
    D = _lo(ps["D"])

    if mode == "decode":
        y, state = ssd.ssd_decode_step(xi[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0],
                                       A, D, ssm_cache["state"])
        y = y[:, None]                                           # (B,1,H,P)
        new_cache = {"state": state, "conv_x": cs_x, "conv_B": cs_B,
                     "conv_C": cs_C}
    elif chunked:
        # padding past the prompt must not advance the recurrence: dt = 0
        # makes a padded position's decay exp(0) = 1 and contribution 0
        dt = jnp.where(jnp.arange(S)[None, :, None] <= chunk_last_idx,
                       dt, 0.0)
        y, state = ssd.ssd_chunked(xi, dt, Bm, Cm, A, D, cfg.ssm_chunk,
                                   state0=ssm_cache["state"])
        new_cache = {"state": state, "conv_x": cs_x, "conv_B": cs_B,
                     "conv_C": cs_C}
    elif cp:
        y0, C_loc, cum_decay, D_loc = ssd.ssd_chunked(
            xi, dt, Bm, Cm, A, D, cfg.ssm_chunk, return_extras=True)
        S_in, S_glob = _cp_state_prefix(C_loc, D_loc, plan)
        # fold the incoming state in (linear correction; exact)
        y_corr = jnp.einsum("bsn,bhpn->bshp", Cm.astype(jnp.float32),
                            S_in) * cum_decay[..., None]
        y = y0 + y_corr.astype(y0.dtype)
        new_cache = None
        if mode == "prefill" and ssm_cache is not None:
            n_cp = cc.axis_size(plan.cp_axes)
            me = dp_linear_index(plan.cp_axes)
            last = (me == n_cp - 1)

            def bcast(t):
                z_ = jnp.where(last, t, jnp.zeros_like(t))
                return cc.psum(z_, plan.cp_axes, "block/cp_tail")
            new_cache = {"state": S_glob, "conv_x": bcast(cs_x),
                         "conv_B": bcast(cs_B), "conv_C": bcast(cs_C)}
    else:
        y, state = ssd.ssd_chunked(xi, dt, Bm, Cm, A, D, cfg.ssm_chunk)
        new_cache = None
        if mode == "prefill" and ssm_cache is not None:
            new_cache = {"state": state, "conv_x": cs_x, "conv_B": cs_B,
                         "conv_C": cs_C}

    # gated RMSNorm over the FULL d_inner (cross-shard sum of squares: one
    # tiny psum — O(B*S) bytes, counted by the ledger)
    g = (y * jax.nn.silu(z.astype(jnp.float32))).reshape(B, S, H * Pd)
    sumsq = jnp.sum(jnp.square(g).astype(jnp.float32), axis=-1, keepdims=True)
    sumsq = cc.psum(sumsq, plan.tp_axes, "block/ssm_norm")
    g = rmsnorm_from_sumsq(g, sumsq, cfg.ssm_expand * cfg.d_model,
                           _lo(ps["norm_scale"]), cfg.norm_eps)
    out = jnp.einsum("bsx,xe->bse", g.astype(xn.dtype),
                     _lo(ps["out"]).reshape(H * Pd, E))
    return out, new_cache


def _paged_ssm(xn, ps, cfg, plan, lay, mode, slab_pool, pages):
    """SSM mixer against the slab pools (paged serving).

    slab_pool: {"statep","conv_xp","conv_Bp","conv_Cp"} with a leading
    ``n_slabs`` dim; pages["slab_ids"]: (B,) slab id per batch row.  Each
    row gathers its slab into the per-slot view ``ssm_mixer`` expects,
    runs one decode token or one prefill chunk with carried state, and
    scatters the updated state back.  Idle/prefilling decode lanes point
    at the reserved scratch slab (id 0), so full-batch decode never
    corrupts a live slab."""
    sid = pages["slab_ids"]
    quant = "sscalep" in slab_pool
    if quant:
        # int8 slabs: dequant through the per-(slab, head) scale on gather,
        # re-quantize the whole slab on scatter (full-overwrite semantics,
        # so the stored bytes depend only on the new state's value)
        state = (slab_pool["statep"][sid].astype(jnp.float32) *
                 slab_pool["sscalep"][sid][:, :, None, None])
    else:
        state = slab_pool["statep"][sid]
    view = {"state": state,
            "conv_x": slab_pool["conv_xp"][sid],
            "conv_B": slab_pool["conv_Bp"][sid],
            "conv_C": slab_pool["conv_Cp"][sid]}
    out, new = ssm_mixer(xn, ps, cfg, plan, lay, mode, view,
                         chunk_last_idx=(pages.get("last_idx")
                                         if mode != "decode" else None))
    pools = {k + "p": slab_pool[k + "p"].at[sid].set(
        v.astype(slab_pool[k + "p"].dtype))
        for k, v in new.items() if not (quant and k == "state")}
    if quant:
        q, s = _row_quant(new["state"])                  # (B,H,P,N), (B,H)
        pools["statep"] = slab_pool["statep"].at[sid].set(q)
        pools["sscalep"] = slab_pool["sscalep"].at[sid].set(
            s.astype(slab_pool["sscalep"].dtype))
    return out, pools


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def dense_ffn(xn, pf, cfg):
    if cfg.gated_ffn:
        h = activation(jnp.einsum("bse,ef->bsf", xn, _lo(pf["w_gate"])),
                       cfg.act) * jnp.einsum("bse,ef->bsf", xn, _lo(pf["w_up"]))
    else:
        h = activation(jnp.einsum("bse,ef->bsf", xn, _lo(pf["w_up"])), cfg.act)
    return jnp.einsum("bsf,fe->bse", h, _lo(pf["w_down"]))


def ffn_sublayer(xn, pf, cfg, plan, spec):
    """-> partial output (B,S,E), reduced by the caller's post-FFN psum."""
    if spec.ffn == FFN_MOE:
        pf_moe = {"router": pf["router"],
                  "experts": jax.tree_util.tree_map(_lo, pf["experts"])}
        if plan.moe_mode == "ep":
            y = moe_ffn_ep(xn, pf_moe, cfg, tp_index(plan), plan.tp,
                           capacity_factor=plan.moe_capacity)
        else:
            y = moe_ffn_tp(xn, pf_moe, cfg,
                           capacity_factor=plan.moe_capacity)
        if cfg.n_shared_experts:
            y = y + dense_ffn(xn, pf["shared"], cfg)
        return y
    return dense_ffn(xn, pf, cfg)


# ---------------------------------------------------------------------------
# Layer forward (two-sync contract)
# ---------------------------------------------------------------------------

def layer_forward(x, p, cache, cfg, plan, lay, spec, mode, positions,
                  pos=None, enc_memory=None, pages=None):
    """One transformer layer.  Returns (x, new_cache)."""
    cache = cache or {}
    new_cache = dict(cache)

    def run_ssm(h):
        sc = cache.get("ssm")
        if sc is not None and "statep" in sc:      # slab pools (paged)
            return _paged_ssm(h, p["ssm"], cfg, plan, lay, mode, sc, pages)
        return ssm_mixer(h, p["ssm"], cfg, plan, lay, mode, sc)

    # ---- mixer sublayer ----------------------------------------------------
    h = apply_norm(x, p["ln1"], cfg)
    if spec.mixer == MIX_ATTN:
        partial, nkv = attn_mixer(h, p["attn"], cfg, plan, lay, spec, mode,
                                  cache.get("kv"), positions, pos, pages)
        if nkv is not None:
            new_cache["kv"] = nkv
    elif spec.mixer == MIX_SSM:
        partial, nssm = run_ssm(h)
        if nssm is not None:
            new_cache["ssm"] = nssm
    else:  # hybrid: parallel attn + ssm heads, fused before ONE psum
        pa, nkv = attn_mixer(h, p["attn"], cfg, plan, lay, spec, mode,
                             cache.get("kv"), positions, pos, pages)
        ps_, nssm = run_ssm(h)
        partial = 0.5 * (pa + ps_)
        if nkv is not None:
            new_cache["kv"] = nkv
        if nssm is not None:
            new_cache["ssm"] = nssm
    red = cc.psum(partial, plan.tp_axes, "block/mixer")  # sync #1
    if cfg.sandwich_norm:
        red = apply_norm(red, p["post_ln1"], cfg)
    x = x + red

    # ---- cross-attention sublayer (enc-dec decoders) ------------------------
    if spec.cross_attn:
        h = apply_norm(x, p["ln_x"], cfg)
        partial, ncross = cross_attn_mixer(h, p["xattn"], cfg, plan, lay,
                                           mode, cache.get("cross"),
                                           enc_memory, pages)
        if ncross is not None:
            new_cache["cross"] = ncross
        x = x + cc.psum(partial, plan.tp_axes, "block/xattn")

    # ---- FFN sublayer --------------------------------------------------------
    if spec.ffn != FFN_NONE:
        h = apply_norm(x, p["ln2"], cfg)
        partial = ffn_sublayer(h, p["ffn"], cfg, plan, spec)
        red = cc.psum(partial, plan.tp_axes, "block/ffn")  # sync #2
        if cfg.sandwich_norm:
            red = apply_norm(red, p["post_ln2"], cfg)
        x = x + red

    return x, (new_cache if new_cache else None)
