"""Attention: chunked flash (prefill/train) + single-token decode.

Pure-JAX implementations used for CPU validation and for the 512-device
dry-run lowering (XLA:TPU fuses these well); the Pallas kernels in
``repro.kernels`` implement the same contracts for real-TPU execution and
are validated against ``repro.kernels.ref`` which in turn matches these.

Shapes follow the per-shard grouped-GQA layout from ``partition.head_layout``:

    q: (B, G, R, Sq, D)   — G local kv slots, R q-heads per slot
    k/v: (B, G, Skv, D)

Sliding-window attention slices the kv stream (linear cost); full causal
attention scans all kv chunks with masking (the known 2x upper-triangle
overhead of scan-based flash — eliminated in the Pallas kernel via grid
pruning and accounted for explicitly in the roofline analytics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as cc

NEG = -1e30


def _online_chunk(acc, m, den, q, k, v, mask, scale, softcap=0.0):
    """One online-softmax update.  q:(...,R,Sq,D) k:(...,C,D) mask:(...,Sq,C)."""
    s = jnp.einsum("...rsd,...cd->...rsc", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[..., None, :, :], s, NEG)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None]) * mask[..., None, :, :]
    corr = jnp.exp(m - m_new)
    den_new = den * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("...rsc,...cd->...rsd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return acc_new, m_new, den_new


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, q_offset=0, kv_offset=0,
                    q_block=512, kv_block=512):
    """Chunked attention.  Returns (B, G, R, Sq, D) in q.dtype."""
    B, G, R, Sq, D = q.shape
    Skv = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)

    # pad sequences to block multiples
    Sq_p = -(-Sq // q_block) * q_block
    Skv_p = -(-Skv // kv_block) * kv_block
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))

    nq = Sq_p // q_block
    q_blocked = q.reshape(B, G, R, nq, q_block, D)

    windowed = causal and window > 0 and Skv_p > window + q_block
    if windowed:
        # slice length covering [q_end - window, q_end) for the whole q block
        L = min(Skv_p, -(-(window + q_block) // kv_block) * kv_block)
    else:
        L = Skv_p
    n_kv = L // kv_block

    def one_q_block(qi, qb):  # qb: (B, G, R, q_block, D)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)
        if windowed:
            # k-array index of the window start for this q block
            start = jnp.clip(q_offset + (qi + 1) * q_block - L - kv_offset,
                             0, Skv_p - L)
            ks = jax.lax.dynamic_slice_in_dim(k, start, L, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, start, L, axis=2)
            kv_base = kv_offset + start
        else:
            ks, vs, kv_base = k, v, kv_offset

        def kv_step(carry, c):
            acc, m, den = carry
            kc = jax.lax.dynamic_slice_in_dim(ks, c * kv_block, kv_block, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vs, c * kv_block, kv_block, axis=2)
            kv_pos = kv_base + c * kv_block + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            mask &= (kv_pos[None, :] < kv_offset + Skv)        # kv padding
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            mask = jnp.broadcast_to(mask, (B, G, q_block, kv_block))
            return _online_chunk(acc, m, den, qb, kc, vc, mask, scale,
                                 softcap), None

        acc0 = jnp.zeros((B, G, R, q_block, D), jnp.float32)
        m0 = jnp.full((B, G, R, q_block), NEG, jnp.float32)
        l0 = jnp.zeros((B, G, R, q_block), jnp.float32)
        (acc, m, den), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                        jnp.arange(n_kv))
        return acc / jnp.maximum(den, 1e-20)[..., None]

    out = jax.lax.map(lambda args: one_q_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(q_blocked, 3, 0)))
    out = jnp.moveaxis(out, 0, 3).reshape(B, G, R, Sq_p, D)
    return out[:, :, :, :Sq].astype(q.dtype)


def flash_attention_split(q, k, v, *, window=0, softcap=0.0, scale=None,
                          q_block=512, kv_block=512, depth=3, q_offset=0):
    """Recursive causal splitting (beyond-paper §Perf optimization).

    The scan-based flash pays ~2x FLOPs on full-causal attention (every q
    block visits every kv chunk, half masked).  Split the q range: the upper
    half genuinely needs the full kv prefix; the lower half only needs the
    first half of kv — a STATIC slice, so recursion is compile-time.  Cost
    converges to (2/3) S^2 vs S^2 (waste 4/3 instead of 2) at depth >= 3.
    Exact — validated against the ref oracle in tests.
    """
    Sq = q.shape[3]
    if depth <= 0 or Sq < 4 * q_block:
        return flash_attention(q, k, v, causal=True, window=window,
                               softcap=softcap, scale=scale,
                               q_offset=q_offset, q_block=q_block,
                               kv_block=kv_block)
    half = Sq // 2
    o_hi = flash_attention(q[:, :, :, half:], k, v, causal=True,
                           window=window, softcap=softcap, scale=scale,
                           q_offset=q_offset + half, q_block=q_block,
                           kv_block=kv_block)
    o_lo = flash_attention_split(
        q[:, :, :, :half], k[:, :, :q_offset + half],
        v[:, :, :q_offset + half], window=window, softcap=softcap,
        scale=scale, q_block=q_block, kv_block=kv_block, depth=depth - 1,
        q_offset=q_offset)
    return jnp.concatenate([o_lo, o_hi], axis=3)


# ---------------------------------------------------------------------------
# Decode (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, slot_pos, cur_pos, *, window=0,
                     softcap=0.0, scale=None, seq_axes=(), tag="attn/decode"):
    """q: (B, G, R, D); caches: (B, G, S_slots, D); slot_pos: (B, S_slots)
    absolute position held by each slot (-1 = empty).  ``seq_axes``: mesh axes
    the cache sequence dim is sharded over (long-context distributed
    flash-decode: partial (m, l, acc) merged with an LSE-weighted psum —
    the paper's partial-output hierarchical reduction applied to sequence).
    """
    B, G, R, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    kf = k_cache
    s = jnp.einsum("bgrd,bgsd->bgrs", q, kf,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if window > 0:
        valid &= slot_pos > (cur_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * valid[:, None, None, :]
    den = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bgrs,bgsd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    if seq_axes:
        gm = cc.psum_max(m, seq_axes, tag + "/m")
        w = jnp.exp(m - gm)
        den = cc.psum(den * w, seq_axes, tag + "/l")
        acc = cc.psum(acc * w[..., None], seq_axes, tag + "/acc")
    out = acc / jnp.maximum(den, 1e-20)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV (block-table indirection over a page pool)
# ---------------------------------------------------------------------------

def gather_pages(pool, block_table):
    """Materialize each slot's logical KV stream from the page pool.

    pool: (n_pages, G, psz, D); block_table: (B, n_max) int32 page ids.
    -> (B, G, n_max * psz, D).  Pure-JAX gather: the Pallas kernel in
    ``repro.kernels.decode_attention`` streams pages via scalar-prefetched
    block tables instead of materializing this copy.
    """
    n_pages, G, psz, D = pool.shape
    B, n_max = block_table.shape
    g = jnp.take(pool, block_table.reshape(-1), axis=0)   # (B*n_max,G,psz,D)
    g = g.reshape(B, n_max, G, psz, D)
    return g.transpose(0, 2, 1, 3, 4).reshape(B, G, n_max * psz, D)


def gather_pages_dequant(pool, scales, block_table, dtype):
    """``gather_pages`` for an int8 pool with per-(page, slot) scales.

    pool: (n_pages, G, psz, D) int8; scales: (n_pages, psz) float32 —
    one scale per token row, written atomically with the payload by
    ``blocks._page_write`` / the cross-KV write step.
    -> (B, G, n_max * psz, D) in ``dtype``.
    """
    B, n_max = block_table.shape
    psz = pool.shape[2]
    g = gather_pages(pool, block_table).astype(jnp.float32)
    s = jnp.take(scales, block_table.reshape(-1), axis=0)    # (B*n_max, psz)
    s = s.reshape(B, 1, n_max * psz, 1)
    return (g * s).astype(dtype)


def paged_decode_attention(q, k_pool, v_pool, block_table, cur_pos, *,
                           window=0, softcap=0.0, scale=None,
                           k_scale=None, v_scale=None):
    """Decode attention reading K/V through a block table.

    q: (B, G, R, D); pools: (n_pages, G, psz, D); block_table: (B, n_max);
    cur_pos: (B,) absolute position of the current token.  Slot s of the
    gathered stream holds absolute position s by construction, so validity
    is simply s <= cur_pos (plus the sliding window).

    ``k_scale``/``v_scale`` ((n_pages, psz) float32): int8 pools are
    dequantized through their per-row scales before scoring.
    """
    B = q.shape[0]
    L = block_table.shape[1] * k_pool.shape[2]
    kv_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    if k_scale is not None:
        kf = gather_pages_dequant(k_pool, k_scale, block_table, q.dtype)
        vf = gather_pages_dequant(v_pool, v_scale, block_table, q.dtype)
    else:
        kf = gather_pages(k_pool, block_table)
        vf = gather_pages(v_pool, block_table)
    return decode_attention(q, kf, vf, kv_pos,
                            cur_pos, window=window, softcap=softcap,
                            scale=scale, tag="attn/paged_decode")


def paged_verify_attention(q, k_pool, v_pool, block_table, cur_pos, *,
                           window=0, softcap=0.0, scale=None,
                           k_scale=None, v_scale=None):
    """Q-query decode attention for speculative verify.

    q: (B, G, R, Q, D) — per slot, query i sits at absolute position
    ``cur_pos + i`` (query 0 is the slot's last accepted token; queries
    1..Q-1 are drafted tokens whose KV the caller wrote this step).
    Pools/block_table as in ``paged_decode_attention``; cur_pos: (B,).

    Validity generalizes decode's ``s <= cur_pos`` per query:
    ``kv_pos <= cur_pos + i`` — the causal mask inside the draft block
    falls out of it, since draft j's KV sits at position cur_pos + j.
    One gather and one batched score pass serve all Q queries, so the
    pools stream off-chip once per verify step instead of once per token
    (the bandwidth argument for speculation on a memory-bound decode).
    """
    B, G, R, Q, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    L = block_table.shape[1] * k_pool.shape[2]
    if k_scale is not None:
        kf = gather_pages_dequant(k_pool, k_scale, block_table, q.dtype)
        vf = gather_pages_dequant(v_pool, v_scale, block_table, q.dtype)
    else:
        kf = gather_pages(k_pool, block_table)
        vf = gather_pages(v_pool, block_table)
    s = jnp.einsum("bgrqd,bgsd->bgrqs", q, kf,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = jnp.arange(L, dtype=jnp.int32)[None, None, :]   # (1, 1, L)
    q_pos = cur_pos[:, None, None] + jnp.arange(Q)[None, :, None]  # (B,Q,1)
    valid = kv_pos <= q_pos                                  # (B, Q, L)
    if window > 0:
        valid &= kv_pos > q_pos - window
    s = jnp.where(valid[:, None, None], s, NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * valid[:, None, None]
    den = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bgrqs,bgsd->bgrqd", p.astype(vf.dtype), vf,
                     preferred_element_type=jnp.float32)
    out = acc / jnp.maximum(den, 1e-20)[..., None]
    return out.astype(q.dtype)
