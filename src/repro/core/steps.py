"""Jitted distributed entry points: train_step / prefill_step / decode_step.

These wrap the per-shard forwards from ``model.py`` in ``jax.shard_map``
with the paper's partitioning specs, then ``jax.jit``.  The same builders
serve CPU smoke tests (1-device mesh), the TP-equivalence tests (8 host
devices) and the 512-device production dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import collectives as cc
from repro.core import kvcache, model
from repro.core.partition import ShardingPlan, model_layout
from repro.optim import AdamWConfig, adamw_update, init_opt_state


def mesh_axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))


def prepare_ledger(mesh):
    cc.set_axis_sizes(mesh_axis_sizes(mesh))


def batch_axes(plan: ShardingPlan):
    return tuple(plan.dp_axes) if len(plan.dp_axes) > 1 else plan.dp_axes[0]


def n_dp(mesh, plan):
    sizes = mesh_axis_sizes(mesh)
    out = 1
    for a in plan.dp_axes:
        out *= sizes.get(a, 1)
    return out


def _shard_map(f, mesh, in_specs, out_specs):
    from repro.compat import shard_map
    return shard_map(f, mesh, in_specs, out_specs)


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------

def train_batch_template(cfg: ModelConfig, shape: ShapeConfig, plan):
    """-> (ShapeDtypeStructs, PartitionSpecs) for a global train batch."""
    B, S = shape.global_batch, shape.seq_len
    bt = batch_axes(plan)
    cp = tuple(plan.cp_axes) if plan.cp_axes else None
    t = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
         "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    s = {"tokens": P(bt, cp), "labels": P(bt, cp)}
    if cfg.is_encdec:
        t["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        s["frames"] = P(bt, None, None)
    if cfg.frontend == "vision_patches":
        n = cfg.n_frontend_embeds
        t["image_embeds"] = jax.ShapeDtypeStruct((B, n, cfg.d_model),
                                                 jnp.bfloat16)
        s["image_embeds"] = P(bt, None, None)
    return t, s


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg, plan, mesh, opt_cfg: Optional[AdamWConfig] = None,
                    shape: Optional[ShapeConfig] = None, grad_transform=None,
                    grad_accum: int = 1):
    """-> (train_step(state, batch) -> (state, metrics), specs dict).

    ``grad_accum`` > 1 splits the per-device batch into microbatches run
    under lax.scan with summed gradients — bounds activation memory for
    large models (the standard companion to selective remat; §Perf)."""
    prepare_ledger(mesh)
    lay = model_layout(cfg, plan)
    pspecs = model.param_pspecs(cfg, plan)
    opt_cfg = opt_cfg or AdamWConfig()
    sizes = mesh_axis_sizes(mesh)
    ndp = 1
    for a in plan.grad_axes:
        ndp *= sizes.get(a, 1)
    inner = ("data",)
    outer = ("pod",) if "pod" in mesh.axis_names else ()
    _, bspecs = train_batch_template(cfg, shape, plan) if shape else (None, None)

    def per_shard(params, batch):
        def loss_fn(p, mb):
            return model.forward_train(p, mb, cfg, plan, lay)

        if grad_accum > 1:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def acc_step(carry, mb):
                loss_a, g_a = carry
                lv, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_a + lv / grad_accum,
                        jax.tree_util.tree_map(
                            lambda a, b: a + b / grad_accum, g_a, g)), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            with cc.LEDGER.scaled(grad_accum):
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch))(params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        # hierarchical DP reduction (paper's grouped tree adapted to pods);
        # context-parallel shards also contribute gradients
        if plan.dp_hierarchical and outer and not plan.cp_axes:
            grads = cc.hierarchical_psum(grads, inner, outer, "dp/grads")
        else:
            grads = cc.psum(grads, plan.grad_axes, "dp/grads")
        grads = jax.tree_util.tree_map(lambda g: g / ndp, grads)
        loss = cc.psum(loss, plan.grad_axes, "dp/loss") / ndp
        return loss, grads

    if bspecs is None:
        bt = batch_axes(plan)
        bspecs = {"tokens": P(bt, None), "labels": P(bt, None)}

    sharded = _shard_map(per_shard, mesh, in_specs=(pspecs, bspecs),
                         out_specs=(P(), pspecs))

    def train_step(state, batch):
        loss, grads = sharded(state["params"], batch)
        new_p, new_opt, stats = adamw_update(state["params"], grads,
                                             state["opt"], opt_cfg)
        stats["loss"] = loss
        return {"params": new_p, "opt": new_opt}, stats

    return train_step, {"params": pspecs, "batch": bspecs}


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer state sharded over the data axis
# ---------------------------------------------------------------------------
#
# The standard path replicates AdamW m/v (f32) across the data axis — 8x the
# bf16 param bytes per device (61.5 GB for mistral-large-123b at tp=16:
# untrainable on 16 GB HBM; see EXPERIMENTS §Perf H2).  ZeRO-1 instead:
#   1. reduce-scatters gradients over 'data' (flat, per leaf) — each data
#      shard owns 1/ndp of every gradient (wire: P(n-1)/n, HALF the psum),
#   2. updates its m/v/param chunk locally (f32 state: bytes / ndp),
#   3. all-gathers the updated bf16 params (wire: P(n-1)/n).
# Total wire == the old grad psum; optimizer memory and update bandwidth
# drop by ndp.  Cross-pod reduction of the (already 1/ndp) chunks keeps the
# paper's hierarchical structure.

def _z1_chunk(leaf_size: int, n: int) -> int:
    return (leaf_size + n - 1) // n


def _is_tp_leaf(spec) -> bool:
    return len(spec) > 0 and spec[0] == "model" or         (len(spec) > 1 and spec[1] == "model")


def abstract_train_state_zero1(cfg, plan, mesh):
    params = model.abstract_params(cfg, plan)
    pspecs = model.param_pspecs(cfg, plan)
    sizes = mesh_axis_sizes(mesh)
    nd = sizes.get("data", 1)

    def shard_leaf(p, spec):
        local = int(np.prod(p.shape))
        if _is_tp_leaf(spec):
            # local leaf excludes the tp axis
            local //= plan.tp
            shape = (plan.tp, nd, _z1_chunk(local, nd))
        else:
            shape = (nd, _z1_chunk(local, nd))
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    flat = jax.tree_util.tree_map(
        shard_leaf, params, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return {"params": params,
            "opt": {"m": flat, "v": flat,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def train_state_pspecs_zero1(cfg, plan):
    pspecs = model.param_pspecs(cfg, plan)

    def spec_leaf(spec):
        if _is_tp_leaf(spec):
            return P("model", "data", None)
        return P("data", None)

    flat = jax.tree_util.tree_map(spec_leaf, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    return {"params": pspecs,
            "opt": {"m": flat, "v": flat, "step": P()}}


def init_train_state_zero1(cfg, plan, mesh, seed=0):
    """Concrete ZeRO-1 state (small/reduced configs; big models restore)."""
    params = model.init_params(cfg, plan, seed)
    sizes = mesh_axis_sizes(mesh)
    nd = sizes.get("data", 1)
    pspecs = model.param_pspecs(cfg, plan)

    def zeros_leaf(p, spec):
        local = int(np.prod(p.shape))
        if _is_tp_leaf(spec):
            local //= plan.tp
            return jnp.zeros((plan.tp, nd, _z1_chunk(local, nd)), jnp.float32)
        return jnp.zeros((nd, _z1_chunk(local, nd)), jnp.float32)

    flat = jax.tree_util.tree_map(
        zeros_leaf, params, pspecs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))
    return {"params": params,
            "opt": {"m": flat, "v": flat, "step": jnp.zeros((), jnp.int32)}}


def make_train_step_zero1(cfg, plan, mesh,
                          opt_cfg: Optional[AdamWConfig] = None,
                          shape: Optional[ShapeConfig] = None,
                          grad_accum: int = 1):
    """ZeRO-1 train step: the whole update runs inside shard_map."""
    from repro.optim import adamw_leaf
    prepare_ledger(mesh)
    lay = model_layout(cfg, plan)
    pspecs = model.param_pspecs(cfg, plan)
    ospecs = train_state_pspecs_zero1(cfg, plan)
    opt_cfg = opt_cfg or AdamWConfig()
    sizes = mesh_axis_sizes(mesh)
    nd = sizes.get("data", 1)
    ndp = 1
    for a in plan.grad_axes:
        ndp *= sizes.get(a, 1)
    outer = ("pod",) if "pod" in mesh.axis_names else ()
    _, bspecs = train_batch_template(cfg, shape, plan) if shape else (None, None)
    if bspecs is None:
        bt = batch_axes(plan)
        bspecs = {"tokens": P(bt, None), "labels": P(bt, None)}
    flat_pspecs = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))

    def per_shard(params, opt, batch):
        def loss_fn(p, mb):
            return model.forward_train(p, mb, cfg, plan, lay)

        if grad_accum > 1:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def acc_step(carry, mb):
                l_a, g_a = carry
                lv, g = jax.value_and_grad(loss_fn)(params, mb)
                return (l_a + lv / grad_accum,
                        jax.tree_util.tree_map(
                            lambda a, b: a + b / grad_accum, g_a, g)), None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            with cc.LEDGER.scaled(grad_accum):
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch))(params)
        loss = cc.psum(loss, plan.grad_axes, "dp/loss") / ndp

        flat_g = jax.tree_util.tree_leaves(grads)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_m = jax.tree_util.tree_leaves(opt["m"])
        flat_v = jax.tree_util.tree_leaves(opt["v"])

        # 1) reduce-scatter grads over 'data' (+ cross-pod psum of chunks)
        g_chunks, p_chunks, tp_mask = [], [], []
        for g, p, spec in zip(flat_g, flat_p, flat_pspecs, strict=True):
            flat = g.reshape(-1).astype(jnp.float32)
            chunk = _z1_chunk(flat.shape[0], nd)
            pad = chunk * nd - flat.shape[0]
            if pad:
                flat = jnp.pad(flat, (0, pad))
            gs = cc.psum_scatter(flat, "data", "dp/z1_rs") if nd > 1 else flat
            if outer:
                gs = cc.psum(gs, outer, "dp/z1_xpod")
            g_chunks.append(gs / ndp)
            pf = p.reshape(-1).astype(jnp.float32)
            if pad:
                pf = jnp.pad(pf, (0, pad))
            if nd > 1:
                me = jax.lax.axis_index("data")
                pf = jax.lax.dynamic_slice_in_dim(pf, me * chunk, chunk)
            p_chunks.append(pf)
            tp_mask.append(_is_tp_leaf(spec))

        # 2) global grad norm (tp-sharded leaves differ across 'model';
        #    replicated leaves are identical there -> reduce separately)
        sq_tp = sum(jnp.sum(jnp.square(g)) for g, t in
                    zip(g_chunks, tp_mask, strict=True) if t) + 0.0
        sq_rep = sum(jnp.sum(jnp.square(g)) for g, t in
                     zip(g_chunks, tp_mask, strict=True) if not t) + 0.0
        sq_tp = cc.psum(sq_tp, ("data",) + tuple(plan.tp_axes) + outer,
                        "dp/z1_norm")
        sq_rep = cc.psum(sq_rep, ("data",) + outer, "dp/z1_norm")
        gnorm = jnp.sqrt(sq_tp + sq_rep)
        step = opt["step"] + 1
        scale = jnp.minimum(1.0, opt_cfg.clip_norm / (gnorm + 1e-9))
        lr = opt_cfg.lr * (opt_cfg.schedule(step) if opt_cfg.schedule
                           else 1.0)

        # 3) local chunk updates + 4) all-gather new params
        new_p_leaves, new_m, new_v = [], [], []
        for p, pc, gc, m, v in zip(flat_p, p_chunks, g_chunks,
                                   flat_m, flat_v, strict=True):
            np_, m2, v2 = adamw_leaf(pc, gc, m, v, step, scale, lr, opt_cfg)
            new_m.append(m2.reshape(m.shape))
            new_v.append(v2.reshape(v.shape))
            np_ = np_.reshape(-1).astype(p.dtype)   # bf16 on the wire
            full = cc.all_gather(np_, "data", "dp/z1_ag") if nd > 1 else np_
            new_p_leaves.append(full.reshape(-1)[: p.size].reshape(p.shape))

        tdef = jax.tree_util.tree_structure(params)
        new_params = jax.tree_util.tree_unflatten(tdef, new_p_leaves)
        new_opt = {"m": jax.tree_util.tree_unflatten(tdef, new_m),
                   "v": jax.tree_util.tree_unflatten(tdef, new_v),
                   "step": step}
        return loss, gnorm, new_params, new_opt

    # strip the leading (tp, nd) layout dims from opt specs for shard_map:
    # inside, each device sees its chunk directly
    sharded = _shard_map(
        per_shard, mesh,
        in_specs=(pspecs, ospecs["opt"], bspecs),
        out_specs=(P(), P(), pspecs, ospecs["opt"]))

    def train_step(state, batch):
        loss, gnorm, new_p, new_opt = sharded(state["params"], state["opt"],
                                              batch)
        return {"params": new_p, "opt": new_opt},             {"loss": loss, "grad_norm": gnorm}

    return train_step, {"params": pspecs, "batch": bspecs,
                        "opt": ospecs["opt"]}


def init_train_state(cfg, plan, seed=0):
    params = model.init_params(cfg, plan, seed)
    return {"params": params, "opt": init_opt_state(params)}


def abstract_train_state(cfg, plan):
    params = model.abstract_params(cfg, plan)
    def f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    return {"params": params,
            "opt": {"m": jax.tree_util.tree_map(f32, params),
                    "v": jax.tree_util.tree_map(f32, params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def train_state_pspecs(cfg, plan):
    pspecs = model.param_pspecs(cfg, plan)
    return {"params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": P()}}


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def serve_templates(cfg, plan, shape: ShapeConfig, mesh):
    """Abstract inputs + specs for prefill/decode lowering of one cell."""
    prepare_ledger(mesh)
    lay = model_layout(cfg, plan)
    B, S = shape.global_batch, shape.seq_len
    batch_ok = (B % n_dp(mesh, plan) == 0) and not plan.seq_shard_kv
    bt = batch_axes(plan) if batch_ok else None  # replicate tiny batches
    tmpl = kvcache.cache_template(cfg, plan, lay, B, S,
                                  batch_sharded=batch_ok)
    cache = kvcache.abstract_cache(tmpl)
    cache_specs = kvcache.cache_pspecs(tmpl)
    t = {
        "tokens1": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        "cache": cache,
        "prompt": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    cp = tuple(plan.cp_axes) if plan.cp_axes else None
    s = {
        "tokens1": P(bt, None),
        "pos": P(bt),
        "cache": cache_specs,
        "prompt": P(bt, cp),
    }
    if cfg.is_encdec:
        # frames span the ENCODER memory length (cfg.enc_seq_len) so the
        # cross cache the prefill writes matches the decode-step template
        t["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq_len, cfg.d_model),
                                           jnp.bfloat16)
        s["frames"] = P(bt, None, None)
        t["dec_tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        s["dec_tokens"] = P(bt, None)
    if cfg.frontend == "vision_patches":
        t["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_embeds, cfg.d_model), jnp.bfloat16)
        s["image_embeds"] = P(bt, None, None)
    return t, s


def _serve_bt(plan, shape, mesh):
    batch_ok = (shape.global_batch % n_dp(mesh, plan) == 0) and \
        not plan.seq_shard_kv
    return batch_axes(plan) if batch_ok else None


def make_decode_step(cfg, plan, mesh, shape: ShapeConfig):
    prepare_ledger(mesh)
    lay = model_layout(cfg, plan)
    pspecs = model.param_pspecs(cfg, plan)
    t, s = serve_templates(cfg, plan, shape, mesh)
    bt = _serve_bt(plan, shape, mesh)

    def per_shard(params, cache, tokens, pos):
        return model.forward_decode(params, cache, tokens, pos, cfg, plan, lay)

    fn = _shard_map(per_shard, mesh,
                    in_specs=(pspecs, s["cache"], s["tokens1"], s["pos"]),
                    out_specs=(P(bt, "model"), s["cache"]))
    return fn, t, s


def make_prefill_step(cfg, plan, mesh, shape: ShapeConfig):
    prepare_ledger(mesh)
    lay = model_layout(cfg, plan)
    pspecs = model.param_pspecs(cfg, plan)
    t, s = serve_templates(cfg, plan, shape, mesh)
    bt = _serve_bt(plan, shape, mesh)

    if cfg.is_encdec:
        def per_shard(params, frames, dec_tokens, cache):
            return model.forward_prefill(params, frames, cache, cfg, plan,
                                         lay, extra={"dec_tokens": dec_tokens})
        fn = _shard_map(per_shard, mesh,
                        in_specs=(pspecs, s["frames"], s["dec_tokens"],
                                  s["cache"]),
                        out_specs=(P(bt, "model"), s["cache"]))
    elif cfg.frontend == "vision_patches":
        def per_shard(params, prompt, image_embeds, cache):
            return model.forward_prefill(params, prompt, cache, cfg, plan,
                                         lay, extra={"image_embeds": image_embeds})
        fn = _shard_map(per_shard, mesh,
                        in_specs=(pspecs, s["prompt"], s["image_embeds"],
                                  s["cache"]),
                        out_specs=(P(bt, "model"), s["cache"]))
    else:
        def per_shard(params, prompt, cache):
            return model.forward_prefill(params, prompt, cache, cfg, plan, lay)
        fn = _shard_map(per_shard, mesh,
                        in_specs=(pspecs, s["prompt"], s["cache"]),
                        out_specs=(P(bt, "model"), s["cache"]))
    return fn, t, s


def zero_cache_for(cfg, plan, mesh, batch, budget):
    lay = model_layout(cfg, plan)
    tmpl = kvcache.cache_template(cfg, plan, lay, batch, budget)
    return kvcache.zero_cache(tmpl)


# ---------------------------------------------------------------------------
# Paged serving steps (block-table KV + chunked prefill)
# ---------------------------------------------------------------------------
#
# One compiled (decode, prefill-chunk) pair serves every request mix: the
# decode step is shaped by (batch_slots, n_pages, n_max_pages) and the chunk
# step by (chunk, n_pages, n_max_pages) — prompt lengths appear only as data
# (block tables, positions, lengths), never as shapes, so admission never
# recompiles.  Heads keep the model-axis TP sharding; the page pools carry a
# leading replica dim sharded over the data axes (``n_replicas``), so with
# dp>1 each data shard stores only its own replicas' pages.  Block tables
# stay replica-relative: each per-shard function folds its local replicas
# into one larger pool and offsets table rows by ``local_replica *
# n_pages``, so attention and the Pallas kernels never see the replica dim,
# and n_replicas == 1 reproduces the old dp=1 behavior exactly.
#
# Architecture coverage: attention layers read/write ``kp``/``vp`` page
# pools through block tables; SSM/hybrid layers read/write their
# ``n_slabs`` recurrent-state slab pools by slab id (``slab_ids`` input,
# scratch slab 0 for idle lanes); enc-dec decoders read the encoder
# memory's K/V through a SECOND, read-only block table
# (``cross_block_table``) over the ``ckp``/``cvp`` pools, written once per
# admission by ``make_cross_kv_write_step``.  Each step's input signature
# grows only the pieces the arch needs (``paged_extra_inputs``).


def paged_extra_inputs(cfg) -> tuple:
    """-> (has_ssm, has_cross): which extra inputs (slab_ids /
    cross_block_table) the arch's paged steps take, in that order."""
    prof = kvcache.cache_profile(cfg)
    return "ssm" in prof, "cross_kv" in prof


def _paged_templates(cfg, plan, mesh, n_pages, page_size, n_replicas=1,
                     n_slabs=0):
    assert not plan.seq_shard_kv, "paged cache is exclusive with seq_shard_kv"
    prepare_ledger(mesh)
    lay = model_layout(cfg, plan)
    tmpl = kvcache.paged_cache_template(cfg, plan, lay, n_pages, page_size,
                                        n_replicas, n_slabs)
    return lay, kvcache.abstract_cache(tmpl), kvcache.cache_pspecs(tmpl)


def n_replicas_local(mesh, plan, n_replicas: int) -> int:
    """Replicas resident per data shard.  n_replicas must cover the data
    axes evenly (each shard owns a whole number of replica pools)."""
    nd = n_dp(mesh, plan)
    assert n_replicas % nd == 0, \
        (f"n_replicas={n_replicas} must be a multiple of the mesh's data "
         f"extent {nd}")
    return n_replicas // nd


def make_paged_decode_step(cfg, plan, mesh, batch: int, n_pages: int,
                           page_size: int, n_max_pages: int,
                           n_replicas: int = 1, n_slabs: int = 0):
    """-> (decode_fn(params, cache, tokens (R*B,1), pos (R*B,), block_table
    (R*B, n_max)[, slab_ids (R*B,)][, cross_block_table (R*B, n_cross)])
    -> (logits, cache), templates, specs).

    ``batch`` is the per-replica slot count; the global decode batch covers
    all ``n_replicas`` replicas' slots (rows r*B..r*B+B-1 belong to replica
    r) and is sharded over the data axes alongside the pools, so one
    compiled step drives every replica.  Archs with SSM layers take the
    ``slab_ids`` input (replica-relative, scratch 0 for idle lanes);
    enc-dec archs take the read-only ``cross_block_table``
    (``paged_extra_inputs`` says which apply)."""
    has_ssm, has_cross = paged_extra_inputs(cfg)
    lay, cache_t, cache_s = _paged_templates(cfg, plan, mesh, n_pages,
                                             page_size, n_replicas, n_slabs)
    pspecs = model.param_pspecs(cfg, plan)
    r_loc = n_replicas_local(mesh, plan, n_replicas)
    bt_ax = batch_axes(plan)
    n_cross = kvcache.pages_needed(cfg.enc_seq_len, page_size) \
        if has_cross else 0

    def per_shard(params, cache, tokens, pos, block_table, *extra):
        # fold this shard's replicas into one pool; rows stay
        # replica-relative, so offset each row into its replica's range
        rep_row = jnp.arange(r_loc * batch, dtype=jnp.int32) // batch
        offs = rep_row[:, None] * n_pages
        pages = {"block_table": block_table + offs, "page_size": page_size}
        extra = list(extra)
        if has_ssm:
            pages["slab_ids"] = extra.pop(0) + rep_row * n_slabs
        if has_cross:
            pages["cross_block_table"] = extra.pop(0) + offs
        logits, folded = model.forward_decode(
            params, kvcache.fold_replica_pools(cache), tokens, pos, cfg,
            plan, lay, pages=pages)
        return logits, kvcache.unfold_replica_pools(folded, r_loc)

    s = {"cache": cache_s, "tokens1": P(bt_ax, None), "pos": P(bt_ax),
         "block_table": P(bt_ax, None)}
    t = {"cache": cache_t,
         "tokens1": jax.ShapeDtypeStruct((n_replicas * batch, 1), jnp.int32),
         "pos": jax.ShapeDtypeStruct((n_replicas * batch,), jnp.int32),
         "block_table": jax.ShapeDtypeStruct(
             (n_replicas * batch, n_max_pages), jnp.int32)}
    extra_s = []
    if has_ssm:
        s["slab_ids"] = P(bt_ax)
        t["slab_ids"] = jax.ShapeDtypeStruct((n_replicas * batch,), jnp.int32)
        extra_s.append(s["slab_ids"])
    if has_cross:
        s["cross_block_table"] = P(bt_ax, None)
        t["cross_block_table"] = jax.ShapeDtypeStruct(
            (n_replicas * batch, n_cross), jnp.int32)
        extra_s.append(s["cross_block_table"])
    fn = _shard_map(per_shard, mesh,
                    in_specs=(pspecs, s["cache"], s["tokens1"], s["pos"],
                              s["block_table"], *extra_s),
                    out_specs=(P(bt_ax, "model"), s["cache"]))
    return fn, t, s


def make_verify_step(cfg, plan, mesh, batch: int, q_len: int, n_pages: int,
                     page_size: int, n_max_pages: int, n_replicas: int = 1):
    """-> (verify_fn(params, cache, tokens (R*B, Q), pos (R*B,), qlen (R*B,),
    block_table (R*B, n_max)) -> (logits (R*B, Q, V), cache), templates,
    specs).

    The speculative-decoding companion of ``make_paged_decode_step``: one
    fused call scores Q = k+1 positions per slot (the last accepted token
    plus k drafted continuations), writing all Q tokens' KV through the
    block table and reading the whole cache once.  ``qlen`` marks the live
    columns per row; padded columns write to the scratch page and their
    logits rows are garbage the engine ignores.  Attention-only archs
    only: SSM recurrences advance strictly one token per step and cross
    archs gate speculation off at the engine."""
    has_ssm, has_cross = paged_extra_inputs(cfg)
    assert not (has_ssm or has_cross), \
        f"verify step requires an attention-only arch, got '{cfg.name}'"
    lay, cache_t, cache_s = _paged_templates(cfg, plan, mesh, n_pages,
                                             page_size, n_replicas, 0)
    pspecs = model.param_pspecs(cfg, plan)
    r_loc = n_replicas_local(mesh, plan, n_replicas)
    bt_ax = batch_axes(plan)

    def per_shard(params, cache, tokens, pos, qlen, block_table):
        rep_row = jnp.arange(r_loc * batch, dtype=jnp.int32) // batch
        offs = rep_row[:, None] * n_pages
        pages = {"block_table": block_table + offs, "page_size": page_size}
        logits, folded = model.forward_verify(
            params, kvcache.fold_replica_pools(cache), tokens, pos, qlen,
            cfg, plan, lay, pages=pages)
        return logits, kvcache.unfold_replica_pools(folded, r_loc)

    s = {"cache": cache_s, "tokens": P(bt_ax, None), "pos": P(bt_ax),
         "qlen": P(bt_ax), "block_table": P(bt_ax, None)}
    t = {"cache": cache_t,
         "tokens": jax.ShapeDtypeStruct((n_replicas * batch, q_len),
                                        jnp.int32),
         "pos": jax.ShapeDtypeStruct((n_replicas * batch,), jnp.int32),
         "qlen": jax.ShapeDtypeStruct((n_replicas * batch,), jnp.int32),
         "block_table": jax.ShapeDtypeStruct(
             (n_replicas * batch, n_max_pages), jnp.int32)}
    fn = _shard_map(per_shard, mesh,
                    in_specs=(pspecs, s["cache"], s["tokens"], s["pos"],
                              s["qlen"], s["block_table"]),
                    out_specs=(P(bt_ax, None, "model"), s["cache"]))
    return fn, t, s


def make_prefill_chunk_step(cfg, plan, mesh, chunk: int, n_pages: int,
                            page_size: int, n_max_pages: int,
                            n_replicas: int = 1, n_slabs: int = 0):
    """-> (chunk_fn(params, cache, tokens (R,C), chunk_start (R,), last_idx
    (R,), block_table (R, n_max)[, slab_ids (R,)][, cross_block_table
    (R, n_cross)]) -> (logits (R, V), cache), templates, specs).

    Row r advances one prefill chunk for replica r; a replica with nothing
    to prefill rides along pointed at its scratch page (all-SCRATCH_PAGE
    block-table row, zero tokens) and its logits row is ignored.  On a dp
    mesh each shard runs only its own replicas' chunks in parallel.

    SSM layers carry their recurrent state across chunks through the slab
    (``slab_ids``); ``last_idx`` doubles as the recurrence mask — padded
    positions past it leave the state untouched, so the state handed to
    decode is exactly the prompt's.  Enc-dec cross-attention reads the
    admission-time cross pages through ``cross_block_table``."""
    has_ssm, has_cross = paged_extra_inputs(cfg)
    lay, cache_t, cache_s = _paged_templates(cfg, plan, mesh, n_pages,
                                             page_size, n_replicas, n_slabs)
    pspecs = model.param_pspecs(cfg, plan)
    r_loc = n_replicas_local(mesh, plan, n_replicas)
    bt_ax = batch_axes(plan)
    n_cross = kvcache.pages_needed(cfg.enc_seq_len, page_size) \
        if has_cross else 0

    def per_shard(params, cache, tokens, chunk_start, last_idx, block_table,
                  *extra):
        folded = kvcache.fold_replica_pools(cache)
        extra = list(extra)
        slab_ids = extra.pop(0) if has_ssm else None
        cross_bt = extra.pop(0) if has_cross else None
        logits = []
        for i in range(r_loc):               # one chunk per local replica
            pages = {"block_table": block_table[i:i + 1] + i * n_pages,
                     "page_size": page_size}
            if has_ssm:
                pages["slab_ids"] = slab_ids[i:i + 1] + i * n_slabs
                pages["last_idx"] = last_idx[i]
            if has_cross:
                pages["cross_block_table"] = cross_bt[i:i + 1] + i * n_pages
            lg, folded = model.forward_prefill_chunk(
                params, folded, tokens[i:i + 1], chunk_start[i],
                last_idx[i], cfg, plan, lay, pages)
            logits.append(lg)
        return (jnp.concatenate(logits, axis=0),
                kvcache.unfold_replica_pools(folded, r_loc))

    s = {"cache": cache_s, "tokens": P(bt_ax, None),
         "chunk_start": P(bt_ax), "last_idx": P(bt_ax),
         "block_table": P(bt_ax, None)}
    t = {"cache": cache_t,
         "tokens": jax.ShapeDtypeStruct((n_replicas, chunk), jnp.int32),
         "chunk_start": jax.ShapeDtypeStruct((n_replicas,), jnp.int32),
         "last_idx": jax.ShapeDtypeStruct((n_replicas,), jnp.int32),
         "block_table": jax.ShapeDtypeStruct((n_replicas, n_max_pages),
                                             jnp.int32)}
    extra_s = []
    if has_ssm:
        s["slab_ids"] = P(bt_ax)
        t["slab_ids"] = jax.ShapeDtypeStruct((n_replicas,), jnp.int32)
        extra_s.append(s["slab_ids"])
    if has_cross:
        s["cross_block_table"] = P(bt_ax, None)
        t["cross_block_table"] = jax.ShapeDtypeStruct(
            (n_replicas, n_cross), jnp.int32)
        extra_s.append(s["cross_block_table"])
    fn = _shard_map(per_shard, mesh,
                    in_specs=(pspecs, s["cache"], s["tokens"],
                              s["chunk_start"], s["last_idx"],
                              s["block_table"], *extra_s),
                    out_specs=(P(bt_ax, "model"), s["cache"]))
    return fn, t, s


def make_page_copy_step(cfg, plan, mesh, n_pages: int, page_size: int,
                        n_replicas: int = 1, n_slabs: int = 0):
    """-> (copy_fn(cache, src (R,), dst (R,)) -> cache, templates, specs).

    Copies one page's K/V across every layer's SELF-KV pool, per replica —
    the mechanism behind copy-on-write divergence: a slot that must append
    into a shared page (radix prefix cache, ``serving.prefix_cache``) first
    duplicates it into a private page, then writes only the copy.  Page ids
    are replica-relative data, so one compiled step serves every (src, dst)
    mix; a replica with no copy this call passes src == dst (identity).
    SSM slab pools (different id space) and cross-KV pools (immutable,
    refcount-shared, never COW'd) pass through untouched."""
    _, cache_t, cache_s = _paged_templates(cfg, plan, mesh, n_pages,
                                           page_size, n_replicas, n_slabs)
    r_loc = n_replicas_local(mesh, plan, n_replicas)
    bt_ax = batch_axes(plan)

    def per_shard(cache, src, dst):
        def leaf(pool):          # (reps, R_loc, n_pages, G, psz, D) folded
            pool = kvcache.fold_replica_pools(pool)
            for i in range(r_loc):
                page = jax.lax.dynamic_slice_in_dim(
                    pool, src[i] + i * n_pages, 1, axis=1)
                pool = jax.lax.dynamic_update_slice_in_dim(
                    pool, page, dst[i] + i * n_pages, axis=1)
            return kvcache.unfold_replica_pools(pool, r_loc)
        # only the self-KV pools: slab/cross ids live in other spaces
        return [[{kind: (jax.tree_util.tree_map(leaf, sub)
                         if kind == "kv" else sub)
                  for kind, sub in d.items()} for d in pat]
                for pat in cache]

    s = {"cache": cache_s, "src": P(bt_ax), "dst": P(bt_ax)}
    t = {"cache": cache_t,
         "src": jax.ShapeDtypeStruct((n_replicas,), jnp.int32),
         "dst": jax.ShapeDtypeStruct((n_replicas,), jnp.int32)}
    fn = _shard_map(per_shard, mesh,
                    in_specs=(s["cache"], s["src"], s["dst"]),
                    out_specs=s["cache"])
    return fn, t, s


def make_page_transfer_step(cfg, plan, mesh, n_pages: int, page_size: int,
                            n_lanes: int, n_replicas: int = 1,
                            n_slabs: int = 0):
    """-> (transfer_fn(cache, src_rep, dst_rep, src_pages (n_lanes,),
    dst_pages (n_lanes,)) -> cache, templates, specs).

    First-class inter-replica page movement: gathers up to ``n_lanes``
    pages (payload AND the int8 per-page scale rows — every leaf of the
    self-KV pools rides along byte-identically) from the source replica's
    pool and scatters them into freshly allocated destination pages.  One
    compiled step covers every (src, dst) replica pair: the replica ids
    are scalar *data*, shards that own neither replica route their writes
    to the scratch page, and the gathered pages cross data shards through
    a ledger-tracked psum (identity — zero wire bytes — when source and
    destination live on the same shard, e.g. any 1-shard mesh).  Unused
    lanes pass scratch→scratch.  Host-side refcount ownership moves
    separately and atomically via ``kvcache.handoff_refs``.

    The disaggregated-serving substrate: prefill replicas hand finished
    KV page runs to decode replicas without re-running prefill.  Only the
    self-KV pools transfer — SSM slabs and cross-KV pools are gated off
    by the engine (attention-only models)."""
    prepare_ledger(mesh)
    _, cache_t, cache_s = _paged_templates(cfg, plan, mesh, n_pages,
                                           page_size, n_replicas, n_slabs)
    r_loc = n_replicas_local(mesh, plan, n_replicas)
    sizes = mesh_axis_sizes(mesh)

    def per_shard(cache, src_rep, dst_rep, src_pages, dst_pages):
        shard = jnp.int32(0)
        for a in plan.dp_axes:
            if sizes.get(a, 1) > 1:
                shard = shard * sizes[a] + jax.lax.axis_index(a)
        base = shard * r_loc
        local_src = src_rep - base
        src_ok = (local_src >= 0) & (local_src < r_loc)
        local_dst = dst_rep - base
        dst_ok = (local_dst >= 0) & (local_dst < r_loc)

        def leaf(pool):          # folded page axis is axis 1 on every leaf
            pool = kvcache.fold_replica_pools(pool)
            rows = jnp.clip(local_src, 0, r_loc - 1) * n_pages + src_pages
            data = jnp.take(pool, rows, axis=1)
            data = jnp.where(src_ok, data, jnp.zeros_like(data))
            data = cc.psum(data, tuple(plan.dp_axes), "page_transfer")
            dst_rows = jnp.where(
                dst_ok,
                jnp.clip(local_dst, 0, r_loc - 1) * n_pages + dst_pages,
                0)               # non-owners write their scratch page
            pool = pool.at[:, dst_rows].set(
                jnp.where(dst_ok, data, jnp.take(pool, dst_rows, axis=1)))
            return kvcache.unfold_replica_pools(pool, r_loc)
        # only the self-KV pools: slab/cross ids live in other spaces
        return [[{kind: (jax.tree_util.tree_map(leaf, sub)
                         if kind == "kv" else sub)
                  for kind, sub in d.items()} for d in pat]
                for pat in cache]

    s = {"cache": cache_s, "src_rep": P(), "dst_rep": P(),
         "src_pages": P(None), "dst_pages": P(None)}
    t = {"cache": cache_t,
         "src_rep": jax.ShapeDtypeStruct((), jnp.int32),
         "dst_rep": jax.ShapeDtypeStruct((), jnp.int32),
         "src_pages": jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
         "dst_pages": jax.ShapeDtypeStruct((n_lanes,), jnp.int32)}
    fn = _shard_map(per_shard, mesh,
                    in_specs=(s["cache"], s["src_rep"], s["dst_rep"],
                              s["src_pages"], s["dst_pages"]),
                    out_specs=s["cache"])
    return fn, t, s


def make_cross_kv_write_step(cfg, plan, mesh, n_pages: int, page_size: int,
                             n_replicas: int = 1, n_slabs: int = 0):
    """-> (write_fn(params, cache, frames (R, S_enc, E), cross_bt
    (R, n_cross)) -> cache, templates, specs).

    The enc-dec admission step: row r runs the ENCODER over replica r's
    frames, projects every cross-attention layer's K/V of the encoder
    memory, and scatters them into the ``ckp``/``cvp`` pools at the pages
    named by its cross block table.  Runs once per admitted request (or
    never, when the frames digest hits the replica's cross-KV cache);
    the written pages are immutable afterwards — decode and chunked
    prefill only read them — so identical-frame requests share them by
    refcount alone.  A replica with nothing to encode rides along with
    zero frames pointed at the scratch page."""
    from repro.core.blocks import _kv_q
    assert paged_extra_inputs(cfg)[1], \
        f"{cfg.name} has no cross-attention layers"
    lay, cache_t, cache_s = _paged_templates(cfg, plan, mesh, n_pages,
                                             page_size, n_replicas, n_slabs)
    pspecs = model.param_pspecs(cfg, plan)
    r_loc = n_replicas_local(mesh, plan, n_replicas)
    bt_ax = batch_axes(plan)
    S_enc = cfg.enc_seq_len
    n_cross = kvcache.pages_needed(S_enc, page_size)

    def scatter(pool, kv1, bt_row, off):
        """pool: (reps, R_loc*n_pages, G, psz, D); kv1: (reps, G, S_enc, D)
        -> pool with position s written at page bt_row[s // psz] + off,
        offset s % psz."""
        pids = jnp.take(bt_row, jnp.arange(S_enc) // page_size) + off
        offs = jnp.arange(S_enc) % page_size
        val = _kv_q(kv1, pool.dtype).transpose(2, 0, 1, 3)  # (S_enc,reps,G,D)
        return pool.at[:, pids, :, offs].set(val)

    def scatter_q(pool, sc, kv1, bt_row, off):
        """int8 pools: per-token-row quantization, scale scattered into the
        (reps, R_loc*n_pages, psz) side tensor atomically with the payload
        (same row scheme as ``blocks._row_quant``)."""
        pids = jnp.take(bt_row, jnp.arange(S_enc) // page_size) + off
        offs = jnp.arange(S_enc) % page_size
        kf = kv1.astype(jnp.float32)
        amax = jnp.max(jnp.abs(kf), axis=(1, 3))            # (reps, S_enc)
        inv = jnp.where(amax > 0, 127.0 / jnp.maximum(amax, 1e-30), 0.0)
        q = jnp.clip(jnp.round(kf * inv[:, None, :, None]),
                     -127, 127).astype(jnp.int8)
        val = q.transpose(2, 0, 1, 3)                       # (S_enc,reps,G,D)
        return (pool.at[:, pids, :, offs].set(val),
                sc.at[:, pids, offs].set(amax * (1.0 / 127.0)))

    def per_shard(params, cache, frames, cross_bt):
        folded = kvcache.fold_replica_pools(cache)
        for i in range(r_loc):
            enc = model.encode(params,
                               frames[i:i + 1].astype(jnp.dtype(cfg.dtype)),
                               cfg, plan, lay)
            kvs = model.forward_cross_kv(params, enc, cfg, plan, lay)
            for gi, per_pat in enumerate(kvs):
                for pi, kv in enumerate(per_pat):
                    if kv is None:
                        continue
                    cr = folded[gi][pi]["cross"]
                    if "cksp" in cr:
                        ckp, cksp = scatter_q(cr["ckp"], cr["cksp"],
                                              kv["k"][:, 0], cross_bt[i],
                                              i * n_pages)
                        cvp, cvsp = scatter_q(cr["cvp"], cr["cvsp"],
                                              kv["v"][:, 0], cross_bt[i],
                                              i * n_pages)
                        cr = {"ckp": ckp, "cvp": cvp,
                              "cksp": cksp, "cvsp": cvsp}
                    else:
                        cr = {"ckp": scatter(cr["ckp"], kv["k"][:, 0],
                                             cross_bt[i], i * n_pages),
                              "cvp": scatter(cr["cvp"], kv["v"][:, 0],
                                             cross_bt[i], i * n_pages)}
                    folded[gi][pi] = dict(folded[gi][pi], cross=cr)
        return kvcache.unfold_replica_pools(folded, r_loc)

    s = {"cache": cache_s, "frames": P(bt_ax, None, None),
         "cross_bt": P(bt_ax, None)}
    t = {"cache": cache_t,
         "frames": jax.ShapeDtypeStruct((n_replicas, S_enc, cfg.d_model),
                                        jnp.dtype(cfg.dtype)),
         "cross_bt": jax.ShapeDtypeStruct((n_replicas, n_cross), jnp.int32)}
    fn = _shard_map(per_shard, mesh,
                    in_specs=(pspecs, s["cache"], s["frames"], s["cross_bt"]),
                    out_specs=s["cache"])
    return fn, t, s


def zero_paged_cache_for(cfg, plan, mesh, n_pages, page_size,
                         n_replicas: int = 1, n_slabs: int = 0):
    lay = model_layout(cfg, plan)
    tmpl = kvcache.paged_cache_template(cfg, plan, lay, n_pages, page_size,
                                        n_replicas, n_slabs)
    return kvcache.zero_paged_cache(tmpl)


_STEP_SET_MEMO: dict = {}


def paged_step_set(cfg, plan, mesh, batch: int, n_pages: int, page_size: int,
                   n_max_pages: int, prefill_chunk: int, n_replicas: int = 1,
                   n_slabs: int = 0, speculative: int = 0) -> dict:
    """-> memoized dict of the jitted paged-engine steps for one shape:
    ``{"prefill", "decode", "copy", "cross_write", "verify", "transfer"}``
    (entries the arch/shape doesn't need are None).

    jax.jit caches compilations per *function object*, so an engine that
    rebuilds its steps on every membership change (``scale_to`` /
    ``kill_replica``) would recompile from scratch each time it revisits a
    replica count.  Memoizing the jitted closures on the step shape makes
    repeated reconfiguration — and fault-injection suites that build many
    engines over the same config — pay compilation once per distinct
    (cfg, mesh, plan-shape, batch, pool, n_replicas) tuple.  The memo holds
    cfg/mesh strongly so their ids cannot be recycled under a live key.

    Donation matches the engine's call conventions: every step that
    threads the cache donates it (arg 1 after params, or arg 0 for the
    param-less copy/transfer steps)."""
    key = (id(cfg), id(mesh), plan.tp, str(plan.kv_cache_dtype),
           str(plan.ssm_cache_dtype), tuple(plan.dp_axes), batch, n_pages,
           page_size, n_max_pages, prefill_chunk, n_replicas, n_slabs,
           speculative)
    hit = _STEP_SET_MEMO.get(key)
    if hit is not None:
        return hit[2]
    has_ssm, has_cross = paged_extra_inputs(cfg)
    prof = kvcache.cache_profile(cfg)
    slabs = n_slabs if has_ssm else 0
    dec, _, _ = make_paged_decode_step(cfg, plan, mesh, batch, n_pages,
                                       page_size, n_max_pages,
                                       n_replicas=n_replicas, n_slabs=slabs)
    chunk_fn, _, _ = make_prefill_chunk_step(cfg, plan, mesh, prefill_chunk,
                                             n_pages, page_size, n_max_pages,
                                             n_replicas=n_replicas,
                                             n_slabs=slabs)
    out = {"decode": jax.jit(dec, donate_argnums=(1,)),
           "prefill": jax.jit(chunk_fn, donate_argnums=(1,)),
           "copy": None, "cross_write": None, "verify": None,
           "transfer": None}
    if "kv" in prof:
        cp, _, _ = make_page_copy_step(cfg, plan, mesh, n_pages, page_size,
                                       n_replicas=n_replicas, n_slabs=slabs)
        out["copy"] = jax.jit(cp, donate_argnums=(0,))
    if has_cross:
        cw, _, _ = make_cross_kv_write_step(cfg, plan, mesh, n_pages,
                                            page_size, n_replicas=n_replicas,
                                            n_slabs=slabs)
        out["cross_write"] = jax.jit(cw, donate_argnums=(1,))
    if speculative > 0:
        vf, _, _ = make_verify_step(cfg, plan, mesh, batch, speculative + 1,
                                    n_pages, page_size, n_max_pages,
                                    n_replicas=n_replicas)
        out["verify"] = jax.jit(vf, donate_argnums=(1,))
    if n_replicas > 1 and not has_ssm and not has_cross:
        tf, _, _ = make_page_transfer_step(cfg, plan, mesh, n_pages,
                                           page_size, n_max_pages,
                                           n_replicas=n_replicas)
        out["transfer"] = jax.jit(tf, donate_argnums=(0,))
    # hold cfg/mesh strongly so their ids cannot be recycled under the key
    _STEP_SET_MEMO[key] = (cfg, mesh, out)
    return out
