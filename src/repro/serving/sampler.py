"""Token samplers over (possibly vocab-padded) logits."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0
    top_p: float = 0.0


def sample_from_logits(logits: np.ndarray, cfg: SamplerConfig,
                       vocab_size: int, rng: np.random.RandomState):
    """logits: (B, V_pad) float32 -> (B,) int32."""
    lg = logits[:, :vocab_size].astype(np.float64)
    if cfg.temperature <= 0:
        return lg.argmax(axis=-1).astype(np.int32)
    lg = lg / cfg.temperature
    if cfg.top_k:
        kth = np.partition(lg, -cfg.top_k, axis=-1)[:, -cfg.top_k][:, None]
        lg = np.where(lg < kth, -np.inf, lg)
    p = np.exp(lg - lg.max(axis=-1, keepdims=True))
    p /= p.sum(axis=-1, keepdims=True)
    if cfg.top_p:
        srt = np.argsort(-p, axis=-1)
        out = np.zeros(lg.shape[0], np.int32)
        for b in range(lg.shape[0]):
            ps = p[b, srt[b]]
            keep = np.cumsum(ps) - ps < cfg.top_p
            keep[0] = True
            sel = srt[b, keep]
            pp = p[b, sel] / p[b, sel].sum()
            out[b] = rng.choice(sel, p=pp)
        return out
    return np.array([rng.choice(lg.shape[1], p=p[b])
                     for b in range(lg.shape[0])], np.int32)


def speculative_sample(logits: np.ndarray, draft, cfg: SamplerConfig,
                       vocab_size: int, rng: np.random.RandomState):
    """Accept/emit loop over verify-step logits — the deterministic-draft
    special case of rejection sampling, token-identical to the one-token
    path by construction.

    ``logits``: (Q, V_pad) where row i is the model's next-token
    distribution after consuming the last accepted token plus draft[:i];
    ``draft``: the kd <= Q-1 proposed tokens.  Row i is sampled exactly as
    ``sample_from_logits`` would on the one-token path (greedy consumes no
    RNG; temperature > 0 consumes one draw per emitted row, in emission
    order), the sampled token is emitted, and drafting continues past row
    i only while the sample agrees with draft[i].  Because the draft is a
    point mass, "target sample == draft token" IS the rejection test, and
    the first disagreeing row already holds the corrected sample — no
    residual-distribution resample is needed.  -> emitted tokens
    (1 <= len <= len(draft) + 1)."""
    out = []
    for i in range(len(draft) + 1):
        tok = int(sample_from_logits(logits[i:i + 1], cfg, vocab_size,
                                     rng)[0])
        out.append(tok)
        if i < len(draft) and tok != int(draft[i]):
            break
    return out


def merged_topk_sample(local_logits_gathered, cfg, vocab_size, rng):
    """Exact sampling from per-shard top-k candidates (serving on a TP mesh):
    the global top-k is a subset of the union of per-shard top-k's.

    Applies the full ``SamplerConfig`` semantics — temperature, top-k AND
    top-p — over the merged candidate set, consuming the request's RNG
    stream exactly like ``sample_from_logits`` does on the single-host
    path, so a TP mesh and a single host draw identical tokens from the
    same seed."""
    vals, ids = local_logits_gathered                  # (tp*k,), (tp*k,)
    mask = ids < vocab_size
    vals = np.where(mask, vals, -np.inf).astype(np.float64)
    if cfg.temperature <= 0:
        return int(ids[int(np.argmax(vals))])
    k = cfg.top_k or len(vals)
    order = np.argsort(-vals)[:k]
    v = vals[order] / cfg.temperature
    p = np.exp(v - v.max())
    p /= p.sum()
    if cfg.top_p:
        # nucleus filter over the merged candidates: `order` is already
        # probability-descending, so the cumulative mask mirrors the
        # single-host path token for token (which draws over sel in that
        # same order)
        keep = np.cumsum(p) - p < cfg.top_p
        keep[0] = True
        sel = order[keep]
        pp = p[keep] / p[keep].sum()
        return int(ids[sel[int(rng.choice(len(sel), p=pp))]])
    # without top_p the single-host path draws over the FULL vocab in
    # token-id order; zero-probability gaps don't shift the CDF, so
    # drawing over the candidates sorted by token id consumes the same
    # uniform identically
    by_id = np.argsort(ids[order], kind="stable")
    return int(ids[order[by_id][int(rng.choice(len(order), p=p[by_id]))]])
