"""Prefix/cross caches: resident KV that admission maps instead of recomputing.

Two caches live here, both holding refcounted page runs in the replica's
``PageAllocator`` id space:

* ``RadixPrefixCache`` — token-id prefixes of *self*-attention KV
  (attention-only decoders; see the invariants below).
* ``CrossKVCache``    — encoder-memory cross-KV keyed by a digest of the
  request's frames (enc-dec archs): requests with identical frames share
  one encode's pages by refcount alone.  Cross pages are immutable after
  the admission-time write, so there is no copy-on-write and no radix
  structure — frames either match exactly or not at all.

Both caches deal purely in page *ids*, so quantized pools need nothing
extra here: a page's int8 payload and its per-(page, slot) scale rows
are indexed by the same id, and sharing, COW duplication and eviction
move/retire them together (the engine's page-copy step copies scale
rows alongside payloads; the allocator marks freed pages' scale rows
for reset before reuse).

Radix prefix cache: token-id sequences -> refcounted page runs.

The serving-layer analogue of the paper's stationary-state discipline:
KV already resident in the page pool is never recomputed or re-stored.
Completed prefills insert their prompt's full pages into a radix tree;
admission looks up the longest cached prefix and maps those pages straight
into the new slot's block table, so chunked prefill starts at the first
uncached token (system prompts, few-shot headers and agent scaffolds all
collapse onto one resident copy).

Machine-checked clauses (scripts/check_static.py; see README §Serving):

Invariant: page alignment — every node key length is a positive multiple
    of ``page_size`` and ``node.pages`` holds exactly ``len(key) /
    page_size`` page ids; children are keyed by their first page of
    tokens, so sequences that diverge mid-page live in sibling nodes.
Enforced-by: tests/test_prefix_cache.py::test_radix_split_shares_page_aligned_prefix

Invariant: cache refs — the tree holds one allocator ref per page it
    references; pages stay alive while reachable and are released only
    by eviction.
Enforced-by: tests/test_prefix_cache.py::test_allocator_refcounts, analysis:refcount-leak

Invariant: immutability — inserted pages hold KV for fully-prefilled
    prompt positions only and are never written again (the engine
    inserts only the ``len(prompt) // page_size`` full pages; the
    partial tail page stays slot-private).
Enforced-by: tests/test_prefix_cache.py::test_radix_partial_hit_mid_page_is_cow_source

Invariant: copy-on-write — a lookup matching into the middle of a node's
    first unmatched page maps the matched full pages directly and
    duplicates the partial page into a private copy
    (``steps.make_page_copy_step``) before the slot appends.
Enforced-by: tests/test_prefix_cache.py::test_scheduler_plans_cow_and_rolls_back_under_pressure

Invariant: LRU eviction — under pool pressure, leaf runs are evicted
    oldest first, and only when no live slot shares their pages
    (refcount == 1), so each eviction frees exactly ``len(node.pages)``
    pages.
Enforced-by: tests/test_prefix_cache.py::test_radix_lru_eviction_and_shared_protection

Invariant: spill restore is byte-identical — page payloads spilled to the
    ``HostSpillStore`` (including int8 payloads and their per-(page, slot)
    scale rows) restore bit-for-bit into freshly allocated pages of any
    replica, so a prefix/cross hit after a membership change reads exactly
    the bytes the original prefill/encode wrote.
Enforced-by: tests/test_elastic_serving.py::test_spill_restore_int8_byte_identity
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Tuple

import numpy as np


def _common_len(a, b) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixNode:
    __slots__ = ("key", "pages", "children", "parent", "last_access")

    def __init__(self, key: tuple, pages: List[int],
                 parent: Optional["RadixNode"]):
        self.key = key
        self.pages = pages
        self.children: dict = {}
        self.parent = parent
        self.last_access = 0


class RadixPrefixCache:
    """Radix tree over token ids; holds allocator refs on cached pages."""

    def __init__(self, allocator, page_size: int):
        self.allocator = allocator
        self.psz = page_size
        self.root = RadixNode((), [], None)
        self._clock = 0          # logical LRU clock (deterministic)
        self.evictions = 0

    # ------------------------------------------------------------- queries
    @property
    def n_cached_pages(self) -> int:
        return sum(len(n.pages) for n in self._nodes())

    @property
    def n_nodes(self) -> int:
        return sum(1 for _ in self._nodes())

    @property
    def n_evictable_pages(self) -> int:
        """Pages eviction could eventually free: nodes whose whole subtree
        is unshared (refcount 1 everywhere).  A pinned descendant keeps its
        ancestors resident because only leaves are ever evicted."""
        total = 0

        def clean(node):
            nonlocal total
            ok = all(self.allocator.refcount(p) == 1 for p in node.pages)
            for ch in node.children.values():
                ok &= clean(ch)           # no short-circuit: count siblings
            if ok and node is not self.root:
                total += len(node.pages)
            return ok

        clean(self.root)
        return total

    def _nodes(self):
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            yield n

    def entries(self):
        """Yield (token_path, pages) per *leaf*, root-to-leaf accumulated.

        Leaves subsume every interior node's prefix, so spilling leaf paths
        alone captures the whole resident corpus; re-inserting them rebuilds
        the interior structure through the normal radix splits."""
        stack = [((), [], self.root)]
        while stack:
            prefix, ppages, node = stack.pop()
            path = prefix + node.key
            pages = ppages + node.pages
            if not node.children and node is not self.root:
                yield path, pages
            stack.extend((path, pages, ch) for ch in node.children.values())

    # -------------------------------------------------------------- lookup
    def lookup(self, tokens) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens`` -> (match_len, pages).

        ``pages`` covers ``ceil(match_len / page_size)`` pages along the
        matched path; when ``match_len`` is not page-aligned the last entry
        is the partially-matched page (copy-on-write source).  Takes no
        refs — the caller pins what it keeps before any eviction can run.
        """
        toks = [int(t) for t in tokens]
        self._clock += 1
        node, pages, matched = self.root, [], 0
        while matched < len(toks):
            rem = toks[matched:]
            child = node.children.get(tuple(rem[:self.psz]))
            if child is None:
                # no full-page match: scan for a mid-page partial match
                best, best_c = None, 0
                for ch in node.children.values():
                    c = _common_len(ch.key, rem)
                    if c > best_c:
                        best, best_c = ch, c
                if best is not None:
                    best.last_access = self._clock
                    pages.append(best.pages[0])
                    matched += best_c
                break
            c = _common_len(child.key, rem)
            child.last_access = self._clock
            n_full = c // self.psz
            pages.extend(child.pages[:n_full])
            if c % self.psz:
                pages.append(child.pages[n_full])
            matched += c
            if c < len(child.key):
                break
            node = child
        return matched, pages

    # -------------------------------------------------------------- insert
    def insert(self, tokens, pages: List[int]) -> int:
        """Cache ``pages`` (full pages of a prefilled prompt) under
        ``tokens``; len(tokens) must equal len(pages) * page_size.

        Prefix parts already cached keep their existing pages (the caller's
        duplicates stay slot-owned and die with the slot); only the new
        suffix takes cache refs.  -> number of newly referenced pages."""
        toks = [int(t) for t in tokens]
        assert len(toks) == len(pages) * self.psz, (len(toks), len(pages))
        self._clock += 1
        node, i = self.root, 0
        while i < len(toks):
            rem = toks[i:]
            child = node.children.get(tuple(rem[:self.psz]))
            if child is None:
                leaf = RadixNode(tuple(rem), list(pages[i // self.psz:]),
                                 node)
                leaf.last_access = self._clock
                self.allocator.incref(leaf.pages)
                node.children[tuple(rem[:self.psz])] = leaf
                return len(leaf.pages)
            c = _common_len(child.key, rem)
            cp = (c // self.psz) * self.psz   # split at a page boundary
            child.last_access = self._clock
            if cp < len(child.key):
                self._split(child, cp)
            i += cp
            node = child
        return 0

    def _split(self, node: RadixNode, cp: int):
        """Split ``node`` so its key ends at page-aligned offset ``cp``."""
        tail = RadixNode(node.key[cp:], node.pages[cp // self.psz:], node)
        tail.children = node.children
        tail.last_access = node.last_access
        for gc in tail.children.values():
            gc.parent = tail
        node.key = node.key[:cp]
        node.pages = node.pages[:cp // self.psz]
        node.children = {tail.key[:self.psz]: tail}

    # ------------------------------------------------------------ eviction
    def evict(self, n_pages: int) -> int:
        """Evict LRU leaf runs until >= ``n_pages`` pages return to the
        pool (or nothing evictable remains).  -> pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            for n in self._nodes():
                if n.children:
                    continue                  # leaves only: children first
                if any(self.allocator.refcount(p) > 1 for p in n.pages):
                    continue                  # shared with a live slot
                if victim is None or n.last_access < victim.last_access:
                    victim = n
            if victim is None:
                break
            self.allocator.decref(victim.pages)
            freed += len(victim.pages)
            del victim.parent.children[victim.key[:self.psz]]
            self.evictions += 1
        return freed


class PromptLookupDraft:
    """Self-drafting source for speculative decoding — no second model.

    Prompt-lookup (n-gram) drafting: the longest trailing n-gram of the
    slot's context (prompt + emitted tokens) is matched against its most
    recent earlier occurrence, first within the context itself, then along
    the radix prefix cache's stored token paths; the k tokens that followed
    that occurrence become the draft.  Drafts are proposals only — the
    verify step scores them against the real model and rejection keeps
    outputs token-identical — so a bad draft costs pages, never accuracy.
    An empty return means "no guess": the engine falls back to the
    one-token decode path for that slot this tick."""

    def __init__(self, prefix_cache: Optional[RadixPrefixCache] = None,
                 max_ngram: int = 3):
        self.prefix_cache = prefix_cache
        self.max_ngram = max_ngram

    def draft(self, context, k: int) -> List[int]:
        """Propose up to ``k`` continuation tokens for ``context``."""
        if k <= 0 or len(context) < 2:
            return []
        toks = [int(t) for t in context]
        for n in range(min(self.max_ngram, len(toks) - 1), 0, -1):
            gram = toks[-n:]
            # most recent earlier occurrence within the context itself
            for i in range(len(toks) - n - 1, -1, -1):
                if toks[i:i + n] == gram:
                    out = toks[i + n:i + n + k]
                    if out:
                        return out
            # then along cached token paths (other requests' prompts)
            best: List[int] = []
            for path in self._cache_paths():
                for i in range(len(path) - n, -1, -1):
                    if list(path[i:i + n]) == gram:
                        out = [int(t) for t in path[i + n:i + n + k]]
                        if len(out) > len(best):
                            best = out
                        break
            if best:
                return best
        return []

    def _cache_paths(self):
        """Root-to-leaf token sequences of the radix cache (leaves subsume
        every interior path, so they are the whole searchable corpus)."""
        if self.prefix_cache is None:
            return
        stack = [((), self.prefix_cache.root)]
        while stack:
            prefix, node = stack.pop()
            path = prefix + node.key
            if not node.children and node is not self.prefix_cache.root:
                yield path
            stack.extend((path, ch) for ch in node.children.values())


class CrossKVCache:
    """Encoder cross-KV sharing: frames digest -> refcounted page run.

    The cache holds ONE allocator ref per page of every entry; a serving
    slot that hits takes an extra ref (``acquire``) and drops it at
    finish/preemption, so an entry's pages return to the pool only when
    the entry is evicted AND no slot still reads them.  Entries whose
    pages are unshared (refcount 1 — cache-only) are LRU-evictable under
    pool pressure.  No copy-on-write: cross pages are written once at
    admission (``steps.make_cross_kv_write_step``) and read-only after."""

    def __init__(self, allocator):
        self.allocator = allocator
        self._entries: dict = {}    # digest -> [pages, last_access]
        self._clock = 0
        self.evictions = 0

    @staticmethod
    def digest(frames) -> str:
        """Identity of an encoder input (exact-content digest)."""
        a = np.ascontiguousarray(np.asarray(frames))
        return hashlib.sha1(a.tobytes() + str(a.shape).encode()).hexdigest()

    @property
    def n_cached_pages(self) -> int:
        return sum(len(e[0]) for e in self._entries.values())

    @property
    def n_entries(self) -> int:
        return len(self._entries)

    @property
    def n_evictable_pages(self) -> int:
        return sum(len(e[0]) for e in self._entries.values()
                   if all(self.allocator.refcount(p) == 1 for p in e[0]))

    def has(self, key: str) -> bool:
        """Read-only residency probe (no refs, no LRU touch) — the dp
        router's frames-affinity signal."""
        return key in self._entries

    def acquire(self, key: str) -> Optional[List[int]]:
        """Pages for ``key`` with one extra (slot) ref taken, or None."""
        self._clock += 1
        e = self._entries.get(key)
        if e is None:
            return None
        e[1] = self._clock
        self.allocator.incref(e[0])
        return list(e[0])

    def insert(self, key: str, pages: List[int]) -> bool:
        """Adopt ``pages`` (freshly written cross-KV) under ``key``; takes
        one cache ref per page.  Returns False (no refs taken) when the
        key is already cached — the caller's pages then stay slot-private
        and die with the slot (two same-frame admissions in one tick)."""
        self._clock += 1
        if key in self._entries:
            return False
        self.allocator.incref(pages)
        self._entries[key] = [list(pages), self._clock]
        return True

    def entries(self):
        """Yield (digest, pages) for every cached encode."""
        for key, (pages, _) in self._entries.items():
            yield key, list(pages)

    def evict(self, n_pages: int) -> int:
        """Evict LRU unshared entries until >= n_pages freed (or nothing
        evictable remains).  -> pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            for k, (pages, last) in self._entries.items():
                if any(self.allocator.refcount(p) > 1 for p in pages):
                    continue            # a live slot still reads them
                if victim is None or last < self._entries[victim][1]:
                    victim = k
            if victim is None:
                break
            pages, _ = self._entries.pop(victim)
            self.allocator.decref(pages)
            freed += len(pages)
            self.evictions += 1
        return freed


class HostSpillStore:
    """Host-side persistence for hot cache entries across membership changes.

    Device page pools die with their replica rows (drain shrinks the pool;
    a crash loses the rows outright), but the *payload bytes* of radix-
    prefix and cross-KV entries are pure functions of tokens/frames — so
    the engine gathers them to host numpy before a reconfiguration
    (``ServingEngine.spill_state``) and re-inserts them into survivors'
    pools afterwards (``_restore_from_spill``).  Keys are the caches' own
    identities: the leaf token path for radix entries, the frames digest
    for cross entries.  Payload lists hold one numpy array per cache leaf
    of the kind, gathered as ``leaf[:, r, pids]`` — int8 payloads and
    their scale rows are separate leaves and ride along byte-identically
    (the SSM preemption stash proved this gather/restore mechanism).

    A plain dict with overwrite semantics: re-spilling a key replaces the
    entry (latest bytes win), and the store survives engine teardown so a
    fresh engine can warm-start from it (``spill=`` ctor knob)."""

    def __init__(self):
        self.radix: dict = {}        # token path tuple -> (n_pages, payloads)
        self.cross: dict = {}        # frames digest    -> (n_pages, payloads)
        self.pages_saved = 0
        self.pages_restored = 0

    def put_prefix(self, tokens: tuple, n_pages: int, payloads):
        self.radix[tuple(int(t) for t in tokens)] = (n_pages, payloads)
        self.pages_saved += n_pages

    def put_cross(self, key: str, n_pages: int, payloads):
        self.cross[key] = (n_pages, payloads)
        self.pages_saved += n_pages

    @property
    def n_entries(self) -> int:
        return len(self.radix) + len(self.cross)
