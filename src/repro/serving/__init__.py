"""Serving layer: continuous-batching engine (mechanism), schedulers
(policy), prefix/cross caches, dp router, and samplers.  See
ARCHITECTURE.md for the end-to-end map and per-module invariants."""
from repro.serving.engine import (EngineStats, ReplicaStats, Request,  # noqa: F401
                                  ServingEngine)
from repro.serving.policies import FairScheduler, PriorityScheduler  # noqa: F401
from repro.serving.prefix_cache import (CrossKVCache, HostSpillStore,  # noqa: F401
                                        RadixPrefixCache)
from repro.serving.router import Router  # noqa: F401
from repro.serving.sampler import SamplerConfig, sample_from_logits  # noqa: F401
from repro.serving.scheduler import Admission, FCFSScheduler, Scheduler  # noqa: F401
