from repro.serving.engine import EngineStats, Request, ServingEngine  # noqa: F401
from repro.serving.sampler import SamplerConfig, sample_from_logits  # noqa: F401
