from repro.serving.engine import EngineStats, Request, ServingEngine  # noqa: F401
from repro.serving.policies import FairScheduler, PriorityScheduler  # noqa: F401
from repro.serving.prefix_cache import RadixPrefixCache  # noqa: F401
from repro.serving.sampler import SamplerConfig, sample_from_logits  # noqa: F401
from repro.serving.scheduler import Admission, FCFSScheduler, Scheduler  # noqa: F401
