"""Scheduling policy for the serving engine (policy/mechanism split).

``ServingEngine`` is pure mechanism: it owns the device-side state (page
pool, block tables, positions) and executes step functions.  Everything
discretionary — admission order, page budgeting, prefix reuse,
copy-on-write planning, cache eviction, page release — lives here, behind
the small ``Scheduler`` interface, so priority / fairness / preemptive
policies can drop in without touching the engine (``serving.policies``
ships ``PriorityScheduler`` and ``FairScheduler``).

A scheduler communicates decisions as ``Admission`` records; the engine
executes them (COW page copies, chunked prefill from the first uncached
token) and reports lifecycle events back (``on_prefill_complete``,
``on_finish``, ``on_preempt``) for the policy to update its bookkeeping.

``FCFSScheduler`` is both the stock policy and the machinery base: all
paged planning (page budgeting, prefix lookup, COW, eviction, rollback)
lives in it, and subclasses override only the queue-discipline hooks
(``_enqueue`` / ``_select_next`` / ``_put_back`` / ``_requeue_preempted``)
plus, for preemptive policies, ``plan_preemptions``.

Preemption contract: the engine calls ``plan_preemptions`` each tick and
evicts the returned victims via ``ServingEngine.preempt``, which hands the
victim's resident tokens to ``on_preempt``.  ``on_preempt`` donates the
victim's full pages to the radix prefix cache (so resume re-admits as a
prefix hit and the KV is never recomputed), releases the slot's page refs,
and re-queues the request.  A resumed request's admission plans over its
*effective prompt* — original prompt plus the tokens it already generated —
so the ordinary prefix-hit machinery restores its state.

Beyond attention-only archs, admission is a JOINT all-or-nothing budget:

* **SSM/hybrid** — one recurrent-state slab per request
  (``slab_allocator``); a request is admitted only if pages AND a slab are
  both available, and every rollback returns both.  These archs carry no
  radix prefix cache (their state is not re-derivable from token-id
  prefixes), so on preemption the ENGINE checkpoints the slot (slab +
  resident KV pages) to a host-side stash; ``on_preempt`` here just
  releases the resources and re-queues.
* **enc-dec** — ``cross_pages_per_req`` pages of encoder cross-KV from the
  same allocator: a frames-digest hit on the replica's ``CrossKVCache``
  shares the resident pages (refcount only), a miss allocates fresh pages
  and marks the admission ``needs_encode`` so the engine runs the
  cross-KV write step once; ``on_cross_written`` then publishes the pages
  for later identical-frame requests.

Disaggregated serving (``--disagg P:D``) assigns each replica's scheduler
a **role**: a ``prefill`` replica budgets only the resident-prompt page
run (the slot leaves at first token, so no decode-horizon pages are
reserved) and skips draft headroom; a ``decode`` replica plans like
``mixed`` but is fed by ``plan_handoff`` — the destination half of a page
handoff, which allocates a fresh run covering resident + remaining-decode
tokens.  ``on_handoff_sent`` then moves ownership atomically
(``kvcache.handoff_refs``).  A decode replica's own queue is populated
only by preemption requeues; it re-admits them through the ordinary
prefix-hit path over the pages the preemption donated.

Invariant: leak freedom — every page is either free, radix-cached, or
    cross-cached, and every slab is free, after ``run()``/``drain()``
    retire all admissions (asserted by tests at drain).
Enforced-by: tests/test_scheduling.py::test_drain_releases_stranded_pages, analysis:refcount-leak

Invariant: role budgeting conserves the pool — a prefill-role admission
    holds exactly the resident-prompt page run, and a handoff moves those
    references to freshly allocated destination pages exactly once, so
    per-replica leak freedom survives any interleaving of handoffs and
    preemptions.
Enforced-by: tests/test_page_transfer.py::test_handoff_preemption_mid_transfer, analysis:refcount-leak

Invariant: migration moves ownership exactly once — a drain-time
    migration (``plan_migration`` → device transfer → ``on_migrated``)
    either completes, handing the resident pages' references to the
    destination allocator and decref'ing the unfilled horizon tail at the
    source, or rolls back atomically (the destination admission is
    retired via ``on_finish`` and the source slot's estate is untouched);
    no interleaving of a crash with an in-progress handoff can orphan or
    double-free a page.
Enforced-by: tests/test_elastic_serving.py::test_crash_during_handoff_rolls_back, analysis:refcount-leak
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.kvcache import handoff_refs, pages_needed


def effective_prompt(req) -> np.ndarray:
    """Tokens a (re-)admission must make resident: the original prompt plus
    everything the request already generated.  For a fresh request this is
    just the prompt; a preempted request re-prefills its own output, which
    the prefix cache turns into a hit on the pages donated at preemption
    (``on_preempt``) — reuse, not recompute."""
    out = getattr(req, "out_tokens", None)
    if not out:
        return np.asarray(req.prompt, np.int32)
    return np.concatenate([np.asarray(req.prompt, np.int32),
                           np.asarray(out, np.int32)])


def remaining_new_tokens(req) -> int:
    """Decode budget still owed to ``req`` (shrinks across preemptions, so
    effective_prompt + remaining is the constant submit-time budget)."""
    out = getattr(req, "out_tokens", None)
    return req.max_new_tokens - (len(out) if out else 0)


@dataclass
class Admission:
    """One scheduler decision: place ``req`` into engine slot ``slot``.

    pages: the slot's full block-table page run (None for the contiguous
    engine).  cached_len: prompt tokens already resident via prefix sharing
    — chunked prefill starts at this offset.  cow: (src, dst) page pair the
    engine must copy before the slot's first write (divergence out of a
    shared partial page).  seq: global admission order stamp (preemptive
    policies use it to pick the victim with the least sunk work).
    slab: the slot's recurrent-state slab id (SSM/hybrid archs).
    cross_pages: the slot's read-only cross-KV page run (enc-dec archs);
    needs_encode marks a frames-digest miss — the engine must run the
    cross-KV write step before this slot's first prefill chunk.
    spec: the slot's page run includes draft headroom (+spec_tokens of
    coverage past prompt + max_new_tokens), so the engine may run the
    k-token verify step on it; False means speculation was denied at
    admission (pool pressure) and the slot decodes one token per tick."""
    slot: int
    req: object
    pages: Optional[List[int]] = None
    cached_len: int = 0
    cow: Optional[Tuple[int, int]] = None
    seq: int = 0
    slab: Optional[int] = None
    cross_pages: Optional[List[int]] = None
    needs_encode: bool = False
    spec: bool = False


class Scheduler:
    """Policy interface the engine drives.  Implementations own the wait
    queue and (for paged engines) all allocator / prefix-cache traffic."""

    def submit(self, req) -> None:
        raise NotImplementedError

    def has_pending(self) -> bool:
        raise NotImplementedError

    def pending_requests(self) -> List:
        """Queued (not yet admitted) requests, in no particular order —
        load introspection for the dp router (``serving.router``)."""
        raise NotImplementedError

    def plan(self, free_slots: List[int]) -> List[Admission]:
        """Admissions for this tick; at most one per free slot."""
        raise NotImplementedError

    def plan_preemptions(self, active: List[Admission],
                         n_free: int) -> List[Admission]:
        """Victims to evict this tick (before admission planning).  The
        engine preempts each returned admission's slot; default: none."""
        return []

    def on_cow_done(self, adm: Admission) -> None:
        """The engine copied adm.cow — release the pin on the source."""

    def on_prefill_complete(self, adm: Admission) -> None:
        """adm's prompt is fully resident (cache-insertion hook)."""

    def on_cross_written(self, adm: Admission) -> None:
        """The engine ran adm's cross-KV write — publish the pages."""

    def on_finish(self, adm: Admission) -> None:
        """adm's request retired — release its resources."""

    def on_preempt(self, adm: Admission, resident_tokens) -> None:
        """adm was evicted mid-flight with ``resident_tokens`` computed —
        salvage its pages and re-queue the request."""
        raise NotImplementedError

    def on_spec_trim(self, adm: Admission, keep: int) -> None:
        """The engine stopped speculating on adm's slot — return the draft
        headroom pages past block-table index ``keep``; default: no-op
        (the contiguous engine holds no pages)."""

    def plan_handoff(self, slot: int, req,
                     resident_len: int) -> Optional[Admission]:
        """Destination-side admission for an incoming page handoff
        (disaggregated serving); default: refuse, the handoff stays
        queued at the source replica."""
        return None

    def on_handoff_sent(self, adm: Admission, dst_allocator,
                        dst_pages) -> None:
        """adm's resident pages were transferred to another replica —
        move reference ownership and retire the source slot."""
        raise NotImplementedError


class FCFSScheduler(Scheduler):
    """First-come-first-served admission (the seed engine's policy).

    Paged mode adds all-or-nothing page budgeting — the head request either
    gets its full budget (prompt + max_new_tokens) or the whole queue waits
    (no mid-flight OOM, no starvation-by-overtaking) — plus optional radix
    prefix sharing: admission maps the longest cached prefix into the block
    table, duplicating a partially-shared page copy-on-write, and evicts
    LRU cache runs when the pool can't cover the remainder."""

    def __init__(self, *, seq_budget: int, allocator=None, page_size: int = 0,
                 prefix_cache=None, stats=None, slab_allocator=None,
                 cross_cache=None, cross_pages_per_req: int = 0,
                 kv_pages: bool = True, spec_tokens: int = 0,
                 role: str = "mixed"):
        assert role in ("mixed", "prefill", "decode"), role
        self.queue: collections.deque = collections.deque()
        self.seq_budget = seq_budget
        # disaggregation role: "prefill" budgets only the resident-prompt
        # page run (the slot hands off at first token) and never reserves
        # draft headroom; "decode" plans like "mixed" (the marker is for
        # the router's placement)
        self.role = role
        self.allocator = allocator
        self.psz = page_size
        self.prefix_cache = prefix_cache
        # False for pure-SSM archs: no layer has a KV pool, so per-token
        # page demand is zero (state lives entirely in the slab)
        self.kv_pages = kv_pages
        self.slab_allocator = slab_allocator        # SSM/hybrid archs
        self.cross_cache = cross_cache              # enc-dec archs
        self.cross_pages_per_req = cross_pages_per_req
        # speculative-decoding draft headroom: admissions try to budget
        # +spec_tokens of extra page coverage so the verify step can write
        # drafted positions past prompt + max_new_tokens (0 = off)
        self.spec_tokens = spec_tokens
        # cross pages planned this tick but not yet written: a second
        # same-frame admission in the same plan() round shares them
        # instead of running a duplicate encode
        self._pending_cross: dict = {}
        self.stats = stats
        self._round = 0      # logical clock: one tick per plan() call
        self._adm_seq = 0    # admission order stamp
        # page demand of the queued backlog, maintained at every
        # enqueue/dequeue (submit / admission / put-back / requeue) so the
        # dp router's load probe is O(1) instead of a queue scan
        self.backlog_pages = 0
        # per-replica counter block (engine-assigned for dp engines) —
        # written at the SAME site as the global stats so the two hit
        # rates cannot drift
        self.replica_stats = None

    @property
    def paged(self) -> bool:
        return self.allocator is not None

    # ------------------------------------------------- queue discipline hooks
    def _enqueue(self, req) -> None:
        self.queue.append(req)

    def _select_next(self):
        """Next request to try admitting, or None."""
        return self.queue.popleft() if self.queue else None

    def _put_back(self, req) -> None:
        """Selected request could not be admitted (page pressure): it stays
        head-of-line so nothing overtakes it."""
        self.queue.appendleft(req)

    def _requeue_preempted(self, req) -> None:
        """Preempted request returns to the queue; FCFS resumes it first."""
        self.queue.appendleft(req)

    # ------------------------------------------------------------- intake
    def submit(self, req) -> None:
        if len(req.prompt) == 0:
            raise RuntimeError(f"request {req.rid} has an empty prompt")
        if self.paged:
            if len(req.prompt) + req.max_new_tokens > self.seq_budget:
                raise RuntimeError(
                    f"request {req.rid} needs {len(req.prompt)} prompt + "
                    f"{req.max_new_tokens} new tokens; the sequence budget "
                    f"is {self.seq_budget}")
            need = self._req_pages(req)
            usable = self.allocator.n_pages - self.allocator.n_reserved
            if need > usable:       # reject now, not mid-run at admission
                raise RuntimeError(
                    f"request {req.rid} needs {need} pages"
                    + (f" (incl. {self.cross_pages_per_req} cross-KV)"
                       if self.cross_pages_per_req else "")
                    + f"; the pool only has {usable} usable")
        elif len(req.prompt) >= self.seq_budget:
            # the contiguous lane needs room past the prompt for decode
            raise RuntimeError(
                f"request {req.rid} prompt ({len(req.prompt)} tokens) "
                f"exceeds the sequence budget {self.seq_budget}")
        self._enqueue(req)
        self.backlog_pages += self._req_pages(req)

    def has_pending(self) -> bool:
        return bool(self.queue)

    def pending_requests(self) -> List:
        return list(self.queue)

    def _req_pages(self, req) -> int:
        """Page demand of one queued request (cross-KV included).
        Constant while it waits (out_tokens only grow while admitted), so
        the backlog counter's add/subtract stay symmetric across put-backs
        and requeues."""
        if not self.paged:
            return 0
        n = len(effective_prompt(req))
        if self.role != "prefill":      # prefill slots leave at first token
            n += remaining_new_tokens(req)
        return (pages_needed(n, self.psz) if self.kv_pages else 0) \
            + self.cross_pages_per_req

    def _evictable_pages(self) -> int:
        """Pages eviction could eventually reclaim across both caches."""
        n = 0
        if self.prefix_cache is not None:
            n += self.prefix_cache.n_evictable_pages
        if self.cross_cache is not None:
            n += self.cross_cache.n_evictable_pages
        return n

    def _reclaim(self, shortfall: int) -> None:
        """Evict cached runs until ``shortfall`` pages are freed (radix
        prefix leaves first — they are rebuildable per request — then
        whole cross-KV entries)."""
        if self.prefix_cache is not None:
            shortfall -= self.prefix_cache.evict(shortfall)
        if shortfall > 0 and self.cross_cache is not None:
            self.cross_cache.evict(shortfall)

    def _admissible_without_eviction(self, req) -> bool:
        """True if a free slot could actually serve ``req`` right now —
        pool pages and state slabs included.  A free slot whose pool is
        exhausted must not suppress preemption: evicting a victim is what
        frees the pages (and its slab)."""
        if not self.paged:
            return True
        if self.slab_allocator is not None and \
                self.slab_allocator.n_free == 0:
            return False
        return self.allocator.n_free + self._evictable_pages() \
            >= self._req_pages(req)

    # ---------------------------------------------------------- admission
    def plan(self, free_slots: List[int]) -> List[Admission]:
        self._round += 1
        out = []
        for slot in free_slots:
            req = self._select_next()
            if req is None:
                break
            self.backlog_pages -= self._req_pages(req)
            if self.paged:
                adm = self._plan_paged(slot, req)
                if adm is None:     # blocked: wait for reclamation
                    self._put_back(req)
                    self.backlog_pages += self._req_pages(req)
                    break
            else:
                adm = Admission(slot=slot, req=req)
            adm.seq = self._adm_seq
            self._adm_seq += 1
            out.append(adm)
        return out

    def _can_reclaim(self, need: int) -> bool:
        """True if evicting cache runs can actually cover a ``need``-page
        allocation (free pages + eventually-evictable cached pages, radix
        and cross-KV caches both)."""
        ev = self._evictable_pages()
        return ev > 0 and self.allocator.n_free + ev >= need

    def _plan_paged(self, slot: int, req) -> Optional[Admission]:
        prompt = effective_prompt(req)
        L = len(prompt)
        horizon = L if self.role == "prefill" \
            else L + remaining_new_tokens(req)
        total = pages_needed(horizon, self.psz) if self.kv_pages else 0
        alloc = self.allocator
        # ---- recurrent-state slab (SSM/hybrid): all-or-nothing with pages
        slab = None
        if self.slab_allocator is not None:
            slab = self.slab_allocator.alloc()
            if slab is None:        # every slot busy or leaked — wait
                return None
        cached_len, run = 0, []
        if self.prefix_cache is not None:
            matched, run = self.prefix_cache.lookup(prompt)
            # always prefill >= 1 token: the final prompt position's logits
            # seed the first decode
            cached_len = min(matched, max(L - 1, 0))
        n_full = cached_len // self.psz
        shared = run[:n_full]
        cow_src = run[n_full] if cached_len % self.psz else None
        # pin the reused pages before eviction (below) can touch them
        alloc.incref(shared)
        if cow_src is not None:
            alloc.incref([cow_src])
        need = total - n_full
        fresh = alloc.alloc(need)
        if fresh is None and self._can_reclaim(need):
            # evict only when it actually covers the shortfall — a futile
            # eviction would wipe hot prefixes and still leave us blocked
            self._reclaim(need - alloc.n_free)
            fresh = alloc.alloc(need)
        if fresh is None and (shared or cow_src is not None):
            # Prefix reuse itself can block admission: the pins above make
            # the matched run unevictable, and the leftover fresh-page need
            # may exceed what eviction can reclaim — forever, if no other
            # slot is in flight.  Degrade to a cold prefill: drop the pins
            # (the run becomes evictable), reclaim, take the budget fresh.
            alloc.decref(shared)
            if cow_src is not None:
                alloc.decref([cow_src])
            shared, cow_src, cached_len, n_full = [], None, 0, 0
            need = total
            if alloc.n_free < need and self._can_reclaim(need):
                self._reclaim(need - alloc.n_free)
            fresh = alloc.alloc(need)
        if fresh is None:           # roll the pins back; the head blocks
            alloc.decref(shared)
            if cow_src is not None:
                alloc.decref([cow_src])
            if slab is not None:
                self.slab_allocator.free(slab)
            return None
        # ---- encoder cross-KV (enc-dec): digest hit shares, miss encodes
        cross_pages, needs_encode = None, False
        if self.cross_cache is not None:
            key = self.cross_cache.digest(req.frames)
            cross_pages = self.cross_cache.acquire(key)
            if cross_pages is None and key in self._pending_cross:
                # same frames admitted earlier this tick: its write step
                # runs before any read, so sharing is already safe
                cross_pages = list(self._pending_cross[key])
                alloc.incref(cross_pages)
            if cross_pages is None:
                needs_encode = True
                ncross = self.cross_pages_per_req
                cross_pages = alloc.alloc(ncross)
                if cross_pages is None and self._can_reclaim(ncross):
                    self._reclaim(ncross - alloc.n_free)
                    cross_pages = alloc.alloc(ncross)
                if cross_pages is None:   # joint rollback; the head blocks
                    alloc.decref(shared + fresh)
                    if cow_src is not None:
                        alloc.decref([cow_src])
                    if slab is not None:
                        self.slab_allocator.free(slab)
                    return None
                self._pending_cross[key] = list(cross_pages)
        # ---- speculative draft headroom: +spec_tokens of page coverage so
        # the verify step can write drafted positions past the base budget.
        # Opportunistic and all-or-nothing: on pool pressure the request is
        # still admitted, just without speculation (adm.spec=False), and no
        # cache eviction runs — hot resident prefixes outrank draft room.
        spec, spec_pages = False, []
        if self.spec_tokens > 0 and self.kv_pages \
                and self.role != "prefill":
            n_max = self.seq_budget // self.psz
            extra = min(pages_needed(L + remaining_new_tokens(req) +
                                     self.spec_tokens, self.psz),
                        n_max) - total
            spec_pages = alloc.alloc(extra)
            if spec_pages is None:
                spec_pages = []
                for st in (self.stats, self.replica_stats):
                    if st is not None:
                        st.spec_denied += 1
            else:
                spec = True
        # count stats on admission only — a blocked head-of-line request is
        # re-planned every tick and must not inflate the hit rates
        if self.prefix_cache is not None:
            for st in (self.stats, self.replica_stats):
                if st is not None:
                    st.prefix_lookups += 1
                    st.prefix_hits += cached_len > 0
        if self.cross_cache is not None:
            for st in (self.stats, self.replica_stats):
                if st is not None:
                    st.cross_lookups += 1
                    st.cross_hits += not needs_encode
        # fresh[0] sits at block-table index n_full: exactly where the COW
        # copy of the partial page belongs
        cow = (cow_src, fresh[0]) if cow_src is not None else None
        return Admission(slot=slot, req=req,
                         pages=shared + fresh + spec_pages,
                         cached_len=cached_len, cow=cow, slab=slab,
                         cross_pages=cross_pages, needs_encode=needs_encode,
                         spec=spec)

    # ------------------------------------------------------------- events
    def on_cow_done(self, adm: Admission) -> None:
        self.allocator.decref([adm.cow[0]])

    def on_prefill_complete(self, adm: Admission) -> None:
        if self.prefix_cache is None:
            return
        prompt = effective_prompt(adm.req)
        n_full = len(prompt) // self.psz    # the partial tail stays private
        if n_full:
            self.prefix_cache.insert(prompt[:n_full * self.psz],
                                     adm.pages[:n_full])

    def on_cross_written(self, adm: Admission) -> None:
        """The engine encoded adm's frames and wrote its cross pages —
        publish them for later identical-frame requests (the cache takes
        its own refs) and retire the same-tick pending entry."""
        key = self.cross_cache.digest(adm.req.frames)
        self._pending_cross.pop(key, None)
        self.cross_cache.insert(key, adm.cross_pages)

    def _release(self, adm: Admission) -> None:
        """Drop every resource an admission holds (pages, slab, cross)."""
        self.allocator.decref(adm.pages)
        if adm.slab is not None:
            self.slab_allocator.free(adm.slab)
        if adm.cross_pages is not None:
            self.allocator.decref(adm.cross_pages)

    def on_finish(self, adm: Admission) -> None:
        if self.paged:
            self._release(adm)

    def on_spec_trim(self, adm: Admission, keep: int) -> None:
        """The engine stopped speculating on adm's slot (persistent draft
        misses) — return the headroom pages past block-table index ``keep``
        to the pool.  Tail pages of a partially rejected draft may be
        shared with the radix prefix cache by the time the trim runs (a
        preemption donated them, or an identical prompt was inserted), so
        this drops a *reference* per page (``allocator.trim``) rather than
        assert-freeing."""
        self.allocator.trim(adm.pages[keep:])
        del adm.pages[keep:]
        adm.spec = False

    def on_preempt(self, adm: Admission, resident_tokens) -> None:
        """Salvage an evicted slot: donate its resident *full* pages to the
        prefix cache (resume finds them as a prefix hit — the victim's KV
        is reused, never recomputed), drop the slot's page refs (slab and
        cross-KV refs too — SSM state travels via the engine's host-side
        stash instead, and cross pages usually stay resident in the
        cross-KV cache), and re-queue the request.  The partial tail page
        is slot-private KV and is simply freed; resume re-prefills those
        few tokens."""
        if self.paged:
            if self.prefix_cache is not None:
                n_full = len(resident_tokens) // self.psz
                if n_full:
                    self.prefix_cache.insert(
                        resident_tokens[:n_full * self.psz],
                        adm.pages[:n_full])
            self._release(adm)
        self._requeue_preempted(adm.req)
        self.backlog_pages += self._req_pages(adm.req)

    # ------------------------------------------------------ disaggregation
    def plan_handoff(self, slot: int, req,
                     resident_len: int) -> Optional[Admission]:
        """Destination-side admission for an incoming page handoff: the
        request arrives with ``resident_len`` tokens of KV already computed
        on the source replica, so this allocates a fresh run covering
        resident + remaining-decode tokens (the device transfer step fills
        the resident prefix; reference ownership moves separately via
        ``on_handoff_sent`` → ``kvcache.handoff_refs``).  All-or-nothing
        like ``_plan_paged``: returning None leaves the handoff queued at
        the source.  Draft headroom is budgeted opportunistically, exactly
        as at a cold admission."""
        total = pages_needed(resident_len + remaining_new_tokens(req),
                             self.psz)
        alloc = self.allocator
        fresh = alloc.alloc(total)
        if fresh is None and self._can_reclaim(total):
            self._reclaim(total - alloc.n_free)
            fresh = alloc.alloc(total)
        if fresh is None:
            return None
        spec, spec_pages = False, []
        if self.spec_tokens > 0:
            n_max = self.seq_budget // self.psz
            extra = min(pages_needed(resident_len +
                                     remaining_new_tokens(req) +
                                     self.spec_tokens, self.psz),
                        n_max) - total
            spec_pages = alloc.alloc(extra)
            if spec_pages is None:
                spec_pages = []
                for st in (self.stats, self.replica_stats):
                    if st is not None:
                        st.spec_denied += 1
            else:
                spec = True
        adm = Admission(slot=slot, req=req, pages=fresh + spec_pages,
                        cached_len=resident_len, spec=spec)
        adm.seq = self._adm_seq
        self._adm_seq += 1
        return adm

    def on_handoff_sent(self, adm: Admission, dst_allocator,
                        dst_pages) -> None:
        """The engine transferred adm's resident pages to another replica:
        move reference ownership atomically (the source refs drop exactly
        once; pages the radix cache shares stay resident here).  Prefill
        admissions hold no slab or cross pages — disaggregation is gated
        to attention-only archs — so the page refs are the whole estate."""
        handoff_refs(self.allocator, adm.pages, dst_allocator, dst_pages)

    # ------------------------------------------------- elastic membership
    def plan_migration(self, slot: int, req,
                       resident_len: int) -> Optional[Admission]:
        """Destination-side admission for a drain-time slot migration.

        Unlike ``plan_handoff`` (whose source is a finished prefill, so
        resident + remaining covers everything), a migrating slot may
        still be mid-prefill — so this budgets the full cold-admission
        horizon, ``len(effective_prompt) + remaining_new_tokens`` (the
        constant submit-time budget).  That total always covers the
        resident pages: a slot's resident length never exceeds its
        effective prompt + emitted tokens.  All-or-nothing: returning
        None makes the engine fall back to preempt-and-requeue."""
        total = pages_needed(len(effective_prompt(req)) +
                             remaining_new_tokens(req), self.psz)
        alloc = self.allocator
        fresh = alloc.alloc(total)
        if fresh is None and self._can_reclaim(total):
            self._reclaim(total - alloc.n_free)
            fresh = alloc.alloc(total)
        if fresh is None:
            return None
        spec, spec_pages = False, []
        if self.spec_tokens > 0:
            n_max = self.seq_budget // self.psz
            extra = min(pages_needed(len(effective_prompt(req)) +
                                     remaining_new_tokens(req) +
                                     self.spec_tokens, self.psz),
                        n_max) - total
            spec_pages = alloc.alloc(extra)
            if spec_pages is None:
                spec_pages = []
                for st in (self.stats, self.replica_stats):
                    if st is not None:
                        st.spec_denied += 1
            else:
                spec = True
        adm = Admission(slot=slot, req=req, pages=fresh + spec_pages,
                        cached_len=resident_len, spec=spec)
        adm.seq = self._adm_seq
        self._adm_seq += 1
        return adm

    def on_migrated(self, adm: Admission, k: int, dst_allocator,
                    dst_pages) -> None:
        """The engine transferred adm's first ``k`` (resident) pages to
        another replica: hand exactly those references over atomically,
        then drop the unfilled horizon tail.  The tail goes through
        ``decref`` rather than ``free`` — a preemption elsewhere may have
        donated overlapping pages to the radix cache by now."""
        if k:
            handoff_refs(self.allocator, adm.pages[:k],
                         dst_allocator, dst_pages)
        self.allocator.decref(adm.pages[k:])
        if adm.cross_pages is not None:
            self.allocator.decref(adm.cross_pages)

    def take_queued(self) -> List:
        """Drain-time queue takeover: every queued (not yet admitted)
        request leaves this scheduler for re-placement elsewhere; the
        backlog counter returns to zero with them."""
        out = self.pending_requests()
        for req in out:
            self.backlog_pages -= self._req_pages(req)
        self._clear_queue()
        return out

    def _clear_queue(self) -> None:
        self.queue.clear()
