"""Scheduling policy for the serving engine (policy/mechanism split).

``ServingEngine`` is pure mechanism: it owns the device-side state (page
pool, block tables, positions) and executes step functions.  Everything
discretionary — admission order, page budgeting, prefix reuse,
copy-on-write planning, cache eviction, page release — lives here, behind
the small ``Scheduler`` interface, so priority / fairness / preemptive
policies can drop in without touching the engine (``serving.policies``
ships ``PriorityScheduler`` and ``FairScheduler``).

A scheduler communicates decisions as ``Admission`` records; the engine
executes them (COW page copies, chunked prefill from the first uncached
token) and reports lifecycle events back (``on_prefill_complete``,
``on_finish``, ``on_preempt``) for the policy to update its bookkeeping.

``FCFSScheduler`` is both the stock policy and the machinery base: all
paged planning (page budgeting, prefix lookup, COW, eviction, rollback)
lives in it, and subclasses override only the queue-discipline hooks
(``_enqueue`` / ``_select_next`` / ``_put_back`` / ``_requeue_preempted``)
plus, for preemptive policies, ``plan_preemptions``.

Preemption contract: the engine calls ``plan_preemptions`` each tick and
evicts the returned victims via ``ServingEngine.preempt``, which hands the
victim's resident tokens to ``on_preempt``.  ``on_preempt`` donates the
victim's full pages to the radix prefix cache (so resume re-admits as a
prefix hit and the KV is never recomputed), releases the slot's page refs,
and re-queues the request.  A resumed request's admission plans over its
*effective prompt* — original prompt plus the tokens it already generated —
so the ordinary prefix-hit machinery restores its state.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.kvcache import pages_needed


def effective_prompt(req) -> np.ndarray:
    """Tokens a (re-)admission must make resident: the original prompt plus
    everything the request already generated.  For a fresh request this is
    just the prompt; a preempted request re-prefills its own output, which
    the prefix cache turns into a hit on the pages donated at preemption
    (``on_preempt``) — reuse, not recompute."""
    out = getattr(req, "out_tokens", None)
    if not out:
        return np.asarray(req.prompt, np.int32)
    return np.concatenate([np.asarray(req.prompt, np.int32),
                           np.asarray(out, np.int32)])


def remaining_new_tokens(req) -> int:
    """Decode budget still owed to ``req`` (shrinks across preemptions, so
    effective_prompt + remaining is the constant submit-time budget)."""
    out = getattr(req, "out_tokens", None)
    return req.max_new_tokens - (len(out) if out else 0)


@dataclass
class Admission:
    """One scheduler decision: place ``req`` into engine slot ``slot``.

    pages: the slot's full block-table page run (None for the contiguous
    engine).  cached_len: prompt tokens already resident via prefix sharing
    — chunked prefill starts at this offset.  cow: (src, dst) page pair the
    engine must copy before the slot's first write (divergence out of a
    shared partial page).  seq: global admission order stamp (preemptive
    policies use it to pick the victim with the least sunk work)."""
    slot: int
    req: object
    pages: Optional[List[int]] = None
    cached_len: int = 0
    cow: Optional[Tuple[int, int]] = None
    seq: int = 0


class Scheduler:
    """Policy interface the engine drives.  Implementations own the wait
    queue and (for paged engines) all allocator / prefix-cache traffic."""

    def submit(self, req) -> None:
        raise NotImplementedError

    def has_pending(self) -> bool:
        raise NotImplementedError

    def pending_requests(self) -> List:
        """Queued (not yet admitted) requests, in no particular order —
        load introspection for the dp router (``serving.router``)."""
        raise NotImplementedError

    def plan(self, free_slots: List[int]) -> List[Admission]:
        """Admissions for this tick; at most one per free slot."""
        raise NotImplementedError

    def plan_preemptions(self, active: List[Admission],
                         n_free: int) -> List[Admission]:
        """Victims to evict this tick (before admission planning).  The
        engine preempts each returned admission's slot; default: none."""
        return []

    def on_cow_done(self, adm: Admission) -> None:
        """The engine copied adm.cow — release the pin on the source."""

    def on_prefill_complete(self, adm: Admission) -> None:
        """adm's prompt is fully resident (cache-insertion hook)."""

    def on_finish(self, adm: Admission) -> None:
        """adm's request retired — release its resources."""

    def on_preempt(self, adm: Admission, resident_tokens) -> None:
        """adm was evicted mid-flight with ``resident_tokens`` computed —
        salvage its pages and re-queue the request."""
        raise NotImplementedError


class FCFSScheduler(Scheduler):
    """First-come-first-served admission (the seed engine's policy).

    Paged mode adds all-or-nothing page budgeting — the head request either
    gets its full budget (prompt + max_new_tokens) or the whole queue waits
    (no mid-flight OOM, no starvation-by-overtaking) — plus optional radix
    prefix sharing: admission maps the longest cached prefix into the block
    table, duplicating a partially-shared page copy-on-write, and evicts
    LRU cache runs when the pool can't cover the remainder."""

    def __init__(self, *, seq_budget: int, allocator=None, page_size: int = 0,
                 prefix_cache=None, stats=None):
        self.queue: collections.deque = collections.deque()
        self.seq_budget = seq_budget
        self.allocator = allocator
        self.psz = page_size
        self.prefix_cache = prefix_cache
        self.stats = stats
        self._round = 0      # logical clock: one tick per plan() call
        self._adm_seq = 0    # admission order stamp
        # page demand of the queued backlog, maintained at every
        # enqueue/dequeue (submit / admission / put-back / requeue) so the
        # dp router's load probe is O(1) instead of a queue scan
        self.backlog_pages = 0
        # per-replica counter block (engine-assigned for dp engines) —
        # written at the SAME site as the global stats so the two hit
        # rates cannot drift
        self.replica_stats = None

    @property
    def paged(self) -> bool:
        return self.allocator is not None

    # ------------------------------------------------- queue discipline hooks
    def _enqueue(self, req) -> None:
        self.queue.append(req)

    def _select_next(self):
        """Next request to try admitting, or None."""
        return self.queue.popleft() if self.queue else None

    def _put_back(self, req) -> None:
        """Selected request could not be admitted (page pressure): it stays
        head-of-line so nothing overtakes it."""
        self.queue.appendleft(req)

    def _requeue_preempted(self, req) -> None:
        """Preempted request returns to the queue; FCFS resumes it first."""
        self.queue.appendleft(req)

    # ------------------------------------------------------------- intake
    def submit(self, req) -> None:
        if len(req.prompt) == 0:
            raise RuntimeError(f"request {req.rid} has an empty prompt")
        if self.paged:
            if len(req.prompt) + req.max_new_tokens > self.seq_budget:
                raise RuntimeError(
                    f"request {req.rid} needs {len(req.prompt)} prompt + "
                    f"{req.max_new_tokens} new tokens; the sequence budget "
                    f"is {self.seq_budget}")
            need = pages_needed(len(req.prompt) + req.max_new_tokens,
                                self.psz)
            usable = self.allocator.n_pages - self.allocator.n_reserved
            if need > usable:       # reject now, not mid-run at admission
                raise RuntimeError(
                    f"request {req.rid} needs {need} pages; the pool only "
                    f"has {usable} usable")
        elif len(req.prompt) >= self.seq_budget:
            # the contiguous lane needs room past the prompt for decode
            raise RuntimeError(
                f"request {req.rid} prompt ({len(req.prompt)} tokens) "
                f"exceeds the sequence budget {self.seq_budget}")
        self._enqueue(req)
        self.backlog_pages += self._req_pages(req)

    def has_pending(self) -> bool:
        return bool(self.queue)

    def pending_requests(self) -> List:
        return list(self.queue)

    def _req_pages(self, req) -> int:
        """Page demand of one queued request.  Constant while it waits
        (out_tokens only grow while admitted), so the backlog counter's
        add/subtract stay symmetric across put-backs and requeues."""
        if not self.paged:
            return 0
        return pages_needed(len(effective_prompt(req)) +
                            remaining_new_tokens(req), self.psz)

    def _admissible_without_eviction(self, req) -> bool:
        """True if a free slot could actually serve ``req`` right now —
        pool pages included.  A free slot whose pool is exhausted must not
        suppress preemption: evicting a victim is what frees the pages."""
        if not self.paged:
            return True
        need = pages_needed(len(effective_prompt(req)) +
                            remaining_new_tokens(req), self.psz)
        avail = self.allocator.n_free
        if self.prefix_cache is not None:
            avail += self.prefix_cache.n_evictable_pages
        return avail >= need

    # ---------------------------------------------------------- admission
    def plan(self, free_slots: List[int]) -> List[Admission]:
        self._round += 1
        out = []
        for slot in free_slots:
            req = self._select_next()
            if req is None:
                break
            self.backlog_pages -= self._req_pages(req)
            if self.paged:
                adm = self._plan_paged(slot, req)
                if adm is None:     # blocked: wait for reclamation
                    self._put_back(req)
                    self.backlog_pages += self._req_pages(req)
                    break
            else:
                adm = Admission(slot=slot, req=req)
            adm.seq = self._adm_seq
            self._adm_seq += 1
            out.append(adm)
        return out

    def _can_reclaim(self, need: int) -> bool:
        """True if evicting cache runs can actually cover a ``need``-page
        allocation (free pages + eventually-evictable cached pages)."""
        return self.prefix_cache is not None and \
            self.allocator.n_free + self.prefix_cache.n_evictable_pages \
            >= need

    def _plan_paged(self, slot: int, req) -> Optional[Admission]:
        prompt = effective_prompt(req)
        L = len(prompt)
        total = pages_needed(L + remaining_new_tokens(req), self.psz)
        alloc = self.allocator
        cached_len, run = 0, []
        if self.prefix_cache is not None:
            matched, run = self.prefix_cache.lookup(prompt)
            # always prefill >= 1 token: the final prompt position's logits
            # seed the first decode
            cached_len = min(matched, max(L - 1, 0))
        n_full = cached_len // self.psz
        shared = run[:n_full]
        cow_src = run[n_full] if cached_len % self.psz else None
        # pin the reused pages before eviction (below) can touch them
        alloc.incref(shared)
        if cow_src is not None:
            alloc.incref([cow_src])
        need = total - n_full
        fresh = alloc.alloc(need)
        if fresh is None and self._can_reclaim(need):
            # evict only when it actually covers the shortfall — a futile
            # eviction would wipe hot prefixes and still leave us blocked
            self.prefix_cache.evict(need - alloc.n_free)
            fresh = alloc.alloc(need)
        if fresh is None and (shared or cow_src is not None):
            # Prefix reuse itself can block admission: the pins above make
            # the matched run unevictable, and the leftover fresh-page need
            # may exceed what eviction can reclaim — forever, if no other
            # slot is in flight.  Degrade to a cold prefill: drop the pins
            # (the run becomes evictable), reclaim, take the budget fresh.
            alloc.decref(shared)
            if cow_src is not None:
                alloc.decref([cow_src])
            shared, cow_src, cached_len, n_full = [], None, 0, 0
            need = total
            if alloc.n_free < need and self._can_reclaim(need):
                self.prefix_cache.evict(need - alloc.n_free)
            fresh = alloc.alloc(need)
        if fresh is None:           # roll the pins back; the head blocks
            alloc.decref(shared)
            if cow_src is not None:
                alloc.decref([cow_src])
            return None
        # count stats on admission only — a blocked head-of-line request is
        # re-planned every tick and must not inflate the hit rate
        if self.prefix_cache is not None:
            for st in (self.stats, self.replica_stats):
                if st is not None:
                    st.prefix_lookups += 1
                    st.prefix_hits += cached_len > 0
        # fresh[0] sits at block-table index n_full: exactly where the COW
        # copy of the partial page belongs
        cow = (cow_src, fresh[0]) if cow_src is not None else None
        return Admission(slot=slot, req=req, pages=shared + fresh,
                         cached_len=cached_len, cow=cow)

    # ------------------------------------------------------------- events
    def on_cow_done(self, adm: Admission) -> None:
        self.allocator.decref([adm.cow[0]])

    def on_prefill_complete(self, adm: Admission) -> None:
        if self.prefix_cache is None:
            return
        prompt = effective_prompt(adm.req)
        n_full = len(prompt) // self.psz    # the partial tail stays private
        if n_full:
            self.prefix_cache.insert(prompt[:n_full * self.psz],
                                     adm.pages[:n_full])

    def on_finish(self, adm: Admission) -> None:
        if self.paged:
            self.allocator.decref(adm.pages)

    def on_preempt(self, adm: Admission, resident_tokens) -> None:
        """Salvage an evicted slot: donate its resident *full* pages to the
        prefix cache (resume finds them as a prefix hit — the victim's KV
        is reused, never recomputed), drop the slot's page refs, and
        re-queue the request.  The partial tail page is slot-private KV and
        is simply freed; resume re-prefills those few tokens."""
        if self.paged:
            if self.prefix_cache is not None:
                n_full = len(resident_tokens) // self.psz
                if n_full:
                    self.prefix_cache.insert(
                        resident_tokens[:n_full * self.psz],
                        adm.pages[:n_full])
            self.allocator.decref(adm.pages)
        self._requeue_preempted(adm.req)
        self.backlog_pages += self._req_pages(adm.req)
