"""Non-FCFS scheduling policies: priority with aging (optionally
preemptive) and deficit-round-robin fairness across client ids.

Both subclass ``FCFSScheduler`` purely for its paged planning machinery
(page budgeting, prefix lookup, COW, eviction, rollback) and override only
the queue-discipline hooks, so every allocator/prefix-cache invariant the
base maintains carries over unchanged.

The target workload is the paper's interactive wearable regime:
latency-critical sensor-triggered queries must not sit behind bulk
requests on a memory-constrained system — a QoS problem over scarce
on-chip state.  ``PriorityScheduler`` (with ``preemption=True``) bounds
high-priority TTFT by evicting low-priority slots; its aging term bounds
low-priority starvation.  ``FairScheduler`` instead divides service evenly
across clients regardless of who floods the queue.

Machine-checked clauses the policies must (and do) preserve
(scripts/check_static.py):

Invariant: resource conservation — every admission's pages/slab/cross
    refs are released through exactly one of ``on_finish`` /
    ``on_preempt``; a victim chosen by ``plan_preemptions`` is always a
    currently-active admission, so no release can double-fire
    (leak-freedom property tests cover fcfs/priority/fair at dp 1 and 2,
    slabs included).
Enforced-by: tests/test_scheduling.py::test_policies_conserve_requests_and_pages_randomized, analysis:refcount-leak

Invariant: output invariance — policies only reorder WORK, never change
    it: greedy outputs are token-identical across all policies and
    preemption points, and sampled outputs are schedule-invariant
    because RNG streams are per-request, not per-slot.
Enforced-by: tests/test_scheduling.py::test_greedy_token_identical_across_policies, tests/test_scheduling.py::test_sampled_outputs_schedule_invariant

Invariant: no ping-pong — preemption is gated on base (not aged)
    priority / a ``preempt_after``-quantum deficit gap, and a victim's
    aging credit resets on requeue, so a victim cannot immediately
    re-evict its evictor.
Enforced-by: tests/test_scheduling.py::test_preemption_resets_victim_aging_no_ping_pong

Invariant: free slots first — ``_admissible_without_eviction`` (pages,
    slabs and evictable caches included) suppresses preemption whenever
    a free slot could actually serve the starved request.
Enforced-by: tests/test_scheduling.py::test_fair_drr_preemption_respects_free_slots, tests/test_scheduling.py::test_preemption_fires_under_page_pressure_despite_free_slot
"""
from __future__ import annotations

import collections
from typing import List

from repro.serving.scheduler import Admission, FCFSScheduler


class PriorityScheduler(FCFSScheduler):
    """Highest-effective-priority admission with aging and preemption.

    Each request carries an integer ``priority`` (higher = more urgent;
    absent = 0).  Admission picks the pending request with the largest
    *effective* priority ``priority + aging_rate * rounds_waited`` (ties:
    submission order), so any positive ``aging_rate`` guarantees a
    low-priority request eventually outranks a continuous high-priority
    stream — no starvation.

    With ``preemption=True`` the scheduler also evicts running slots: when
    a pending request's *base* priority strictly exceeds a running
    request's base priority and no free slot (with enough free/evictable
    pages — a free slot whose pool is exhausted doesn't count) would serve
    it, the lowest-priority (most recently admitted) victim is preempted.
    Base priorities — not aged ones — gate preemption, so an aged
    low-priority request can win a *free* slot but never steal a busy one;
    and a preempted victim's aging credit resets, so it re-queues *below*
    the urgent request that displaced it instead of out-ranking it at the
    next admission and ping-ponging the slot every tick.
    """

    def __init__(self, *, aging_rate: float = 0.125, preemption: bool = False,
                 **kw):
        super().__init__(**kw)
        assert aging_rate >= 0, aging_rate
        self.aging_rate = aging_rate
        self.preemption = preemption
        self._seq = 0

    @staticmethod
    def _base(req) -> int:
        return getattr(req, "priority", 0)

    def _eff(self, req) -> float:
        return self._base(req) + \
            self.aging_rate * (self._round - req._sched_round)

    def _enqueue(self, req) -> None:
        req._sched_seq = self._seq
        self._seq += 1
        if not hasattr(req, "_sched_round"):
            req._sched_round = self._round
        self.queue.append(req)

    def _select_next(self):
        if not self.queue:
            return None
        # single linear pass (deque index access would make this O(n^2))
        best, _ = max(enumerate(self.queue),
                      key=lambda t: (self._eff(t[1]), -t[1]._sched_seq))
        req = self.queue[best]
        del self.queue[best]
        return req

    def _put_back(self, req) -> None:
        # selection re-sorts every round, so position is irrelevant; the
        # blocked request keeps outranking the queue until it fits
        self.queue.append(req)

    def _requeue_preempted(self, req) -> None:
        # the victim's aging credit resets: an aged-up victim must not
        # immediately out-rank the urgent request that displaced it (that
        # would ping-pong the slot every tick and starve both)
        req._sched_round = self._round
        self.queue.append(req)

    def plan_preemptions(self, active: List[Admission],
                         n_free: int) -> List[Admission]:
        if not self.preemption or not self.queue:
            return []
        pend = sorted(self.queue,
                      key=lambda r: (-self._eff(r), r._sched_seq))
        # victim order: lowest base priority first; among equals the most
        # recently admitted (least sunk prefill/decode work)
        pool = sorted(active, key=lambda a: (self._base(a.req), -a.seq))
        victims, spare = [], n_free
        for req in pend:
            if not pool and spare <= 0:
                break           # nothing left to grant, stop scanning
            if spare > 0 and self._admissible_without_eviction(req):
                spare -= 1      # a free slot serves it without eviction
            elif pool and self._base(pool[0].req) < self._base(req):
                victims.append(pool.pop(0))
            # else: this request can't preempt anyone, but one further down
            # the effective-priority order (e.g. fresh-high behind aged-low)
            # still might — keep scanning
        return victims


class FairScheduler(FCFSScheduler):
    """Deficit round-robin across client ids.

    Each request carries a ``client_id`` (absent = 0); requests queue FIFO
    per client.  Clients are visited round-robin; a visit tops the client's
    deficit counter up by ``quantum`` tokens, and the head request is
    admitted once the deficit covers its cost (prompt + max_new_tokens
    tokens — its whole KV footprint).  Service therefore converges to an
    equal token share per client: a client flooding the queue only
    lengthens its own backlog, and a client with large requests is charged
    proportionally more rounds per admission.

    With ``preemption=True`` DRR also preempts: plain DRR only rotates at
    admission time, so once a client's long-running requests occupy every
    slot, a newly arrived client waits out their full decode — unbounded
    starvation.  A backlogged client with no running slot (and no free
    slot that could serve it) instead accrues ``quantum`` deficit per tick,
    and once its deficit exceeds a running client's by
    ``preempt_after * quantum`` it evicts that client's most recently
    admitted slot (least sunk work; preempted KV is donated to the prefix
    cache, so nothing is recomputed on resume).  Admission then charges
    the starved client's cost as usual, dropping it back below the
    threshold — slots time-slice between contending clients at
    ``preempt_after``-quantum granularity instead of ping-ponging."""

    def __init__(self, *, quantum: int = 64, preemption: bool = False,
                 preempt_after: int = 4, **kw):
        super().__init__(**kw)
        assert quantum > 0, quantum
        assert preempt_after > 0, preempt_after
        self.quantum = quantum
        self.preemption = preemption
        self.preempt_after = preempt_after
        self._queues: dict = {}                       # client -> FIFO
        self._deficit: dict = {}
        self._rr: collections.deque = collections.deque()  # visit order

    @staticmethod
    def _client(req):
        return getattr(req, "client_id", 0)

    @staticmethod
    def _cost(req) -> int:
        return len(req.prompt) + req.max_new_tokens

    def _ensure(self, c) -> None:
        if c not in self._queues:
            self._queues[c] = collections.deque()
            self._deficit[c] = 0
            self._rr.append(c)

    def _enqueue(self, req) -> None:
        c = self._client(req)
        self._ensure(c)
        self._queues[c].append(req)

    def has_pending(self) -> bool:
        return any(self._queues.values())

    def pending_requests(self) -> List:
        return [r for q in self._queues.values() for r in q]

    def plan_preemptions(self, active: List[Admission],
                         n_free: int) -> List[Admission]:
        """Preemptive DRR (see class docstring): starved clients accrue
        deficit per tick and evict a running client once the gap exceeds
        ``preempt_after * quantum``."""
        if not self.preemption or not self.has_pending():
            return []
        running: dict = {}                 # client -> its active admissions
        for a in active:
            running.setdefault(self._client(a.req), []).append(a)
        victims, spare = [], n_free
        for c in sorted((c for c, q in self._queues.items()
                         if q and c not in running),
                        key=lambda c: -self._deficit.get(c, 0)):
            if spare > 0 and self._admissible_without_eviction(
                    self._queues[c][0]):
                spare -= 1                 # a free slot serves it; no ev.
                continue
            # starvation clock: only waiting clients that nothing (free
            # slot or running share) currently serves accrue credit
            self._deficit[c] += self.quantum
            # victim client: the most-served (lowest-deficit) running
            # client; within it, the most recent admission (least sunk
            # prefill/decode work, mirroring the priority policy)
            pool = sorted(
                ((self._deficit.get(rc, 0), rc) for rc, adms in
                 running.items() if adms),
                key=lambda t: t[0])
            if not pool:
                break
            vdef, vc = pool[0]
            if self._deficit[c] - vdef <= self.preempt_after * self.quantum:
                continue
            victim = max(running[vc], key=lambda a: a.seq)
            running[vc].remove(victim)
            victims.append(victim)
        return victims

    def _select_next(self):
        if not self.has_pending():
            return None
        # DRR: rotate through clients topping up deficits; terminates
        # because every full rotation credits each backlogged client
        while True:
            c = self._rr[0]
            q = self._queues[c]
            if not q:
                self._deficit[c] = 0    # classic DRR: idle clients reset
                self._rr.rotate(-1)
                continue
            if self._deficit[c] < self._cost(q[0]):
                self._deficit[c] += self.quantum
                self._rr.rotate(-1)
                continue
            req = q.popleft()
            self._deficit[c] -= self._cost(req)
            return req

    def _put_back(self, req) -> None:
        c = self._client(req)
        self._ensure(c)
        self._queues[c].appendleft(req)
        self._deficit[c] += self._cost(req)   # blocked, not served: refund

    def _requeue_preempted(self, req) -> None:
        # resumes at its client's head; the service it consumed stays spent
        c = self._client(req)
        self._ensure(c)
        self._queues[c].appendleft(req)

    def _clear_queue(self) -> None:
        # drain-time takeover: clients keep their deficit/rotation state
        # (an idle client's deficit resets at the next _select_next visit)
        for q in self._queues.values():
            q.clear()
