"""Request router for dp>1 serving: pick a replica for every request.

With the page pool sharded over the data axes, each replica serves out of
its own allocator / radix prefix cache / scheduler — state never crosses a
replica boundary.  That makes placement a real decision: a request whose
prefix is resident on replica 1 costs a full re-prefill anywhere else.
The router resolves it with the classic two-level rule:

1. **Prefix affinity** — route to the replica whose radix cache holds the
   longest prefix of the request's effective prompt (so shared system
   prompts, agent scaffolds, and preempted-and-resumed requests land where
   their KV already lives).  Because routing happens at submit, a burst of
   same-prefix requests would otherwise scatter before the first prefill
   ever populates a cache — so affinity also scores against the prompts
   *recently routed* to each replica (their KV is resident or about to
   be).  A match shorter than one page is noise (no page is reusable) and
   falls through.  Ties fall through to rule 2 among the tied replicas.
2. **Least loaded** — otherwise, route to the replica with the lowest page
   load: pages pinned by live slots (minus what eviction could reclaim)
   plus the page demand of its queued backlog.  Ties break on the lowest
   replica index (deterministic routing).

Routing happens once, at submit, and is sticky: preemption donates pages
to the *owning* replica's prefix cache (or stashes SSM state / releases
cross refs) and re-queues on the same replica's scheduler, so resume is
a local hit.  Affinity lookups take no page refs
(``RadixPrefixCache.lookup`` is read-only apart from its LRU clock), so
routing can never pin or leak pages.

Invariant: replica locality — the router is the ONLY component that
    sees all replicas at once.  Everything it routes to — allocator,
    slab allocator, prefix/cross caches, scheduler queues, preemption
    donations — is replica-local, and no page/slab id ever crosses a
    replica boundary; the dp tests assert per-replica leak-freedom
    independently.
Enforced-by: tests/test_dp_serving.py::test_dp2_drain_releases_both_replicas, tests/test_dp_serving.py::test_dp_policies_conserve_requests_and_pages

Invariant: routing pins nothing — affinity lookups take no page refs
    (``RadixPrefixCache.lookup`` is read-only apart from its LRU clock),
    so routing can never pin or leak pages.
Enforced-by: tests/test_dp_serving.py::test_router_prefix_affinity_wins, analysis:refcount-leak

Invariant: role-aware placement — under disaggregation (``roles`` set)
    fresh requests are admitted only on prefill-role replicas, and
    ``decode_placement`` hands finished page runs only to decode-role
    replicas; neither set is ever empty and a request crosses the
    boundary exactly once, via the page-transfer handoff.
Enforced-by: tests/test_page_transfer.py::test_disagg_dp2_matches_serial_dp1_greedy

Invariant: no placement onto a draining replica — once
    ``mark_draining`` names a replica, ``route`` and ``decode_placement``
    exclude it even when it momentarily reports the least page load (a
    drain empties it), so admissions racing an active ``scale_to`` land
    on survivors and are never migrated twice.
Enforced-by: tests/test_elastic_serving.py::test_admission_during_active_drain_avoids_draining_replica
"""
from __future__ import annotations

import collections
from typing import List, Optional

from repro.serving.prefix_cache import _common_len
from repro.serving.scheduler import effective_prompt


class Router:
    """Replica selector over parallel (scheduler, allocator, prefix-cache)
    triples; ``route`` returns a replica index."""

    def __init__(self, scheds: List, allocators: List,
                 prefix_caches: List[Optional[object]], page_size: int,
                 recent_window: int = 32, cross_caches=None,
                 roles: Optional[List[str]] = None):
        assert len(scheds) == len(allocators) == len(prefix_caches)
        self.scheds = scheds
        self.allocators = allocators
        self.prefix_caches = prefix_caches
        self.cross_caches = cross_caches or [None] * len(scheds)
        self.psz = page_size
        self.n_replicas = len(scheds)
        # disaggregation: per-replica roles ("prefill" / "decode"); None
        # means every replica serves both phases (the interleaved engine)
        self.roles = roles
        if roles is not None:
            assert len(roles) == len(scheds)
            self._admit_set = [r for r, ro in enumerate(roles)
                               if ro == "prefill"]
            assert self._admit_set and len(self._admit_set) < len(scheds)
        else:
            self._admit_set = list(range(self.n_replicas))
        self.affinity_routed = 0       # requests placed by prefix affinity
        self._draining: set = set()    # replicas mid-drain: never place here
        # prompts recently routed per replica: speculative affinity for
        # bursts whose shared prefix hasn't finished prefilling anywhere yet
        self._recent = [collections.deque(maxlen=recent_window)
                        for _ in range(self.n_replicas)]
        # frames digests recently routed per replica (enc-dec): same
        # speculative window for encodes that haven't landed yet
        self._recent_frames = [collections.deque(maxlen=recent_window)
                               for _ in range(self.n_replicas)]

    def page_load(self, r: int) -> int:
        """Replica r's page pressure: pages held that eviction cannot
        reclaim, plus the page demand of its queued backlog.  The backlog
        term is a running counter on the scheduler (O(1), so load doesn't
        rescan a growing queue per submit); the evictable-pages term walks
        the replica's radix tree, bounded by its cached-page count."""
        alloc = self.allocators[r]
        held = alloc.n_pages - alloc.n_reserved - alloc.n_free
        cache = self.prefix_caches[r]
        if cache is not None:
            held -= cache.n_evictable_pages
        return held + self.scheds[r].backlog_pages

    def affinity(self, req) -> List[int]:
        """Per-replica affinity score: the longest cached prefix of the
        request's effective prompt, or the longest common prefix with a
        recently routed prompt (resident-or-soon KV), whichever is
        longer.  Enc-dec requests additionally score a frames-digest hit
        on the replica's cross-KV cache (or its recently routed digests)
        as one full page — landing where the encode already ran turns a
        duplicate encode into a refcount share."""
        prompt = effective_prompt(req)
        toks = [int(t) for t in prompt]
        digest = None
        if getattr(req, "frames", None) is not None and \
                any(c is not None for c in self.cross_caches):
            from repro.serving.prefix_cache import CrossKVCache
            digest = CrossKVCache.digest(req.frames)
        out = []
        for r, (c, recent) in enumerate(zip(self.prefix_caches,
                                            self._recent, strict=True)):
            s = c.lookup(prompt)[0] if c is not None else 0
            for q in recent:
                if s >= len(toks):
                    break
                s = max(s, _common_len(q, toks))
            if digest is not None:
                xc = self.cross_caches[r]
                if (xc is not None and xc.has(digest)) or \
                        digest in self._recent_frames[r]:
                    s = max(s, self.psz)
            out.append(s)
        return out

    def mark_draining(self, r: int) -> None:
        """Exclude replica r from all future placement (an active
        ``scale_to`` is migrating its state away).  Rebuilding the router
        after the membership change clears the mark by construction."""
        self._draining.add(r)

    def route(self, req) -> int:
        """Pick a replica for ``req`` (no state change beyond LRU clocks);
        call ``commit`` once the replica's scheduler accepted it."""
        admit = [r for r in self._admit_set if r not in self._draining] \
            or self._admit_set
        if len(admit) == 1:
            return admit[0]
        hits = self.affinity(req)
        best = max(hits[r] for r in admit)
        if best >= self.psz:           # at least one full page reusable
            cand = [r for r in admit if hits[r] == best]
            self.affinity_routed += 1
        else:
            cand = list(admit)
        return min(cand, key=lambda rr: (self.page_load(rr), rr))

    def decode_placement(self, candidates: List[int]) -> int:
        """Pick the decode replica to receive a finished page run: least
        page load, index tiebreak (the same deterministic rule as cold
        routing).  ``candidates`` is the engine's per-tick set of
        decode-role replicas that still have a free slot."""
        cand = [r for r in candidates if r not in self._draining] \
            or list(candidates)
        return min(cand, key=lambda rr: (self.page_load(rr), rr))

    def commit(self, req, r: int) -> None:
        """Record a successful placement: ``req``'s prompt (and frames
        digest, for enc-dec) joins replica r's recent-routing window
        (rejected requests must not skew affinity, so this is separate
        from ``route``)."""
        self._recent[r].append([int(t) for t in effective_prompt(req)])
        if getattr(req, "frames", None) is not None:
            from repro.serving.prefix_cache import CrossKVCache
            self._recent_frames[r].append(CrossKVCache.digest(req.frames))
