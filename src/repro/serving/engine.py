"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

Slot-based continuous batching (vLLM-style, sized for the paper's
single-user edge regime up through pod-scale batches): a fixed decode batch
of B slots; every engine tick runs ONE fused decode step for all active
slots (the GEMV-batching the paper's autoregressive mode maps to on TPU).
EOS/length-complete slots free up and are refilled from the queue.

The engine is pure **mechanism**: it owns the device-side state (KV pool,
block tables, positions) and executes step functions.  All **policy** —
admission order, page budgeting, prefix reuse, eviction, preemption
victim choice — lives in ``serving.scheduler`` / ``serving.policies``
behind the ``Scheduler`` interface; the engine executes the scheduler's
``Admission`` decisions (and preemption verdicts) and reports lifecycle
events back.

Two cache disciplines, selected by the ``paged`` flag:

* **contiguous** (reference oracle): each slot owns an exact-length cache
  lane; admission prefills the whole prompt in one step (recompiling per
  prompt length) and splices the lane in.
* **paged**: K/V live in a fixed pool of fixed-size pages
  (``core.kvcache``); admission allocates the slot's block table up front
  (prompt + max_new_tokens worth — all-or-nothing, so requests queue
  instead of OOMing mid-flight), prefill advances one fixed-size chunk per
  tick interleaved with decode, and completion returns the pages to the
  pool.  One compiled (chunk, decode) pair serves every prompt-length mix.
  With ``prefix_cache=True`` a radix tree maps cached prompt prefixes to
  refcounted page runs: admission starts prefill at the first uncached
  token, copying partially-shared pages copy-on-write
  (``serving.prefix_cache``).

Sampling is schedule-invariant: every request draws from its own seeded
RNG stream (``Request.rng``), so non-greedy outputs do not depend on
admission order, batch composition, or preemption points.

The engine is mesh-agnostic: it drives whatever step functions
``core.steps`` built — 1-device CPU smoke or a full pod.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import SCRATCH_PAGE, PageAllocator
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.sampler import SamplerConfig, sample_from_logits
from repro.serving.scheduler import (Admission, FCFSScheduler, Scheduler,
                                     effective_prompt)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    priority: int = 0                  # higher = more urgent (policies.py)
    client_id: int = 0                 # fairness accounting key (policies.py)
    seed: Optional[int] = None         # sampling stream seed (default: rid)
    rng: Optional[np.random.RandomState] = None   # set at submit
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


@dataclass
class EngineStats:
    ticks: int = 0
    prefills: int = 0
    decoded_tokens: int = 0
    prefill_tokens_skipped: int = 0    # prompt tokens served from the cache
    cow_copies: int = 0
    preemptions: int = 0
    prefix_lookups: int = 0
    prefix_hits: int = 0
    tpot_s: list = field(default_factory=list)
    request_ttft: dict = field(default_factory=dict)   # rid -> seconds

    @property
    def ttft_s(self) -> list:
        """TTFT samples in first-token order (derived per request)."""
        return list(self.request_ttft.values())

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_lookups \
            if self.prefix_lookups else 0.0


class ServingEngine:
    def __init__(self, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                 params, prefill_fn, decode_fn, eos_id: int = 1,
                 sampler: Optional[SamplerConfig] = None, *,
                 paged: bool = False, page_size: int = 16,
                 n_pages: int = 0, prefill_chunk: int = 0,
                 prefix_cache: bool = False, scheduler=None,
                 rng_seed: int = 0):
        from repro.core import steps as _steps
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.B, self.S = batch_slots, seq_budget
        self.params = params
        self.prefill_fn = prefill_fn   # jitted: batch=1 lane / paged chunk
        self.decode_fn = decode_fn     # jitted, batch=B
        self.eos = eos_id
        self.sampler = sampler or SamplerConfig()
        self.admissions: List[Optional[Admission]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.paged = paged
        self.stats = EngineStats()
        self.allocator = None
        self.prefix_cache = None
        if paged:
            assert seq_budget % page_size == 0, (seq_budget, page_size)
            assert prefill_chunk > 0 and seq_budget % prefill_chunk == 0, \
                (seq_budget, prefill_chunk)
            self.page_size = page_size
            self.chunk = prefill_chunk
            self.n_max_pages = seq_budget // page_size
            self.allocator = PageAllocator(n_pages)
            if prefix_cache:
                self.prefix_cache = RadixPrefixCache(self.allocator,
                                                     page_size)
            self.slot_state: List[Optional[str]] = [None] * batch_slots
            self.prefill_done = np.zeros(batch_slots, np.int32)
            self.cache = _steps.zero_paged_cache_for(cfg, plan, mesh,
                                                     n_pages, page_size)
            copy_fn, _, _ = _steps.make_page_copy_step(cfg, plan, mesh,
                                                       n_pages, page_size)
            self.copy_fn = jax.jit(copy_fn)
        else:
            assert not prefix_cache, "prefix cache requires the paged engine"
            self.cache = _steps.zero_cache_for(cfg, plan, mesh, batch_slots,
                                               seq_budget)
        # ``scheduler`` is either a ready instance or a factory (a Scheduler
        # subclass / functools.partial): factories receive the engine-owned
        # shared state, so callers can pass e.g. ``PriorityScheduler``
        # without pre-building the allocator themselves.
        sched = scheduler or FCFSScheduler
        if not isinstance(sched, Scheduler):
            sched = sched(seq_budget=seq_budget, allocator=self.allocator,
                          page_size=page_size if paged else 0,
                          prefix_cache=self.prefix_cache, stats=self.stats)
        self.sched = sched
        self._rids: set = set()
        self.rng_seed = rng_seed

    @classmethod
    def build_paged(cls, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                    params, *, page_size: int = 16, n_pages: int = 0,
                    prefill_chunk: int = 16, eos_id: int = 1,
                    sampler: Optional[SamplerConfig] = None,
                    prefix_cache: bool = False, scheduler=None,
                    rng_seed: int = 0):
        """Construct a paged engine, compiling its (chunk, decode) pair.

        ``n_pages`` defaults to full occupancy (every slot at budget) plus
        the scratch page; pass something smaller to exercise admission
        control under memory pressure."""
        from repro.core import steps as _steps
        n_max = seq_budget // page_size
        n_pages = n_pages or batch_slots * n_max + 1
        dec, _, _ = _steps.make_paged_decode_step(
            cfg, plan, mesh, batch_slots, n_pages, page_size, n_max)
        chunk_fn, _, _ = _steps.make_prefill_chunk_step(
            cfg, plan, mesh, prefill_chunk, n_pages, page_size, n_max)
        return cls(cfg, plan, mesh, batch_slots, seq_budget, params,
                   jax.jit(chunk_fn), jax.jit(dec), eos_id=eos_id,
                   sampler=sampler, paged=True, page_size=page_size,
                   n_pages=n_pages, prefill_chunk=prefill_chunk,
                   prefix_cache=prefix_cache, scheduler=scheduler,
                   rng_seed=rng_seed)

    # ------------------------------------------------------------------ API
    @property
    def slots(self) -> List[Optional[Request]]:
        """Requests in flight, by slot (derived from the admissions)."""
        return [a.req if a is not None else None for a in self.admissions]

    def submit(self, req: Request):
        if req.rid in self._rids:     # rids key the per-request stats
            raise RuntimeError(f"duplicate request id {req.rid}")
        self.sched.submit(req)        # raises on infeasible requests
        self._rids.add(req.rid)
        if req.rng is None:
            # one private stream per request: sampled outputs depend only on
            # (engine seed, request seed), never on scheduling
            seed = req.seed if req.seed is not None else req.rid
            req.rng = np.random.RandomState([self.rng_seed, seed])
        req.t_submit = time.monotonic()

    def run(self, max_ticks: int = 10_000):
        while (self.sched.has_pending() or
               any(a is not None for a in self.admissions)) and \
                self.stats.ticks < max_ticks:
            self.tick()
        return self.stats

    def drain(self) -> int:
        """Abort every in-flight admission (e.g. after ``run`` exhausted
        ``max_ticks``): each is routed through ``sched.on_finish`` so its
        pages return to the pool — no leaked refcounts.  Aborted requests
        keep ``done=False``; queued-but-never-admitted requests hold no
        resources and stay queued.  -> number of slots drained."""
        n = 0
        for b in range(self.B):
            adm = self.admissions[b]
            if adm is None:
                continue
            self.sched.on_finish(adm)
            self._clear_slot(b)
            n += 1
        return n

    def preempt(self, b: int):
        """Evict slot ``b`` mid-flight.  The slot's progress needs no
        explicit snapshot: emitted tokens already live on
        ``req.out_tokens``, and resume re-admits over the *effective
        prompt* (prompt + emitted tokens), so ``pos``/``prefill_done``
        are reconstructed by ordinary admission.  The resident full pages
        are donated to the prefix cache via ``sched.on_preempt`` — resume
        finds them as a prefix hit and the victim's KV is reused, not
        recomputed (only the partial tail page is re-prefilled)."""
        assert self.paged, "preemption requires the paged engine"
        adm = self.admissions[b]
        assert adm is not None, f"slot {b} is idle"
        n = int(self.prefill_done[b]) if self.slot_state[b] == "prefill" \
            else int(self.pos[b])
        resident = effective_prompt(adm.req)[:n]
        self.sched.on_preempt(adm, resident)
        self._clear_slot(b)
        self.stats.preemptions += 1

    def _clear_slot(self, b: int):
        self.admissions[b] = None
        self.pos[b] = 0
        self.last_token[b] = 0
        if self.paged:
            self.slot_state[b] = None
            self.prefill_done[b] = 0

    # ----------------------------------------------------------------- tick
    def tick(self):
        if self.paged:
            return self._tick_paged()
        self._admit()
        if not any(self.slots):
            return
        with self.mesh:
            logits, self.cache = self.decode_fn(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(self.pos))
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        now = time.monotonic()
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[b] += 1        # the decode step wrote last_token's KV
            self._emit(b, req, self._sample_row(logits, b, req), now)
        self.stats.ticks += 1

    def _sample_row(self, logits: np.ndarray, b: int, req: Request) -> int:
        """Sample row b from the request's own stream (schedule-invariant)."""
        return int(sample_from_logits(logits[b:b + 1], self.sampler,
                                      self.cfg.vocab_size, req.rng)[0])

    def _emit(self, b: int, req: Request, tok: int, now: float):
        """Record one generated token for slot b; retire the slot when done.

        The caller owns ``pos``: decode ticks advance it past the KV they
        just wrote before emitting; prefill completion leaves it at the
        prompt length (the sampled token's KV is written by the next decode
        tick)."""
        if not req.out_tokens:
            req.t_first_token = now
            self.stats.request_ttft[req.rid] = now - req.t_submit
        req.out_tokens.append(tok)
        self.last_token[b] = tok
        self.stats.decoded_tokens += 1
        if tok == self.eos or len(req.out_tokens) >= req.max_new_tokens \
                or self.pos[b] >= self.S - 1:
            req.done = True
            req.t_done = now
            self.stats.tpot_s.append(
                (now - req.t_first_token) /
                max(len(req.out_tokens) - 1, 1))
            self.sched.on_finish(self.admissions[b])
            self._clear_slot(b)

    def _admit(self):
        free = [b for b in range(self.B) if self.admissions[b] is None]
        for adm in self.sched.plan(free):
            self.admissions[adm.slot] = adm
            self._prefill_into(adm.slot, adm.req)

    def _prefill_into(self, b: int, req: Request):
        """Prefill a single request and splice its cache into lane b."""
        from repro.core import steps as _steps
        S = len(req.prompt)
        assert S < self.S
        prompt = np.zeros((1, self.S), np.int32)
        prompt[0, :S] = req.prompt
        lane_cache = _steps.zero_cache_for(self.cfg, self.plan, self.mesh, 1,
                                           self.S)
        with self.mesh:
            logits, lane_cache = self.prefill_fn(
                self.params, jnp.asarray(prompt[:, :S]), lane_cache)
        self.stats.prefills += 1
        # splice lane 0 of lane_cache into slot b of the engine cache
        self.cache = _splice_cache(self.cache, lane_cache, b)
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        # the token sampled from the prompt's final logits IS the first
        # generated token: emit it (TTFT lands at prefill completion, and
        # max_new_tokens counts it)
        self.pos[b] = S
        self._emit(b, req, self._sample_row(logits, 0, req),
                   time.monotonic())

    # ------------------------------------------------------------ paged tick
    def _tick_paged(self):
        active = [a for a in self.admissions if a is not None]
        for adm in self.sched.plan_preemptions(active,
                                               self.B - len(active)):
            self.preempt(adm.slot)
        self._admit_paged()
        for b in range(self.B):
            if self.admissions[b] is not None and \
                    self.slot_state[b] == "prefill":
                self._prefill_chunk(b)
        self._decode_tick_paged()
        self.stats.ticks += 1

    def _admit_paged(self):
        """Execute this tick's admissions from the scheduler."""
        free = [b for b in range(self.B) if self.admissions[b] is None]
        for adm in self.sched.plan(free):
            b = adm.slot
            self.admissions[b] = adm
            self.slot_state[b] = "prefill"
            if adm.cow is not None:
                src, dst = adm.cow
                with self.mesh:
                    self.cache = self.copy_fn(self.cache,
                                              jnp.asarray(src, jnp.int32),
                                              jnp.asarray(dst, jnp.int32))
                self.sched.on_cow_done(adm)
                self.stats.cow_copies += 1
            # prefix-cached tokens are already resident: prefill resumes at
            # the first uncached position (for a preempted request this is
            # its donated progress — reused, not recomputed)
            self.prefill_done[b] = adm.cached_len
            self.stats.prefill_tokens_skipped += adm.cached_len
            self.pos[b] = 0
            self.last_token[b] = 0

    def _bt_row(self, b: int) -> np.ndarray:
        row = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
        adm = self.admissions[b]
        if adm is not None and adm.pages is not None:
            row[:len(adm.pages)] = adm.pages
        return row

    def _prefill_chunk(self, b: int):
        """Advance slot b's prefill by one fixed-size chunk."""
        req = self.admissions[b].req
        prompt = effective_prompt(req)     # includes resumed output tokens
        L, C = len(prompt), self.chunk
        c0 = int(self.prefill_done[b])
        chunk_toks = np.zeros((1, C), np.int32)
        n = min(C, L - c0)
        chunk_toks[0, :n] = prompt[c0:c0 + n]
        last_idx = min(L - 1 - c0, C - 1)
        with self.mesh:
            logits, self.cache = self.prefill_fn(
                self.params, self.cache, jnp.asarray(chunk_toks),
                jnp.asarray(c0, jnp.int32), jnp.asarray(last_idx, jnp.int32),
                jnp.asarray(self._bt_row(b)[None]))
        self.prefill_done[b] = c0 + C
        if c0 + C >= L:                  # prompt fully resident
            self.stats.prefills += 1
            self.sched.on_prefill_complete(self.admissions[b])
            logits = np.asarray(jax.device_get(logits)).astype(np.float32)
            # emit the token sampled from the final prompt position — the
            # first generated token (or, on resume, the next one: resumed
            # requests re-enter here with out_tokens non-empty, so TTFT is
            # not re-recorded)
            self.pos[b] = L
            self._emit(b, req, self._sample_row(logits, 0, req),
                       time.monotonic())
            if self.admissions[b] is not None:   # not retired by that token
                self.slot_state[b] = "decode"

    def _decode_tick_paged(self):
        active = [b for b in range(self.B)
                  if self.admissions[b] is not None
                  and self.slot_state[b] == "decode"]
        if not active:
            return
        # idle / prefilling lanes ride along pointed at the scratch page
        bt = np.stack([self._bt_row(b) if b in active else
                       np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
                       for b in range(self.B)])
        pos = np.where(np.isin(np.arange(self.B), active), self.pos, 0)
        with self.mesh:
            logits, self.cache = self.decode_fn(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(pos.astype(np.int32)), jnp.asarray(bt))
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        now = time.monotonic()
        for b in active:
            req = self.admissions[b].req
            self.pos[b] += 1        # the decode step wrote last_token's KV
            self._emit(b, req, self._sample_row(logits, b, req), now)


def _splice_cache(big, lane, b):
    def leaf(big_l, lane_l):
        return big_l.at[:, b:b + 1].set(lane_l[:, 0:1]) \
            if big_l.ndim >= 2 else big_l
    return jax.tree_util.tree_map(leaf, big, lane)
