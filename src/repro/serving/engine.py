"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

Slot-based scheduler (vLLM-style, sized for the paper's single-user edge
regime up through pod-scale batches): a fixed decode batch of B slots; every
engine tick runs ONE fused decode step for all active slots (the
GEMV-batching the paper's autoregressive mode maps to on TPU).
EOS/length-complete slots free up and are refilled from the queue.

Two cache disciplines, selected by the ``paged`` flag:

* **contiguous** (reference oracle): each slot owns an exact-length cache
  lane; admission prefills the whole prompt in one step (recompiling per
  prompt length) and splices the lane in.
* **paged**: K/V live in a fixed pool of fixed-size pages
  (``core.kvcache``); admission allocates the slot's block table up front
  (prompt + max_new_tokens worth — all-or-nothing, so requests queue
  instead of OOMing mid-flight), prefill advances one fixed-size chunk per
  tick interleaved with decode, and completion returns the pages to the
  pool.  One compiled (chunk, decode) pair serves every prompt-length mix.

The engine is mesh-agnostic: it drives whatever step functions
``core.steps`` built — 1-device CPU smoke or a full pod.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import SCRATCH_PAGE, PageAllocator, pages_needed
from repro.serving.sampler import SamplerConfig, sample_from_logits


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


@dataclass
class EngineStats:
    ticks: int = 0
    prefills: int = 0
    decoded_tokens: int = 0
    ttft_s: list = field(default_factory=list)
    tpot_s: list = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                 params, prefill_fn, decode_fn, eos_id: int = 1,
                 sampler: Optional[SamplerConfig] = None, *,
                 paged: bool = False, page_size: int = 16,
                 n_pages: int = 0, prefill_chunk: int = 0):
        from repro.core import steps as _steps
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.B, self.S = batch_slots, seq_budget
        self.params = params
        self.prefill_fn = prefill_fn   # jitted: batch=1 lane / paged chunk
        self.decode_fn = decode_fn     # jitted, batch=B
        self.eos = eos_id
        self.sampler = sampler or SamplerConfig()
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.paged = paged
        if paged:
            assert seq_budget % page_size == 0, (seq_budget, page_size)
            assert prefill_chunk > 0 and seq_budget % prefill_chunk == 0, \
                (seq_budget, prefill_chunk)
            self.page_size = page_size
            self.chunk = prefill_chunk
            self.n_max_pages = seq_budget // page_size
            self.allocator = PageAllocator(n_pages)
            self.slot_pages: List[Optional[list]] = [None] * batch_slots
            self.slot_state: List[Optional[str]] = [None] * batch_slots
            self.prefill_done = np.zeros(batch_slots, np.int32)
            self.cache = _steps.zero_paged_cache_for(cfg, plan, mesh,
                                                     n_pages, page_size)
        else:
            self.cache = _steps.zero_cache_for(cfg, plan, mesh, batch_slots,
                                               seq_budget)
        self.stats = EngineStats()
        self._rng = np.random.RandomState(0)

    @classmethod
    def build_paged(cls, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                    params, *, page_size: int = 16, n_pages: int = 0,
                    prefill_chunk: int = 16, eos_id: int = 1,
                    sampler: Optional[SamplerConfig] = None):
        """Construct a paged engine, compiling its (chunk, decode) pair.

        ``n_pages`` defaults to full occupancy (every slot at budget) plus
        the scratch page; pass something smaller to exercise admission
        control under memory pressure."""
        from repro.core import steps as _steps
        n_max = seq_budget // page_size
        n_pages = n_pages or batch_slots * n_max + 1
        dec, _, _ = _steps.make_paged_decode_step(
            cfg, plan, mesh, batch_slots, n_pages, page_size, n_max)
        chunk_fn, _, _ = _steps.make_prefill_chunk_step(
            cfg, plan, mesh, prefill_chunk, n_pages, page_size, n_max)
        return cls(cfg, plan, mesh, batch_slots, seq_budget, params,
                   jax.jit(chunk_fn), jax.jit(dec), eos_id=eos_id,
                   sampler=sampler, paged=True, page_size=page_size,
                   n_pages=n_pages, prefill_chunk=prefill_chunk)

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        if self.paged:
            assert len(req.prompt) + req.max_new_tokens <= self.S, \
                "request exceeds the sequence budget"
            need = pages_needed(len(req.prompt) + req.max_new_tokens,
                                self.page_size)
            usable = self.allocator.n_pages - self.allocator.n_reserved
            if need > usable:       # reject now, not mid-run at admission
                raise RuntimeError(
                    f"request {req.rid} needs {need} pages; the pool only "
                    f"has {usable} usable")
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000):
        while (self.queue or any(self.slots)) and \
                self.stats.ticks < max_ticks:
            self.tick()
        return self.stats

    # ----------------------------------------------------------------- tick
    def tick(self):
        if self.paged:
            return self._tick_paged()
        self._admit()
        if not any(self.slots):
            return
        with self.mesh:
            logits, self.cache = self.decode_fn(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(self.pos))
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        toks = sample_from_logits(logits, self.sampler,
                                  self.cfg.vocab_size, self._rng)
        now = time.monotonic()
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            self._emit(b, req, int(toks[b]), now)
        self.stats.ticks += 1

    def _emit(self, b: int, req: Request, tok: int, now: float):
        """Record one decoded token for slot b; retire the slot when done."""
        if not req.out_tokens:
            req.t_first_token = now
            self.stats.ttft_s.append(now - req.t_submit)
        req.out_tokens.append(tok)
        self.pos[b] += 1
        self.last_token[b] = tok
        self.stats.decoded_tokens += 1
        if tok == self.eos or len(req.out_tokens) >= req.max_new_tokens \
                or self.pos[b] >= self.S - 1:
            req.done = True
            req.t_done = now
            self.stats.tpot_s.append(
                (now - req.t_first_token) /
                max(len(req.out_tokens) - 1, 1))
            self.slots[b] = None
            if self.paged:
                self.allocator.free(self.slot_pages[b])
                self.slot_pages[b] = None
                self.slot_state[b] = None

    def _admit(self):
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(b, req)
                self.slots[b] = req

    def _prefill_into(self, b: int, req: Request):
        """Prefill a single request and splice its cache into lane b."""
        from repro.core import steps as _steps
        S = len(req.prompt)
        assert S < self.S
        prompt = np.zeros((1, self.S), np.int32)
        prompt[0, :S] = req.prompt
        lane_cache = _steps.zero_cache_for(self.cfg, self.plan, self.mesh, 1,
                                           self.S)
        with self.mesh:
            logits, lane_cache = self.prefill_fn(
                self.params, jnp.asarray(prompt[:, :S]), lane_cache)
        self.stats.prefills += 1
        # splice lane 0 of lane_cache into slot b of the engine cache
        self.cache = _splice_cache(self.cache, lane_cache, b)
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        tok = sample_from_logits(logits, self.sampler, self.cfg.vocab_size,
                                 self._rng)[0]
        self.pos[b] = S
        self.last_token[b] = int(tok)
        req.out_tokens = []

    # ------------------------------------------------------------ paged tick
    def _tick_paged(self):
        self._admit_paged()
        for b in range(self.B):
            if self.slots[b] is not None and self.slot_state[b] == "prefill":
                self._prefill_chunk(b)
        self._decode_tick_paged()
        self.stats.ticks += 1

    def _admit_paged(self):
        """Fill free slots from the queue, page allocation permitting.

        All-or-nothing FIFO admission: the head request either gets its full
        page budget (prompt + max_new_tokens) or the queue waits for slot
        completions to reclaim pages."""
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = pages_needed(len(req.prompt) + req.max_new_tokens,
                                self.page_size)
            pages = self.allocator.alloc(need)
            if pages is None:        # impossible requests rejected at submit
                break                # feasible: wait for reclamation
            self.queue.popleft()
            self.slots[b] = req
            self.slot_pages[b] = pages
            self.slot_state[b] = "prefill"
            self.prefill_done[b] = 0
            self.pos[b] = 0
            self.last_token[b] = 0

    def _bt_row(self, b: int) -> np.ndarray:
        row = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
        pages = self.slot_pages[b]
        if pages is not None:
            row[:len(pages)] = pages
        return row

    def _prefill_chunk(self, b: int):
        """Advance slot b's prefill by one fixed-size chunk."""
        req = self.slots[b]
        L, C = len(req.prompt), self.chunk
        c0 = int(self.prefill_done[b])
        chunk_toks = np.zeros((1, C), np.int32)
        n = min(C, L - c0)
        chunk_toks[0, :n] = req.prompt[c0:c0 + n]
        last_idx = min(L - 1 - c0, C - 1)
        with self.mesh:
            logits, self.cache = self.prefill_fn(
                self.params, self.cache, jnp.asarray(chunk_toks),
                jnp.asarray(c0, jnp.int32), jnp.asarray(last_idx, jnp.int32),
                jnp.asarray(self._bt_row(b)[None]))
        self.prefill_done[b] = c0 + C
        if c0 + C >= L:                  # prompt fully resident
            self.stats.prefills += 1
            logits = np.asarray(jax.device_get(logits)).astype(np.float32)
            tok = sample_from_logits(logits, self.sampler,
                                     self.cfg.vocab_size, self._rng)[0]
            self.pos[b] = L
            self.last_token[b] = int(tok)
            req.out_tokens = []
            self.slot_state[b] = "decode"

    def _decode_tick_paged(self):
        active = [b for b in range(self.B)
                  if self.slots[b] is not None
                  and self.slot_state[b] == "decode"]
        if not active:
            return
        # idle / prefilling lanes ride along pointed at the scratch page
        bt = np.stack([self._bt_row(b) if b in active else
                       np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
                       for b in range(self.B)])
        pos = np.where(np.isin(np.arange(self.B), active), self.pos, 0)
        with self.mesh:
            logits, self.cache = self.decode_fn(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(pos.astype(np.int32)), jnp.asarray(bt))
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        toks = sample_from_logits(logits, self.sampler,
                                  self.cfg.vocab_size, self._rng)
        now = time.monotonic()
        for b in active:
            self._emit(b, self.slots[b], int(toks[b]), now)


def _splice_cache(big, lane, b):
    def leaf(big_l, lane_l):
        return big_l.at[:, b:b + 1].set(lane_l[:, 0:1]) \
            if big_l.ndim >= 2 else big_l
    return jax.tree_util.tree_map(leaf, big, lane)
