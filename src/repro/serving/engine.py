"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

Slot-based scheduler (vLLM-style, sized for the paper's single-user edge
regime up through pod-scale batches): a fixed decode batch of B slots; new
requests prefill into a free slot cache lane (production note: bucket prompt
lengths to bound recompilation; exact-length prefill is used here); every
engine tick runs ONE
fused decode step for all active slots (the GEMV-batching the paper's
autoregressive mode maps to on TPU).  EOS/length-complete slots free up and
are refilled from the queue.

The engine is mesh-agnostic: it drives whatever (prefill_fn, decode_fn)
pair ``core.steps`` built — 1-device CPU smoke or a full pod.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import SamplerConfig, sample_from_logits


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


@dataclass
class EngineStats:
    ticks: int = 0
    prefills: int = 0
    decoded_tokens: int = 0
    ttft_s: list = field(default_factory=list)
    tpot_s: list = field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                 params, prefill_fn, decode_fn, eos_id: int = 1,
                 sampler: Optional[SamplerConfig] = None):
        from repro.core import steps as _steps
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.B, self.S = batch_slots, seq_budget
        self.params = params
        self.prefill_fn = prefill_fn        # jitted, batch=1 lane
        self.decode_fn = decode_fn          # jitted, batch=B
        self.eos = eos_id
        self.sampler = sampler or SamplerConfig()
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.cache = _steps.zero_cache_for(cfg, plan, mesh, batch_slots,
                                           seq_budget)
        self.stats = EngineStats()
        self._rng = np.random.RandomState(0)

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000):
        while (self.queue or any(self.slots)) and \
                self.stats.ticks < max_ticks:
            self.tick()
        return self.stats

    # ----------------------------------------------------------------- tick
    def tick(self):
        self._admit()
        if not any(self.slots):
            return
        with self.mesh:
            logits, self.cache = self.decode_fn(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(self.pos))
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        toks = sample_from_logits(logits, self.sampler,
                                  self.cfg.vocab_size, self._rng)
        now = time.monotonic()
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(toks[b])
            if not req.out_tokens:
                req.t_first_token = now
                self.stats.ttft_s.append(now - req.t_submit)
            req.out_tokens.append(tok)
            self.pos[b] += 1
            self.last_token[b] = tok
            self.stats.decoded_tokens += 1
            if tok == self.eos or len(req.out_tokens) >= req.max_new_tokens \
                    or self.pos[b] >= self.S - 1:
                req.done = True
                req.t_done = now
                self.stats.tpot_s.append(
                    (now - req.t_first_token) /
                    max(len(req.out_tokens) - 1, 1))
                self.slots[b] = None
        self.stats.ticks += 1

    def _admit(self):
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(b, req)
                self.slots[b] = req

    def _prefill_into(self, b: int, req: Request):
        """Prefill a single request and splice its cache into lane b."""
        from repro.core import steps as _steps
        S = len(req.prompt)
        assert S < self.S
        prompt = np.zeros((1, self.S), np.int32)
        prompt[0, :S] = req.prompt
        lane_cache = _steps.zero_cache_for(self.cfg, self.plan, self.mesh, 1,
                                           self.S)
        with self.mesh:
            logits, lane_cache = self.prefill_fn(
                self.params, jnp.asarray(prompt[:, :S]), lane_cache)
        self.stats.prefills += 1
        # splice lane 0 of lane_cache into slot b of the engine cache
        self.cache = _splice_cache(self.cache, lane_cache, b)
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        tok = sample_from_logits(logits, self.sampler, self.cfg.vocab_size,
                                 self._rng)[0]
        self.pos[b] = S
        self.last_token[b] = int(tok)
        req.out_tokens = []


def _splice_cache(big, lane, b):
    def leaf(big_l, lane_l):
        if big_l.ndim >= 2 and big_l.shape[1] == lane_l.shape[1] and \
                lane_l.shape[0] == big_l.shape[0]:
            pass
        return big_l.at[:, b:b + 1].set(lane_l[:, 0:1]) \
            if big_l.ndim >= 2 else big_l
    return jax.tree_util.tree_map(leaf, big, lane)
