"""Batched serving engine: continuous-batching-lite over prefill/decode steps.

Slot-based continuous batching (vLLM-style, sized for the paper's
single-user edge regime up through pod-scale batches): a fixed decode batch
of B slots; every engine tick runs ONE fused decode step for all active
slots (the GEMV-batching the paper's autoregressive mode maps to on TPU).
EOS/length-complete slots free up and are refilled from the queue.

The engine is pure **mechanism**: it owns the device-side state (KV pool,
block tables, positions) and executes step functions.  All **policy** —
admission order, page budgeting, prefix reuse, eviction, preemption
victim choice — lives in ``serving.scheduler`` / ``serving.policies``
behind the ``Scheduler`` interface; the engine executes the scheduler's
``Admission`` decisions (and preemption verdicts) and reports lifecycle
events back.

Two cache disciplines, selected by the ``paged`` flag:

* **contiguous** (reference oracle): each slot owns an exact-length cache
  lane; admission prefills the whole prompt in one step (recompiling per
  prompt length) and splices the lane in.
* **paged**: K/V live in fixed pools of fixed-size pages
  (``core.kvcache``); admission allocates the slot's block table up front
  (prompt + max_new_tokens worth — all-or-nothing, so requests queue
  instead of OOMing mid-flight), prefill advances one fixed-size chunk per
  tick interleaved with decode, and completion returns the pages to the
  pool.  One compiled (chunk, decode) pair serves every prompt-length mix.
  With ``prefix_cache=True`` a radix tree maps cached prompt prefixes to
  refcounted page runs: admission starts prefill at the first uncached
  token, copying partially-shared pages copy-on-write
  (``serving.prefix_cache``).

**Architecture coverage** (paged engine): beyond attention-only decoders,

* *SSM/hybrid* archs get one recurrent-state **slab** per admitted request
  (``SlabAllocator``; slab 0 is scratch, mirroring page 0): SSM layers
  read/write their slab by slot-relative slab id while hybrid attention
  heads keep reading KV through block tables.  Slabs are zeroed at
  admission.  Preemption CHECKPOINTS the slot — recurrent state cannot be
  re-derived from donated pages — into a host-side stash (slab + resident
  KV page payloads); resume re-admits cold, restores the stash into the
  freshly allocated slab/pages, and continues exactly where it stopped
  (``stats.slab_restores``).  The token-id radix prefix cache is
  unavailable here (and raises a precise error): an SSM layer's state for
  a shared prefix is not addressable by pages.
* *Enc-dec* archs run ``encode`` once at admission: a compiled cross-KV
  write step projects the encoder memory's K/V into read-only **cross
  pages** that decode/prefill read through a second block table.  Requests
  whose frames digest matches share one encode's pages by refcount
  (``CrossKVCache``) — no copy-on-write, since cross pages are immutable
  after the write.  The token-id prefix cache is likewise unavailable
  (self-KV depends on the frames through cross-attention, so equal token
  prefixes do NOT imply equal KV — sharing would be silently wrong).

Admission budgets pages + slabs + cross pages JOINTLY (all-or-nothing),
and ``drain()`` leak-freedom extends to all three: after every admission
retires, each replica's pages are free or cache-held and its slabs free.

**Data parallelism** (``dp`` — paged engine only): the engine runs ``dp``
*replicas*, each with its own ``batch_slots`` slots and — crucially — its
own replica-local ``PageAllocator``, ``RadixPrefixCache`` and
``Scheduler`` instance, so page refcounts, prefix pins, eviction and
preemption donations never cross a replica boundary.  The page pools carry
a leading replica dim sharded over ``plan.dp_axes`` (``core.kvcache``), so
on a dp mesh each data shard stores only its replica's pages — the
paper's stationary-local-memory discipline.  A ``serving.router.Router``
assigns every submitted request to a replica (longest-prefix-hit affinity
first, then least page load) and the single ``run()`` loop drives all
replicas' slots through one compiled decode step per tick; per-replica
counters land in ``EngineStats.replicas``.  ``dp=1`` (the default) is the
old single-pool engine, token-for-token.

**Speculative decoding** (``speculative=k`` — paged, attention-only archs):
each tick a self-drafting source (``serving.prefix_cache.PromptLookupDraft``
— prompt-lookup n-grams over the slot's own context and the radix cache's
token paths; no second model) proposes up to k tokens per slot, and ONE
fused verify step (``core.steps.make_verify_step``) scores all k+1
positions, writing their KV through the block table.  Rejection sampling
(``serving.sampler.speculative_sample``) emits 1..k+1 tokens per slot,
token-identical to the one-token path: row i is sampled exactly as the
one-token path would, and drafting past row i survives only while the
sample agrees with the draft.  Rejected-draft KV needs no device-side
rollback — per-query validity masks positions past ``pos`` and the next
step overwrites position ``pos`` before any read, so the host-side
``pos``/block-table bookkeeping IS the trim.  Admission budgets +k tokens
of page headroom all-or-nothing (``Admission.spec``; denied speculation
still admits, the slot just decodes one token per tick), and a slot whose
drafts keep getting rejected stops drafting and returns the headroom pages
(``Scheduler.on_spec_trim`` — a refcount trim, safe against pages shared
with the prefix cache).

**Pipelined execution** (paged engine; ``overlap=True``, the default):
each tick splits into three phases — **plan** (host: preemption verdicts,
admissions, COW/cross/handoff planning), **collect** (the tick's single
barrier: one batched ``jax.device_get`` over every in-flight handle, then
emissions / prefill completions / deferred preemptions), and **dispatch**
(enqueue the tick's compiled steps and return without blocking).  In
overlap mode the results of tick t's dispatch are consumed at tick t+1's
collect, so the host plans tick t+1 while tick t's decode/prefill/verify
calls run on device.  Correctness needs no device-side fences: every step
threads (and donates) the cache value, so all device work serializes
through its dependency chain, and host-side planning only ever touches
pages no in-flight step references (frees happen at collect, before the
following dispatch).  ``overlap=False`` collects in the same tick — the
serial oracle.  Either way outputs are token-identical: admission/decode
timing shifts are invisible to per-request RNG streams.  ``run()``,
``drain()`` and ``preempt()`` barrier on in-flight work first, so
conservation accounting and SSM stashes never race a dispatched step.

**Disaggregated serving** (``disagg=(P, D)`` with ``dp == P + D``;
attention-only archs): replicas split into P prefill-role and D
decode-role.  The router admits fresh requests only on prefill replicas,
which chunk-prefill the prompt, emit the first token, and queue the slot
for handoff; the engine then moves the finished KV page run to the
least-loaded decode replica through one compiled page-transfer step
(``core.steps.make_page_transfer_step`` — int8 scale rows ride along
byte-identically) while ``kvcache.handoff_refs`` moves refcount ownership
atomically.  Decode replicas run pure token-per-tick (or verify) steps,
so long prefills never stall another request's decode — the
prefill/decode interference that dominates TTFT tails.

Sampling is schedule-invariant: every request draws from its own seeded
RNG stream (``Request.rng``), so non-greedy outputs do not depend on
admission order, batch composition, replica routing, handoff placement,
or preemption points — and speculative decoding preserves this
per-request stream exactly.

The engine is mesh-agnostic: it drives whatever step functions
``core.steps`` built — 1-device CPU smoke or a full pod.

Machine-checked clauses (scripts/check_static.py):

Invariant: one compiled (chunk, decode, verify) step set serves every
    request mix — request lengths flow in as data, never as traced
    shapes, so the paged hot loop triggers zero recompiles after tick 1.
Enforced-by: analysis:jit-stability, analysis:traced-shape

Invariant: the per-tick path reads device values only through the single
    batched explicit jax.device_get per collect point — no hidden host
    syncs in run().
Enforced-by: analysis:host-sync

Invariant: dispatch never blocks — between dispatching a tick's compiled
    steps and the next plan phase the host performs no device barrier
    (no jax.device_get / .block_until_ready() / .item() outside collect
    points), so host planning genuinely overlaps device compute.
Enforced-by: analysis:async-barrier

Invariant: speculative headroom return is a refcount trim, never a
    free() — headroom pages may be shared with the radix prefix cache.
Enforced-by: tests/test_spec_decode.py::test_trim_releases_shared_tail_without_freeing, analysis:shared-free

Invariant: no request is lost across a membership change — ``scale_to``
    migrates (or preempt-requeues) every in-flight request of a leaving
    replica and re-places its queue on survivors, and ``kill_replica``
    re-admits the dead replica's orphans as re-prefills from host-side
    request state (prompt + emitted tokens); every submitted request
    completes, with greedy outputs token-identical to an uninterrupted
    dp=1 run and sampled outputs schedule-invariant (per-request RNG
    streams advance one draw per emitted token on every path).
Enforced-by: tests/test_elastic_serving.py::test_chaos_schedules_complete_and_match_oracle

Invariant: membership changes barrier first — ``scale_to`` and
    ``kill_replica`` consume all in-flight dispatched work (``_barrier``)
    before touching pools, allocators, or slot state, so a migration,
    reshard, or recovery never races a dispatched step's page
    references; the overlap pipeline and the serial oracle take the
    same elastic path.
Enforced-by: tests/test_elastic_serving.py::test_scale_down_mid_overlap_completes_all, analysis:async-barrier
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import (SCRATCH_PAGE, SCRATCH_SLAB, PageAllocator,
                                SlabAllocator, cache_profile,
                                kv_pool_is_quantized, pages_needed)
from repro.serving.prefix_cache import (CrossKVCache, HostSpillStore,
                                        PromptLookupDraft, RadixPrefixCache)
from repro.serving.router import Router
from repro.serving.sampler import (SamplerConfig, sample_from_logits,
                                   speculative_sample)
from repro.serving.scheduler import (Admission, FCFSScheduler, Scheduler,
                                     effective_prompt)

# consecutive zero-accept verify steps after which a slot stops drafting
# and returns its draft-headroom pages (the speculation is clearly not
# paying for its page + compute overhead on this request)
SPEC_DISABLE_AFTER = 4


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    frames: Optional[np.ndarray] = None  # (enc_seq_len, d_model) — enc-dec
    max_new_tokens: int = 32
    priority: int = 0                  # higher = more urgent (policies.py)
    client_id: int = 0                 # fairness accounting key (policies.py)
    seed: Optional[int] = None         # sampling stream seed (default: rid)
    rng: Optional[np.random.RandomState] = None   # set at submit
    replica: int = -1                  # routed data shard (set at submit)
    out_tokens: list = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


@dataclass
class ReplicaStats:
    """Per-replica counters (``EngineStats.replicas[r]``)."""
    role: str = "mixed"                # "prefill"/"decode" under --disagg
    routed: int = 0                    # requests the router assigned here
    prefills: int = 0
    decoded_tokens: int = 0
    preemptions: int = 0
    prefix_lookups: int = 0
    prefix_hits: int = 0
    cross_lookups: int = 0             # enc-dec frames-digest lookups
    cross_hits: int = 0
    spec_denied: int = 0               # admissions denied draft headroom
    handoffs_out: int = 0              # finished page runs sent (prefill role)
    handoffs_in: int = 0               # ... received (decode role)
    pages_transferred_out: int = 0
    pages_transferred_in: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_lookups \
            if self.prefix_lookups else 0.0


@dataclass
class EngineStats:
    ticks: int = 0
    prefills: int = 0
    decoded_tokens: int = 0
    prefill_tokens_skipped: int = 0    # prompt tokens served from the cache
    cow_copies: int = 0
    preemptions: int = 0
    prefix_lookups: int = 0
    prefix_hits: int = 0
    cross_lookups: int = 0             # enc-dec frames-digest lookups
    cross_hits: int = 0                # ... served from a shared encode
    cross_encodes: int = 0             # cross-KV write steps actually run
    slab_restores: int = 0             # preempted SSM state reloads
    spec_steps: int = 0                # verify slot-steps with a draft
    spec_drafted: int = 0              # draft tokens proposed to the verifier
    spec_accepted: int = 0             # draft tokens accepted
    spec_emitted: int = 0              # tokens emitted by drafted slot-steps
    spec_draft_lookups: int = 0        # draft-source queries
    spec_draft_hits: int = 0           # ... that produced a usable draft
    spec_denied: int = 0               # admissions denied draft headroom
    handoffs: int = 0                  # prefill->decode page-run transfers
    pages_transferred: int = 0         # pages moved across replicas
    scale_events: int = 0              # scale_to membership changes applied
    crashes: int = 0                   # kill_replica recoveries
    migrations: int = 0                # in-flight slots moved off a drain
    migrated_pages: int = 0            # pages those migrations carried
    readmitted: int = 0                # requests re-placed by drain/recovery
    plan_ahead_ticks: int = 0          # plan phases run with work in flight
    plan_invalidations: int = 0        # speculative plan entries rolled back
    collect_wait_s: float = 0.0        # host time blocked at collect points
    device_busy_s: float = 0.0         # dispatch->collect device intervals
    tick_wall_s: float = 0.0           # total wall time inside tick()
    tpot_s: list = field(default_factory=list)
    request_ttft: dict = field(default_factory=dict)   # rid -> seconds
    replicas: List[ReplicaStats] = field(default_factory=list)

    @property
    def device_busy_fraction(self) -> float:
        """Fraction of tick wall time with dispatched work in flight — an
        overlap health proxy (dispatch-to-collect intervals over total tick
        time; approximate, since the device may finish before collect)."""
        return min(self.device_busy_s / self.tick_wall_s, 1.0) \
            if self.tick_wall_s else 0.0

    @property
    def ttft_s(self) -> list:
        """TTFT samples in first-token order (derived per request)."""
        return list(self.request_ttft.values())

    @property
    def accepted_tokens_per_tick(self) -> float:
        """Tokens emitted per drafted verify slot-step (> 1.0 means the
        speculation is beating the one-token path)."""
        return self.spec_emitted / self.spec_steps if self.spec_steps \
            else 0.0

    @property
    def draft_hit_rate(self) -> float:
        return self.spec_draft_hits / self.spec_draft_lookups \
            if self.spec_draft_lookups else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_lookups \
            if self.prefix_lookups else 0.0

    @property
    def cross_hit_rate(self) -> float:
        return self.cross_hits / self.cross_lookups \
            if self.cross_lookups else 0.0


class ServingEngine:
    def __init__(self, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                 params, prefill_fn, decode_fn, eos_id: int = 1,
                 sampler: Optional[SamplerConfig] = None, *,
                 paged: bool = False, page_size: int = 16,
                 n_pages: int = 0, prefill_chunk: int = 0,
                 prefix_cache: bool = False, scheduler=None,
                 rng_seed: int = 0, dp: int = 1, n_slabs: int = 0,
                 speculative: int = 0, verify_fn=None,
                 overlap: bool = True, disagg=None, transfer_fn=None,
                 spill=None):
        from repro.core import steps as _steps
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        assert dp >= 1, dp
        assert paged or dp == 1, "dp>1 serving requires the paged engine"
        self.R = dp                    # data-parallel replicas
        self.Bp = batch_slots          # slots per replica
        self.B = batch_slots * dp      # global slots (the decode batch)
        self.S = seq_budget
        self.params = params
        self.prefill_fn = prefill_fn   # jitted: batch=1 lane / paged chunks
        self.decode_fn = decode_fn     # jitted, batch=R*Bp
        self.eos = eos_id
        self.sampler = sampler or SamplerConfig()
        self.admissions: List[Optional[Admission]] = [None] * self.B
        self.pos = np.zeros(self.B, np.int32)
        self.last_token = np.zeros(self.B, np.int32)
        self.paged = paged
        self.stats = EngineStats(replicas=[ReplicaStats()
                                           for _ in range(self.R)])
        self.allocators: List[PageAllocator] = []
        self.prefix_caches: List[Optional[RadixPrefixCache]] = []
        self.slab_allocators: List[SlabAllocator] = []
        self.cross_caches: List[Optional[CrossKVCache]] = []
        self.router: Optional[Router] = None
        prof = cache_profile(cfg)
        self.has_ssm = paged and "ssm" in prof
        self.has_cross = paged and "cross_kv" in prof
        # int8 page pools carry per-(page, slot) scale tensors whose rows
        # must be invalidated when a page is recycled (see _plan_admissions)
        self.quant_pools = paged and kv_pool_is_quantized(plan) and \
            ("kv" in prof or "cross_kv" in prof)
        self.overlap = bool(overlap) and paged
        self.disagg = None
        self.roles: Optional[List[str]] = None
        if disagg is not None:
            p_reps, d_reps = int(disagg[0]), int(disagg[1])
            if not paged:
                raise ValueError(
                    "disaggregated serving requires the paged engine")
            if p_reps < 1 or d_reps < 1 or p_reps + d_reps != dp:
                raise ValueError(
                    f"disagg {p_reps}:{d_reps} must cover every replica "
                    f"with at least one of each role: need P >= 1, D >= 1 "
                    f"and P + D == dp ({dp})")
            if prof != {"kv"}:
                raise ValueError(
                    f"disaggregated serving is unsupported for arch "
                    f"'{cfg.name}': the page-transfer step moves self-KV "
                    f"page runs between replica pools (cache kinds "
                    f"{sorted(prof)}) — SSM slabs and cross-KV pages do "
                    f"not hand off")
            self.disagg = (p_reps, d_reps)
            self.roles = ["prefill"] * p_reps + ["decode"] * d_reps
        if paged:
            from repro.core.kvcache import paged_cache_supported
            ok, why = paged_cache_supported(cfg)
            if not ok:
                raise ValueError(
                    f"paged serving unsupported for arch '{cfg.name}': {why}")
            if prefix_cache and self.has_ssm:
                raise ValueError(
                    f"prefix_cache=True is unsupported for arch "
                    f"'{cfg.name}': its SSM layers hold recurrent state "
                    f"that a token-id prefix cannot address (cache kinds "
                    f"{sorted(prof)}); run it paged without the prefix "
                    f"cache")
            if prefix_cache and self.has_cross:
                raise ValueError(
                    f"prefix_cache=True is unsupported for arch "
                    f"'{cfg.name}': decoder self-KV depends on the "
                    f"request's encoder frames through cross-attention, "
                    f"so equal token prefixes do not imply equal KV; "
                    f"cross-KV sharing is keyed by frames digest instead "
                    f"(automatic)")
            assert seq_budget % page_size == 0, (seq_budget, page_size)
            assert prefill_chunk > 0 and seq_budget % prefill_chunk == 0, \
                (seq_budget, prefill_chunk)
            self.page_size = page_size
            self.chunk = prefill_chunk
            self.n_max_pages = seq_budget // page_size
            self.n_slabs = n_slabs or batch_slots + 1
            self.n_pool_pages = n_pages        # per-replica pool size
            self._prefix_cache_enabled = bool(prefix_cache)
            self.n_cross_pages = pages_needed(cfg.enc_seq_len, page_size) \
                if self.has_cross else 0
            # replica-local pools: refcounts never cross a replica boundary
            self.allocators = [PageAllocator(n_pages) for _ in range(dp)]
            self.prefix_caches = [
                RadixPrefixCache(a, page_size) if prefix_cache else None
                for a in self.allocators]
            self.slab_allocators = [SlabAllocator(self.n_slabs)
                                    for _ in range(dp)] if self.has_ssm \
                else []
            self.cross_caches = [CrossKVCache(a) for a in self.allocators] \
                if self.has_cross else []
            self.slot_state: List[Optional[str]] = [None] * self.B
            self.prefill_done = np.zeros(self.B, np.int32)
            self._stash: dict = {}     # rid -> preempted SSM checkpoint
            self.cache = _steps.zero_paged_cache_for(
                cfg, plan, mesh, n_pages, page_size, n_replicas=dp,
                n_slabs=self.n_slabs if self.has_ssm else 0)
        else:
            assert not prefix_cache, "prefix cache requires the paged engine"
            self.cache = _steps.zero_cache_for(cfg, plan, mesh, batch_slots,
                                               seq_budget)
        self.speculative = int(speculative)
        self.verify_fn = verify_fn
        self.draft_sources: List[PromptLookupDraft] = []
        self.spec_miss = np.zeros(self.B, np.int32)
        if self.speculative > 0:
            if not paged:
                raise ValueError(
                    "speculative decoding requires the paged engine")
            if prof != {"kv"}:
                raise ValueError(
                    f"speculative decoding is unsupported for arch "
                    f"'{cfg.name}': the k-token verify step covers "
                    f"attention-only decoders (cache kinds {sorted(prof)}) "
                    f"— SSM recurrences advance one token per step and "
                    f"enc-dec verify is not implemented")
            self.draft_sources = [PromptLookupDraft(self.prefix_caches[r])
                                  for r in range(dp)]
        if paged:
            # compiled steps come from the memoized per-shape step set
            # (steps.paged_step_set): repeated engine builds and elastic
            # membership changes reuse XLA executables instead of
            # recompiling.  Explicitly passed functions win.
            self._wire_steps(prefill_fn=prefill_fn, decode_fn=decode_fn,
                             verify_fn=verify_fn, transfer_fn=transfer_fn)
        # ``scheduler`` is either a ready instance (dp=1 only) or a factory
        # (a Scheduler subclass / functools.partial): factories receive the
        # engine-owned shared state, so callers can pass e.g.
        # ``PriorityScheduler`` without pre-building the allocator
        # themselves.  With dp>1 one instance is built per replica so every
        # policy's bookkeeping (queues, deficits, aging clocks) is
        # replica-local.
        sched = scheduler or FCFSScheduler
        if isinstance(sched, Scheduler):
            assert dp == 1, "dp>1 needs a scheduler factory, not an instance"
            assert self.disagg is None, \
                "disaggregation needs a scheduler factory, not an instance"
            self.scheds = [sched]
        else:
            self.scheds = [
                sched(seq_budget=seq_budget,
                      allocator=self.allocators[r] if paged else None,
                      page_size=page_size if paged else 0,
                      prefix_cache=self.prefix_caches[r] if paged else None,
                      slab_allocator=(self.slab_allocators[r]
                                      if self.has_ssm else None),
                      cross_cache=(self.cross_caches[r]
                                   if self.has_cross else None),
                      cross_pages_per_req=(self.n_cross_pages
                                           if self.has_cross else 0),
                      kv_pages=not paged or "kv" in prof,
                      spec_tokens=self.speculative if paged else 0,
                      stats=self.stats,
                      **({"role": self.roles[r]}
                         if self.roles is not None else {}))
                for r in range(dp)]
        for r, s in enumerate(self.scheds):
            # per-replica counters update at the scheduler's single
            # counting site, alongside the global stats
            if getattr(s, "replica_stats", None) is None:
                s.replica_stats = self.stats.replicas[r]
        if self.roles is not None:
            for r, ro in enumerate(self.roles):
                self.stats.replicas[r].role = ro
        if paged:
            self.router = Router(self.scheds, self.allocators,
                                 self.prefix_caches, page_size,
                                 cross_caches=self.cross_caches or None,
                                 roles=self.roles)
        self._rids: set = set()
        self.rng_seed = rng_seed
        # pipelined execution state: results of the previous dispatch phase
        # not yet consumed (None = nothing in flight), plus the FIFO of
        # prefill-role slots whose finished page runs await a decode home
        self._inflight: Optional[dict] = None
        self._pending_handoffs: List[int] = []
        # elastic membership: scale_to/kill_replica need the factory to
        # build schedulers for joined replicas; a hook installed here fires
        # at the top of every paged tick (fault injection, ops triggers)
        self._sched_factory = None if isinstance(sched, Scheduler) else sched
        self.membership_hook = None
        self.spill = spill
        if paged and spill is not None:
            self._restore_from_spill(spill)

    @classmethod
    def build_paged(cls, cfg, plan, mesh, batch_slots: int, seq_budget: int,
                    params, *, page_size: int = 16, n_pages: int = 0,
                    prefill_chunk: int = 16, eos_id: int = 1,
                    sampler: Optional[SamplerConfig] = None,
                    prefix_cache: bool = False, scheduler=None,
                    rng_seed: int = 0, dp: int = 1, n_slabs: int = 0,
                    speculative: int = 0, overlap: bool = True,
                    disagg=None, spill=None):
        """Construct a paged engine; its compiled (chunk, decode) pair
        (plus the cross-KV write step for enc-dec archs, the k+1-token
        verify step when ``speculative=k`` > 0, and the page-transfer step
        for dp>1 attention-only configs) comes from the memoized per-shape
        step set, so repeated builds reuse XLA executables.

        ``n_pages`` is the PER-REPLICA pool size and defaults to full
        occupancy (every slot at budget, plus every slot's cross-KV pages
        for enc-dec archs) plus the scratch page; pass something smaller to
        exercise admission control under memory pressure.  ``n_slabs``
        (SSM/hybrid archs) defaults to one recurrent-state slab per slot
        plus the scratch slab.  ``dp`` replicas each get ``batch_slots``
        slots and their own pool, driven together by one compiled step
        pair.  ``spill`` (a ``HostSpillStore``) warm-starts the prefix /
        cross caches from a previous engine's spilled page payloads."""
        from repro.core import steps as _steps
        from repro.core.kvcache import paged_cache_supported
        ok, why = paged_cache_supported(cfg)
        if not ok:
            raise ValueError(
                f"paged serving unsupported for arch '{cfg.name}': {why}")
        has_ssm, has_cross = _steps.paged_extra_inputs(cfg)
        n_max = seq_budget // page_size
        n_cross = pages_needed(cfg.enc_seq_len, page_size) if has_cross else 0
        n_pages = n_pages or batch_slots * (n_max + n_cross) + 1
        n_slabs = n_slabs or batch_slots + 1
        return cls(cfg, plan, mesh, batch_slots, seq_budget, params,
                   None, None, eos_id=eos_id,
                   sampler=sampler, paged=True, page_size=page_size,
                   n_pages=n_pages, prefill_chunk=prefill_chunk,
                   prefix_cache=prefix_cache, scheduler=scheduler,
                   rng_seed=rng_seed, dp=dp, n_slabs=n_slabs,
                   speculative=speculative, overlap=overlap, disagg=disagg,
                   spill=spill)

    # ------------------------------------------------------------------ API
    @property
    def sched(self):
        """The single scheduler (dp=1 compatibility accessor)."""
        assert self.R == 1, "dp>1: use engine.scheds[r] / has_pending()"
        return self.scheds[0]

    @property
    def allocator(self):
        """The single allocator (dp=1 compatibility accessor)."""
        if not self.paged:
            return None
        assert self.R == 1, "dp>1: use engine.allocators[r]"
        return self.allocators[0]

    @property
    def prefix_cache(self):
        """The single prefix cache (dp=1 compatibility accessor)."""
        if not self.paged:
            return None
        assert self.R == 1, "dp>1: use engine.prefix_caches[r]"
        return self.prefix_caches[0]

    @property
    def slots(self) -> List[Optional[Request]]:
        """Requests in flight, by global slot (derived from admissions)."""
        return [a.req if a is not None else None for a in self.admissions]

    def _rep(self, b: int) -> int:
        """Replica owning global slot ``b``."""
        return b // self.Bp

    def _gslot(self, r: int, local: int) -> int:
        """Replica-local slot index -> global slot index."""
        return r * self.Bp + local

    def _wire_steps(self, prefill_fn=None, decode_fn=None, verify_fn=None,
                    transfer_fn=None):
        """(Re)wire the paged engine's compiled steps from the memoized
        per-shape step set for the CURRENT replica count ``self.R`` —
        called at construction and again after every membership change.
        Explicitly passed functions win over the set's entries."""
        from repro.core import steps as _steps
        sset = _steps.paged_step_set(
            self.cfg, self.plan, self.mesh, self.Bp, self.n_pool_pages,
            self.page_size, self.n_max_pages, self.chunk,
            n_replicas=self.R,
            n_slabs=self.n_slabs if self.has_ssm else 0,
            speculative=self.speculative)
        self.prefill_fn = prefill_fn or sset["prefill"]
        self.decode_fn = decode_fn or sset["decode"]
        self.copy_fn = sset["copy"]    # COW only exists with self-KV pools
        if self.has_cross:
            self.cross_write_fn = sset["cross_write"]
        self.verify_fn = verify_fn or sset["verify"]
        self.transfer_fn = transfer_fn or sset["transfer"]

    # ------------------------------------------------- cache-tree plumbing
    def _kind_leaves(self, kind: str):
        """Leaves of one cache kind ("kv" pools / "ssm" slabs / "cross"),
        in deterministic tree order."""
        out = []
        for pat in self.cache:
            for d in pat:
                if kind in d:
                    out.extend(jax.tree_util.tree_leaves(d[kind]))
        return out

    def _update_kind(self, kind: str, fn):
        """Rebuild ``self.cache`` applying ``fn(leaf, i)`` to the i-th leaf
        of ``kind`` (same order as ``_kind_leaves``); other kinds pass
        through untouched."""
        idx = [0]

        def upd(leaf):
            res = fn(leaf, idx[0])
            idx[0] += 1
            return res

        self.cache = [[{k: (jax.tree_util.tree_map(upd, v) if k == kind
                            else v) for k, v in d.items()}
                       for d in pat] for pat in self.cache]

    def _reset_scale_rows(self, r: int, pids):
        """Zero the per-(page, slot) scale rows of recycled pages in
        replica ``r`` — scale 0 dequantizes to exact zeros, so a recycled
        page can never pair a fresh payload with a stale scale (each write
        re-sets payload + scale atomically, but rows past a new occupant's
        length would otherwise keep the previous owner's scales)."""
        idx = jnp.asarray(np.asarray(pids, np.int32))

        def upd(kind):
            self.cache = [[{k: ({kk: (vv.at[:, r, idx].set(0.0)
                                      if kk.endswith("sp") else vv)
                                 for kk, vv in v.items()}
                                if k == kind and isinstance(v, dict) else v)
                            for k, v in d.items()}
                           for d in pat] for pat in self.cache]

        upd("kv")
        upd("cross")

    def _zero_slab(self, r: int, slab: int):
        """Fresh requests start from zero recurrent state; the previous
        occupant's state persists in the pool otherwise."""
        self._update_kind(
            "ssm", lambda leaf, i: leaf.at[:, r, slab].set(0))

    def _stash_slot(self, b: int, adm, n: int):
        """Checkpoint a preempted SSM-arch slot to host: the slab (state
        after exactly ``n`` tokens) plus the payloads of the KV pages
        covering those tokens.  KV alone could be recomputed, but not
        THROUGH hybrid layers without re-advancing the SSM state — the
        resume point must restore both or neither, so both are stashed."""
        r = self._rep(b)
        stash = {"n": n, "ssm": [], "kv": [], "n_kv_pages": 0}
        for leaf in self._kind_leaves("ssm"):
            stash["ssm"].append(np.asarray(leaf[:, r, adm.slab]))
        if adm.pages:
            k = pages_needed(n, self.page_size)
            pids = jnp.asarray(np.asarray(adm.pages[:k], np.int32))
            stash["n_kv_pages"] = k
            for leaf in self._kind_leaves("kv"):
                stash["kv"].append(np.asarray(leaf[:, r, pids]))
        self._stash[adm.req.rid] = stash

    def _restore_slot(self, b: int, adm, stash):
        """Reload a stashed checkpoint into the re-admission's freshly
        allocated slab and pages; prefill then continues at token
        ``stash["n"]`` — nothing resident is recomputed."""
        r = self._rep(b)
        ssm_payload = stash["ssm"]
        self._update_kind(
            "ssm", lambda leaf, i: leaf.at[:, r, adm.slab].set(
                jnp.asarray(ssm_payload[i])))
        k = stash["n_kv_pages"]
        if k:
            pids = jnp.asarray(np.asarray(adm.pages[:k], np.int32))
            kv_payload = stash["kv"]
            self._update_kind(
                "kv", lambda leaf, i: leaf.at[:, r, pids].set(
                    jnp.asarray(kv_payload[i])))
        self.stats.slab_restores += 1

    def has_pending(self) -> bool:
        return any(s.has_pending() for s in self.scheds)

    def submit(self, req: Request):
        if req.rid in self._rids:     # rids key the per-request stats
            raise RuntimeError(f"duplicate request id {req.rid}")
        if self.cfg.is_encdec:
            want = (self.cfg.enc_seq_len, self.cfg.d_model)
            if req.frames is None:
                raise RuntimeError(
                    f"request {req.rid}: arch '{self.cfg.name}' is "
                    f"encoder-decoder — Request.frames of shape {want} "
                    f"(encoder frame embeddings) is required")
            if tuple(np.shape(req.frames)) != want:
                raise RuntimeError(
                    f"request {req.rid}: frames shape "
                    f"{tuple(np.shape(req.frames))} != {want} expected by "
                    f"arch '{self.cfg.name}' (enc_seq_len, d_model)")
        if self.disagg is not None:
            # prefill-role admission budgets the prompt only; the request
            # must still fit a decode replica's pool at handoff time
            need = pages_needed(len(req.prompt) + req.max_new_tokens,
                                self.page_size)
            usable = max(self.allocators[rr].n_pages -
                         self.allocators[rr].n_reserved
                         for rr in range(self.R)
                         if self.roles[rr] == "decode")
            if need > usable:
                raise RuntimeError(
                    f"request {req.rid} needs {need} pages to decode but "
                    f"the largest decode-replica pool has only {usable} "
                    f"usable pages — it could prefill but never hand off")
        r = self.router.route(req) if self.router is not None else 0
        self.scheds[r].submit(req)    # raises on infeasible requests
        if self.router is not None:
            self.router.commit(req, r)
        req.replica = r
        self.stats.replicas[r].routed += 1
        self._rids.add(req.rid)
        if req.rng is None:
            # one private stream per request: sampled outputs depend only on
            # (engine seed, request seed), never on scheduling or routing
            seed = req.seed if req.seed is not None else req.rid
            req.rng = np.random.RandomState([self.rng_seed, seed])
        req.t_submit = time.monotonic()

    def run(self, max_ticks: int = 10_000):
        while (self.has_pending() or
               any(a is not None for a in self.admissions)) and \
                self.stats.ticks < max_ticks:
            self.tick()
        # final barrier: collect any work still in flight (overlap mode
        # after max_ticks exhaustion) so emitted tokens and retirements
        # land before the caller inspects state or drains
        self._barrier()
        return self.stats

    def _barrier(self):
        """Consume any in-flight dispatched work (no-op when idle); the
        engine is fully synchronous afterwards."""
        if self._inflight is not None:
            self._collect_phase()

    def drain(self) -> int:
        """Abort every in-flight admission (e.g. after ``run`` exhausted
        ``max_ticks``): each is routed through its own replica's
        ``sched.on_finish`` so its pages return to that replica's pool —
        no leaked refcounts.  Aborted requests keep ``done=False``;
        queued-but-never-admitted requests hold no resources and stay
        queued.  -> number of slots drained.

        Host-side SSM checkpoints are dropped too: a still-queued
        preempted request that resumes after a drain re-prefills from
        scratch (exact — admission plans cold and zeroes its slab)
        instead of restoring, so stash memory cannot outlive the work
        it was checkpointing."""
        self._barrier()               # in-flight work settles before abort
        n = 0
        for b in range(self.B):
            adm = self.admissions[b]
            if adm is None:
                continue
            self.scheds[self._rep(b)].on_finish(adm)
            self._clear_slot(b)
            n += 1
        if self.paged:
            self._stash.clear()
        return n

    def preempt(self, b: int):
        """Evict global slot ``b`` mid-flight.  The slot's progress needs
        no explicit snapshot: emitted tokens already live on
        ``req.out_tokens``, and resume re-admits over the *effective
        prompt* (prompt + emitted tokens), so ``pos``/``prefill_done``
        are reconstructed by ordinary admission.  The resident full pages
        are donated to the OWNING REPLICA's prefix cache via its
        ``sched.on_preempt`` — resume finds them as a prefix hit on the
        same replica (routing is sticky) and the victim's KV is reused,
        not recomputed (only the partial tail page is re-prefilled)."""
        assert self.paged, "preemption requires the paged engine"
        assert self.admissions[b] is not None, f"slot {b} is idle"
        self._barrier()               # external preempt: settle first
        if self.admissions[b] is None:
            return                    # the slot retired at that collect point
        self._preempt_now(b)

    def _preempt_now(self, b: int):
        """Immediate eviction — callers guarantee no in-flight dispatched
        step references slot ``b``'s pages (either nothing is in flight,
        or the in-flight results were just collected)."""
        adm = self.admissions[b]
        assert adm is not None, f"slot {b} is idle"
        n = int(self.prefill_done[b]) if self.slot_state[b] == "prefill" \
            else int(self.pos[b])
        if self.has_ssm:
            # recurrent state cannot be re-derived from donated pages:
            # checkpoint the slot (slab + resident KV payloads) to host
            # BEFORE the scheduler releases its resources; resume reloads
            # it (see _restore_slot)
            self._stash_slot(b, adm, n)
        resident = effective_prompt(adm.req)[:n]
        self.scheds[self._rep(b)].on_preempt(adm, resident)
        self._clear_slot(b)
        self.stats.preemptions += 1
        self.stats.replicas[self._rep(b)].preemptions += 1

    def _clear_slot(self, b: int):
        self.admissions[b] = None
        self.pos[b] = 0
        self.last_token[b] = 0
        self.spec_miss[b] = 0
        if self.paged:
            self.slot_state[b] = None
            self.prefill_done[b] = 0
            if b in self._pending_handoffs:
                self._pending_handoffs.remove(b)

    # --------------------------------------------------- elastic membership
    def scale_to(self, dp_new: int):
        """Live dp reconfiguration: grow or shrink to ``dp_new`` replicas
        without dropping an in-flight request.  Scale-down drains the
        leaving replicas first — each active slot migrates its resident KV
        pages to a survivor via the compiled page-transfer step (int8
        scale rows ride along; host refcounts hand off atomically), or
        falls back to preempt-and-requeue where migration cannot apply
        (SSM/enc-dec state, no free slot, destination pool pressure) —
        then queued requests re-route to survivors and, with a spill store
        attached, the leaving replicas' cached pages spill to host.  Both
        directions then rebuild: pools canonicalize and re-scatter to the
        new width, survivors keep their allocator/cache/scheduler objects
        (page ids and refcounts stay valid), joined replicas start fresh,
        and the compiled steps rewire from the memoized step set."""
        from repro.core import steps as _steps
        if not self.paged:
            raise ValueError("elastic membership requires the paged engine")
        if self.disagg is not None:
            raise ValueError(
                "scale_to under disaggregation is unsupported: a disagg "
                "engine's prefill/decode role sets are static")
        if self._sched_factory is None:
            raise ValueError(
                "scale_to needs a scheduler factory, not a pre-built "
                "instance (joined replicas build their own scheduler)")
        dp_new = int(dp_new)
        nd = _steps.n_dp(self.mesh, self.plan)
        if dp_new < 1 or dp_new % nd:
            raise ValueError(
                f"dp_new={dp_new} must be a positive multiple of the "
                f"mesh's data extent ({nd}) so every replica keeps a "
                f"whole device group")
        if dp_new == self.R:
            return
        self._barrier()               # in-flight work settles first
        self.stats.scale_events += 1
        if dp_new > self.R:
            self._rebuild(list(range(self.R)), dp_new)
        else:
            keep = list(range(dp_new))
            self._drain_replicas(list(range(dp_new, self.R)), keep)
            self._rebuild(keep, dp_new)
        if self.spill is not None:
            self._restore_from_spill(self.spill)

    def kill_replica(self, r: int):
        """Injected (or detected) replica FAILURE — no drain: replica
        ``r``'s device pages, allocator and scheduler state are presumed
        lost.  Recovery (runtime.ft.plan_recovery) re-admits its orphans
        on the survivors: active slots replay prompt + emitted tokens as a
        re-prefill from host-side request state (exact continuation — the
        per-request RNG stream has advanced one draw per emitted token
        either way), queued requests simply re-route.  -> the
        ``RecoveryReport``."""
        from repro.core import steps as _steps
        from repro.runtime.ft import plan_recovery
        if not self.paged:
            raise ValueError("elastic membership requires the paged engine")
        if self.disagg is not None:
            raise ValueError(
                "kill_replica under disaggregation is unsupported: a "
                "disagg engine's prefill/decode role sets are static")
        if self._sched_factory is None:
            raise ValueError(
                "kill_replica needs a scheduler factory, not a pre-built "
                "instance")
        if not 0 <= r < self.R:
            raise ValueError(f"replica {r} out of range (dp={self.R})")
        nd = _steps.n_dp(self.mesh, self.plan)
        if self.R < 2 or (self.R - 1) % nd:
            raise ValueError(
                f"cannot lose a replica at dp={self.R}: the survivor "
                f"count must stay a positive multiple of the mesh's data "
                f"extent ({nd})")
        self._barrier()
        self.stats.crashes += 1
        active = [self.admissions[b] for b in self._rep_slots(r)
                  if self.admissions[b] is not None]
        reqs, report = plan_recovery(r, active,
                                     self.scheds[r].pending_requests())
        # the dead replica's pool/allocator/caches are discarded wholesale:
        # clear its slots WITHOUT routing through on_finish (there is no
        # surviving refcount state to release into)
        for b in self._rep_slots(r):
            if self.admissions[b] is not None:
                self._clear_slot(b)
        self._rebuild([x for x in range(self.R) if x != r], self.R - 1)
        for req in reqs:
            self._place(req)
        return report

    def _drain_replicas(self, leaving: List[int], keep: List[int]):
        """Empty the leaving replicas: mark them unroutable, migrate (or
        preempt-requeue) every active slot, re-place their queues on
        survivors, and spill their cached pages to host if a spill store
        is attached.  Runs fully synchronously (callers barrier first)."""
        for r in leaving:
            self.router.mark_draining(r)
        for r in leaving:
            for b in self._rep_slots(r):
                if self.admissions[b] is None:
                    continue
                if not self._migrate_slot(b, keep):
                    # fallback: evict onto the leaving scheduler's queue
                    # (SSM slots checkpoint to host); re-placed below
                    self._preempt_now(b)
        for r in leaving:
            for req in self.scheds[r].take_queued():
                self._place(req)
        if self.spill is not None:
            # spill BEFORE the rebuild discards the leaving replicas'
            # pool rows — preempt-donated progress is captured too
            self.spill_state(self.spill, replicas=leaving)

    def _migrate_slot(self, b_src: int, keep: List[int]) -> bool:
        """Move global slot ``b_src``'s in-flight request to a surviving
        replica: claim a destination admission, copy the resident pages
        with the compiled transfer step (scale rows ride along), hand the
        refcounts off atomically, and install the slot state (pos /
        prefill progress / last token) at the destination.  -> False when
        migration cannot apply (state kinds that do not transfer, no free
        slot, destination pool pressure, or a transfer fault) — the
        destination claim, if any, is rolled back and the caller falls
        back to preemption; the source slot is left untouched."""
        if self.has_ssm or self.has_cross or self.transfer_fn is None:
            return False
        src_r = self._rep(b_src)
        adm = self.admissions[b_src]
        req = adm.req
        in_prefill = self.slot_state[b_src] == "prefill"
        n = int(self.prefill_done[b_src]) if in_prefill \
            else int(self.pos[b_src])
        cand = [r for r in keep
                if any(self.admissions[b] is None
                       for b in self._rep_slots(r))]
        if not cand:
            return False
        dst_r = self.router.decode_placement(cand)
        local = min(b - dst_r * self.Bp for b in self._rep_slots(dst_r)
                    if self.admissions[b] is None)
        dst_adm = self.scheds[dst_r].plan_migration(local, req, n)
        if dst_adm is None:
            return False              # destination pool pressure
        k = pages_needed(n, self.page_size)
        if k:
            src_pages = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
            dst_pages = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
            src_pages[:k] = adm.pages[:k]
            dst_pages[:k] = dst_adm.pages[:k]
            try:
                with self.mesh:
                    self.cache = self.transfer_fn(
                        self.cache, jnp.int32(src_r), jnp.int32(dst_r),
                        jnp.asarray(src_pages), jnp.asarray(dst_pages))
            except Exception:
                # mid-handoff fault: no refcount moved yet (handoff_refs
                # runs only after the transfer), so retiring the claimed
                # destination admission restores the pre-migration state
                # exactly — no orphan pages on either side
                self.scheds[dst_r].on_finish(dst_adm)
                return False
        self.scheds[src_r].on_migrated(adm, k, self.allocators[dst_r],
                                       dst_adm.pages[:k])
        b_dst = self._gslot(dst_r, dst_adm.slot)
        self.admissions[b_dst] = dst_adm
        self.slot_state[b_dst] = "prefill" if in_prefill else "decode"
        self.pos[b_dst] = self.pos[b_src]
        self.prefill_done[b_dst] = self.prefill_done[b_src]
        self.last_token[b_dst] = self.last_token[b_src]
        self.spec_miss[b_dst] = self.spec_miss[b_src]
        self._clear_slot(b_src)
        req.replica = dst_r
        self.router.commit(req, dst_r)
        self.stats.migrations += 1
        self.stats.migrated_pages += k
        self.stats.pages_transferred += k
        self.stats.replicas[src_r].pages_transferred_out += k
        self.stats.replicas[dst_r].pages_transferred_in += k
        self.stats.replicas[dst_r].routed += 1
        return True

    def _place(self, req: Request):
        """Re-place an already-submitted request after a membership
        change: route (draining/dead replicas excluded), enqueue, and
        keep its identity — rid, RNG stream, submit time and emitted
        tokens all persist, so this is invisible to the client beyond
        latency.  Feasibility cannot newly fail: every replica pool has
        the same size, and the effective prompt grows exactly as
        remaining new tokens shrink."""
        r = self.router.route(req)
        self.scheds[r].submit(req)
        self.router.commit(req, r)
        req.replica = r
        self.stats.replicas[r].routed += 1
        self.stats.readmitted += 1

    def _rebuild(self, keep: List[int], dp_new: int):
        """Re-stamp the engine for ``dp_new`` replicas with survivors
        ``keep`` (old indices, order preserved): pools canonicalize and
        re-scatter (runtime.elastic.reshard_replica_pools), surviving
        replicas carry their allocator / cache / scheduler OBJECTS over
        (page ids and refcounts stay valid — position in the pool dim is
        all that changes), joined replicas start fresh, slot arrays remap,
        the router rebuilds (drain marks clear by construction; recent-
        routing windows and counters carry over), and the compiled steps
        rewire from the memoized step set."""
        from repro.runtime.elastic import reshard_replica_pools
        assert self._inflight is None and not self._pending_handoffs
        keep = list(keep)
        n_keep = len(keep)
        self.cache = reshard_replica_pools(self.cache, keep, dp_new)
        self.allocators = [self.allocators[r] for r in keep] + \
            [PageAllocator(self.n_pool_pages)
             for _ in range(dp_new - n_keep)]
        self.prefix_caches = [self.prefix_caches[r] for r in keep] + \
            [RadixPrefixCache(a, self.page_size)
             if self._prefix_cache_enabled else None
             for a in self.allocators[n_keep:]]
        if self.has_ssm:
            self.slab_allocators = \
                [self.slab_allocators[r] for r in keep] + \
                [SlabAllocator(self.n_slabs)
                 for _ in range(dp_new - n_keep)]
        if self.has_cross:
            self.cross_caches = [self.cross_caches[r] for r in keep] + \
                [CrossKVCache(a) for a in self.allocators[n_keep:]]
        self.scheds = [self.scheds[r] for r in keep]
        prof = cache_profile(self.cfg)
        for j in range(n_keep, dp_new):
            self.scheds.append(self._sched_factory(
                seq_budget=self.S,
                allocator=self.allocators[j],
                page_size=self.page_size,
                prefix_cache=self.prefix_caches[j],
                slab_allocator=(self.slab_allocators[j]
                                if self.has_ssm else None),
                cross_cache=(self.cross_caches[j]
                             if self.has_cross else None),
                cross_pages_per_req=(self.n_cross_pages
                                     if self.has_cross else 0),
                kv_pages="kv" in prof,
                spec_tokens=self.speculative,
                stats=self.stats))
        self.stats.replicas = [self.stats.replicas[r] for r in keep] + \
            [ReplicaStats() for _ in range(dp_new - n_keep)]
        for j, s in enumerate(self.scheds):
            # survivors' ReplicaStats objects moved with them; only the
            # joined replicas' schedulers need wiring
            if getattr(s, "replica_stats", None) is None:
                s.replica_stats = self.stats.replicas[j]
        # remap slot arrays: old global slot keep[j]*Bp+l -> new j*Bp+l
        old = (self.admissions, self.pos, self.last_token, self.spec_miss,
               self.slot_state, self.prefill_done)
        B_new = self.Bp * dp_new
        self.admissions = [None] * B_new
        self.pos = np.zeros(B_new, np.int32)
        self.last_token = np.zeros(B_new, np.int32)
        self.spec_miss = np.zeros(B_new, np.int32)
        self.slot_state = [None] * B_new
        self.prefill_done = np.zeros(B_new, np.int32)
        for j, r_old in enumerate(keep):
            for ll in range(self.Bp):
                ob, nb = r_old * self.Bp + ll, j * self.Bp + ll
                self.admissions[nb] = old[0][ob]
                self.pos[nb] = old[1][ob]
                self.last_token[nb] = old[2][ob]
                self.spec_miss[nb] = old[3][ob]
                self.slot_state[nb] = old[4][ob]
                self.prefill_done[nb] = old[5][ob]
                if old[0][ob] is not None:
                    old[0][ob].req.replica = j
        for j, s in enumerate(self.scheds):
            for req in s.pending_requests():
                req.replica = j
        old_router = self.router
        self.R, self.B = dp_new, B_new
        self.router = Router(self.scheds, self.allocators,
                             self.prefix_caches, self.page_size,
                             cross_caches=self.cross_caches or None)
        for j, r_old in enumerate(keep):
            self.router._recent[j].extend(old_router._recent[r_old])
            self.router._recent_frames[j].extend(
                old_router._recent_frames[r_old])
        self.router.affinity_routed = old_router.affinity_routed
        if self.speculative > 0:
            self.draft_sources = [PromptLookupDraft(self.prefix_caches[r])
                                  for r in range(dp_new)]
        self._wire_steps()

    # -------------------------------------------------------- host spill
    def spill_state(self, store=None, replicas: Optional[List[int]] = None):
        """Spill the radix-prefix and cross-KV cache contents of
        ``replicas`` (default: all) to a host-side ``HostSpillStore``:
        page payloads — int8 payloads and their per-(page, slot) scale
        rows included, byte-for-byte — keyed by token path / frames
        digest.  The pool itself is untouched (spilling takes no refs);
        restore re-allocates fresh pages wherever the store is next
        attached.  -> the store."""
        assert self.paged, "spill requires the paged engine"
        store = store if store is not None else HostSpillStore()
        for r in (replicas if replicas is not None else range(self.R)):
            pc = self.prefix_caches[r]
            if pc is not None:
                for toks, pages in pc.entries():
                    pids = jnp.asarray(np.asarray(pages, np.int32))
                    store.put_prefix(
                        toks, len(pages),
                        [np.asarray(leaf[:, r, pids])
                         for leaf in self._kind_leaves("kv")])
            if self.has_cross:
                for key, pages in self.cross_caches[r].entries():
                    pids = jnp.asarray(np.asarray(pages, np.int32))
                    store.put_cross(
                        key, len(pages),
                        [np.asarray(leaf[:, r, pids])
                         for leaf in self._kind_leaves("cross")])
        return store

    def _restore_from_spill(self, store):
        """Reload spilled cache entries into the least-loaded replicas:
        allocate fresh pages, write the stored payloads bit-for-bit
        (restored pages stay referenced, so recycled-page scale-row
        resets never touch them), and register with the replica's cache.
        Entries already resident, or not fitting the pool right now, are
        skipped — the spill store is a warm-start, not a ledger."""
        if store is None or not self.paged:
            return
        cand = [r for r in range(self.R)
                if self.prefix_caches[r] is not None]
        if cand:
            for toks, (k, payloads) in store.radix.items():
                prompt = list(toks)
                if any(self.prefix_caches[c].lookup(prompt)[0] >= len(toks)
                       for c in cand):
                    continue          # already resident somewhere
                r = min(cand, key=lambda rr: (self.router.page_load(rr), rr))
                pages = self.allocators[r].alloc(k)
                if pages is None:
                    continue          # pool pressure: stay spilled
                pids = jnp.asarray(np.asarray(pages, np.int32))
                self._update_kind(
                    "kv", lambda leaf, i, r=r, pids=pids, pl=payloads:
                    leaf.at[:, r, pids].set(jnp.asarray(pl[i])))
                self.prefix_caches[r].insert(prompt, pages)
                # the cache holds its own refs now; shared-prefix pages we
                # over-allocated drop to rc 0 here and recycle harmlessly
                self.allocators[r].decref(pages)
                store.pages_restored += k
        if self.has_cross:
            for key, (k, payloads) in store.cross.items():
                if any(xc is not None and xc.has(key)
                       for xc in self.cross_caches):
                    continue
                r = min(range(self.R),
                        key=lambda rr: (self.router.page_load(rr), rr))
                pages = self.allocators[r].alloc(k)
                if pages is None:
                    continue
                pids = jnp.asarray(np.asarray(pages, np.int32))
                self._update_kind(
                    "cross", lambda leaf, i, r=r, pids=pids, pl=payloads:
                    leaf.at[:, r, pids].set(jnp.asarray(pl[i])))
                self.cross_caches[r].insert(key, pages)
                self.allocators[r].decref(pages)
                store.pages_restored += k

    # ----------------------------------------------------------------- tick
    def tick(self):
        if self.paged:
            return self._tick_paged()
        self._admit()
        if not any(self.slots):
            return
        with self.mesh:
            logits, self.cache = self.decode_fn(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(self.pos))
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        now = time.monotonic()
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[b] += 1        # the decode step wrote last_token's KV
            self._emit(b, req, self._sample_row(logits, b, req), now)
        self.stats.ticks += 1

    def _sample_row(self, logits: np.ndarray, b: int, req: Request) -> int:
        """Sample row b from the request's own stream (schedule-invariant)."""
        return int(sample_from_logits(logits[b:b + 1], self.sampler,
                                      self.cfg.vocab_size, req.rng)[0])

    def _emit(self, b: int, req: Request, tok: int, now: float):
        """Record one generated token for slot b; retire the slot when done.

        The caller owns ``pos``: decode ticks advance it past the KV they
        just wrote before emitting; prefill completion leaves it at the
        prompt length (the sampled token's KV is written by the next decode
        tick)."""
        if not req.out_tokens:
            req.t_first_token = now
            self.stats.request_ttft[req.rid] = now - req.t_submit
        req.out_tokens.append(tok)
        self.last_token[b] = tok
        self.stats.decoded_tokens += 1
        self.stats.replicas[self._rep(b)].decoded_tokens += 1
        if tok == self.eos or len(req.out_tokens) >= req.max_new_tokens \
                or self.pos[b] >= self.S - 1:
            req.done = True
            req.t_done = now
            self.stats.tpot_s.append(
                (now - req.t_first_token) /
                max(len(req.out_tokens) - 1, 1))
            self.scheds[self._rep(b)].on_finish(self.admissions[b])
            self._clear_slot(b)

    def _admit(self):
        free = [b for b in range(self.B) if self.admissions[b] is None]
        for adm in self.scheds[0].plan(free):
            self.admissions[adm.slot] = adm
            self._prefill_into(adm.slot, adm.req)

    def _prefill_into(self, b: int, req: Request):
        """Prefill a single request and splice its cache into lane b.
        Enc-dec archs additionally run the encoder over the request's
        frames here; prefill writes the cross-KV lane the decode step
        reads."""
        from repro.core import steps as _steps
        S = len(req.prompt)
        assert S < self.S
        prompt = np.zeros((1, self.S), np.int32)
        prompt[0, :S] = req.prompt
        lane_cache = _steps.zero_cache_for(self.cfg, self.plan, self.mesh, 1,
                                           self.S)
        with self.mesh:
            if self.cfg.is_encdec:
                logits, lane_cache = self.prefill_fn(
                    self.params,
                    jnp.asarray(np.asarray(req.frames, np.float32)[None],
                                jnp.dtype(self.cfg.dtype)),
                    jnp.asarray(prompt[:, :S]), lane_cache)
            else:
                logits, lane_cache = self.prefill_fn(
                    self.params, jnp.asarray(prompt[:, :S]), lane_cache)
        self.stats.prefills += 1
        self.stats.replicas[self._rep(b)].prefills += 1
        # splice lane 0 of lane_cache into slot b of the engine cache
        self.cache = _splice_cache(self.cache, lane_cache, b)
        logits = np.asarray(jax.device_get(logits)).astype(np.float32)
        # the token sampled from the prompt's final logits IS the first
        # generated token: emit it (TTFT lands at prefill completion, and
        # max_new_tokens counts it)
        self.pos[b] = S
        self._emit(b, req, self._sample_row(logits, 0, req),
                   time.monotonic())

    # ------------------------------------------------------------ paged tick
    def _tick_paged(self):
        """One pipelined tick: plan (host, overlaps in-flight device work),
        collect (the tick's single barrier — consume the PREVIOUS tick's
        dispatched results), apply deferred preemption verdicts, dispatch
        this tick's compiled steps.  ``overlap=False`` collects the fresh
        dispatch immediately — the serial oracle.  A membership hook (set
        by fault-injection harnesses or ops triggers) fires first, before
        any planning — scale_to/kill_replica barrier internally, so the
        hook sees (and leaves) a fully synchronous engine."""
        t0 = time.monotonic()
        if self.membership_hook is not None:
            self.membership_hook(self)
        tick_plan = self._plan_phase()
        self._collect_phase()
        self._run_deferred_preempts(tick_plan)
        self._dispatch_phase(tick_plan)
        if not self.overlap:
            self._collect_phase()
        self.stats.ticks += 1
        self.stats.tick_wall_s += time.monotonic() - t0

    def _rep_slots(self, r: int):
        return range(r * self.Bp, (r + 1) * self.Bp)

    # ------------------------------------------------------------ plan phase
    def _plan_phase(self) -> dict:
        """Host planning for this tick — runs while the previous tick's
        dispatched work is still in flight.  Preemption verdicts against
        slots with in-flight results are DEFERRED to after this tick's
        collect point (their emissions may retire the victim first —
        ``plan_invalidations``); with nothing in flight they apply
        immediately, matching the serial engine exactly.  The only device
        work enqueued here (slab zero/restore, scale-row resets) rides the
        cache value's dependency chain, so it serializes after the
        in-flight step without any host sync."""
        tick_plan = {"preempts": [], "handoffs": [], "cow": [], "cross": []}
        if self._inflight is not None:
            self.stats.plan_ahead_ticks += 1
        for r in range(self.R):
            active = [self.admissions[b] for b in self._rep_slots(r)
                      if self.admissions[b] is not None]
            for adm in self.scheds[r].plan_preemptions(
                    active, self.Bp - len(active)):
                b = self._gslot(r, adm.slot)
                if self._inflight is None:
                    self._preempt_now(b)
                else:
                    tick_plan["preempts"].append((b, adm.req.rid))
        if self.disagg is not None:
            self._plan_handoffs(tick_plan)
        self._plan_admissions(tick_plan)
        return tick_plan

    def _plan_handoffs(self, tick_plan: dict):
        """Match pending finished-prefill slots (FIFO) to decode replicas
        with a free slot.  The destination slot is claimed NOW — this
        tick's admission planning must see it occupied — but the transfer
        itself (and the source release) happens at dispatch; a deferred
        preemption landing on the source first rolls the claim back."""
        deferred = {b for b, _ in tick_plan["preempts"]}
        while self._pending_handoffs:
            b_src = self._pending_handoffs[0]
            if b_src in deferred:
                break           # source being evicted at this collect point
            src_adm = self.admissions[b_src]
            cand = [r for r in range(self.R)
                    if self.roles[r] == "decode"
                    and any(self.admissions[b] is None
                            for b in self._rep_slots(r))]
            if not cand:
                break
            dst_r = self.router.decode_placement(cand)
            local = min(b - dst_r * self.Bp for b in self._rep_slots(dst_r)
                        if self.admissions[b] is None)
            resident = int(self.pos[b_src])
            dst_adm = self.scheds[dst_r].plan_handoff(local, src_adm.req,
                                                      resident)
            if dst_adm is None:
                break           # destination pool pressure: head waits
            b_dst = self._gslot(dst_r, dst_adm.slot)
            self.admissions[b_dst] = dst_adm
            self.slot_state[b_dst] = "decode"
            self.pos[b_dst] = resident
            self.prefill_done[b_dst] = resident
            self.last_token[b_dst] = src_adm.req.out_tokens[-1]
            self.spec_miss[b_dst] = 0
            self._pending_handoffs.pop(0)
            tick_plan["handoffs"].append(
                (b_src, src_adm, dst_r, b_dst, dst_adm))

    def _plan_admissions(self, tick_plan: dict):
        """Install this tick's admissions, per replica, and assemble the
        COW / cross-KV rounds the dispatch phase will execute.  Each round
        batches one unit of work per replica (identity/scratch rows for
        replicas with nothing to do).  SSM-arch slots get their slab
        zeroed — or, for a preempted request, restored from its host-side
        stash, resuming prefill at the checkpointed token."""
        cow_rounds: List[List[Optional[Admission]]] = tick_plan["cow"]
        cross_rounds: List[List[Optional[Admission]]] = tick_plan["cross"]
        for r in range(self.R):
            free = [b - r * self.Bp for b in self._rep_slots(r)
                    if self.admissions[b] is None]
            n_cow = n_cross = 0
            for adm in self.scheds[r].plan(free):
                b = self._gslot(r, adm.slot)
                self.admissions[b] = adm
                self.slot_state[b] = "prefill"
                if adm.cow is not None:
                    if n_cow == len(cow_rounds):
                        cow_rounds.append([None] * self.R)
                    cow_rounds[n_cow][r] = adm
                    n_cow += 1
                if adm.needs_encode:
                    if n_cross == len(cross_rounds):
                        cross_rounds.append([None] * self.R)
                    cross_rounds[n_cross][r] = adm
                    n_cross += 1
                # prefix-cached tokens are already resident: prefill resumes
                # at the first uncached position (for a preempted request
                # this is its donated progress — reused, not recomputed)
                self.prefill_done[b] = adm.cached_len
                self.stats.prefill_tokens_skipped += adm.cached_len
                self.pos[b] = 0
                self.last_token[b] = 0
                if self.has_ssm:
                    stash = self._stash.pop(adm.req.rid, None)
                    if stash is not None:
                        self._restore_slot(b, adm, stash)
                        self.prefill_done[b] = stash["n"]
                        self.stats.prefill_tokens_skipped += stash["n"]
                    else:
                        self._zero_slab(r, adm.slab)
            if self.quant_pools:
                dirty = self.allocators[r].take_scale_dirty()
                if dirty:
                    self._reset_scale_rows(r, dirty)

    # --------------------------------------------------------- collect phase
    def _collect_phase(self):
        """The tick's single barrier point: one batched ``jax.device_get``
        over every in-flight handle, then host-side consumption in
        dispatch order — prefill completions (first-token emission, state
        flip to decode or the handoff queue) before decode/verify
        emissions.  Slots evicted or retired since dispatch are skipped by
        (slot, rid) guard, so a cancelled request's RNG stream is never
        advanced."""
        inf = self._inflight
        if inf is None:
            return
        self._inflight = None
        t0 = time.monotonic()
        handles = [h for h, comps in inf["pf"] if comps]
        step = inf["step"]
        if step is not None:
            handles.append(step[1])
        vals = jax.device_get(handles) if handles else []
        t1 = time.monotonic()
        self.stats.collect_wait_s += t1 - t0
        self.stats.device_busy_s += t1 - inf["t_dispatch"]
        vi = 0
        for _, comps in inf["pf"]:
            if not comps:
                continue
            logits_np = np.asarray(vals[vi]).astype(np.float32)
            vi += 1
            for r, b, rid, L in comps:
                adm = self.admissions[b]
                if adm is None or adm.req.rid != rid:
                    continue           # evicted since dispatch
                req = adm.req
                self.stats.prefills += 1
                self.stats.replicas[r].prefills += 1
                self.scheds[r].on_prefill_complete(adm)
                # emit the token sampled from the final prompt position —
                # the first generated token (or, on resume, the next one:
                # resumed requests re-enter with out_tokens non-empty, so
                # TTFT is not re-recorded)
                self.pos[b] = L
                self._emit(b, req, self._sample_row(logits_np, r, req),
                           time.monotonic())
                if self.admissions[b] is None:
                    continue           # retired by that token
                if self.roles is not None and self.roles[r] == "prefill":
                    # prefill-role replicas never decode: queue the slot's
                    # finished page run for transfer to a decode replica
                    self.slot_state[b] = "handoff"
                    self._pending_handoffs.append(b)
                else:
                    self.slot_state[b] = "decode"
        if step is None:
            return
        logits = np.asarray(vals[vi]).astype(np.float32)
        now = time.monotonic()
        if step[0] == "decode":
            for b, rid in step[2]:
                adm = self.admissions[b]
                if adm is None or adm.req.rid != rid:
                    continue
                self.pos[b] += 1    # the decode step wrote last_token's KV
                self._emit(b, adm.req, self._sample_row(logits, b, adm.req),
                           now)
        else:                        # verify
            drafts = step[3]
            for b, rid in step[2]:
                adm = self.admissions[b]
                if adm is None or adm.req.rid != rid:
                    continue
                req = adm.req
                d = drafts.get(b, [])
                out = speculative_sample(logits[b, :len(d) + 1], d,
                                         self.sampler, self.cfg.vocab_size,
                                         req.rng)
                emitted = 0
                for tok in out:
                    self.pos[b] += 1    # verify wrote this position's KV
                    self._emit(b, req, tok, now)
                    emitted += 1
                    if self.admissions[b] is None:
                        break           # retired mid-accept: drop the tail
                if d:
                    self.stats.spec_steps += 1
                    self.stats.spec_drafted += len(d)
                    self.stats.spec_accepted += emitted - 1
                    self.stats.spec_emitted += emitted
                    if self.admissions[b] is not None:  # retired slots reset
                        self.spec_miss[b] = 0 if emitted > 1 \
                            else self.spec_miss[b] + 1

    def _run_deferred_preempts(self, tick_plan: dict):
        """Apply preemption verdicts deferred past the collect point.  A
        victim that retired (or handed off) at collect is simply skipped —
        no release fires twice (``plan_invalidations`` counts the miss)."""
        for b, rid in tick_plan["preempts"]:
            adm = self.admissions[b]
            if adm is None or adm.req.rid != rid:
                self.stats.plan_invalidations += 1
                continue
            self._preempt_now(b)

    # -------------------------------------------------------- dispatch phase
    def _dispatch_phase(self, tick_plan: dict):
        """Enqueue this tick's compiled steps and return without blocking:
        page-run handoffs first (freshly claimed decode slots join this
        tick's decode batch), then cross-KV encodes, COW copies, prefill
        chunk rounds, and the decode-or-verify step.  Result handles land
        in ``self._inflight`` for the next collect point."""
        self._dispatch_handoffs(tick_plan)
        for round_ in tick_plan["cross"]:
            frames = np.zeros((self.R, self.cfg.enc_seq_len,
                               self.cfg.d_model), np.float32)
            cbt = np.full((self.R, self.n_cross_pages), SCRATCH_PAGE,
                          np.int32)
            for r, adm in enumerate(round_):
                if adm is not None:
                    frames[r] = np.asarray(adm.req.frames, np.float32)
                    cbt[r] = adm.cross_pages
            with self.mesh:
                self.cache = self.cross_write_fn(
                    self.params, self.cache,
                    jnp.asarray(frames, jnp.dtype(self.cfg.dtype)),
                    jnp.asarray(cbt))
            for r, adm in enumerate(round_):
                if adm is not None:
                    self.scheds[r].on_cross_written(adm)
                    self.stats.cross_encodes += 1
        for round_ in tick_plan["cow"]:
            src = np.full(self.R, SCRATCH_PAGE, np.int32)
            dst = np.full(self.R, SCRATCH_PAGE, np.int32)   # src==dst: no-op
            for r, adm in enumerate(round_):
                if adm is not None:
                    src[r], dst[r] = adm.cow
            with self.mesh:
                self.cache = self.copy_fn(self.cache,
                                          jnp.asarray(src), jnp.asarray(dst))
            for r, adm in enumerate(round_):
                if adm is not None:
                    self.scheds[r].on_cow_done(adm)
                    self.stats.cow_copies += 1
        pf = self._dispatch_prefill()
        step = self._dispatch_step()
        if pf or step is not None:
            self._inflight = {"pf": pf, "step": step,
                              "t_dispatch": time.monotonic()}

    def _dispatch_handoffs(self, tick_plan: dict):
        """Execute the planned page-run transfers: one compiled gather →
        all-reduce → scatter step per handoff moves the source slot's
        resident pages (int8 scale rows included) into the destination
        replica's freshly allocated pages, then ``on_handoff_sent`` moves
        the refcounts atomically and the source slot clears.  A plan
        invalidated at collect (source evicted) rolls the destination
        claim back instead."""
        for b_src, src_adm, dst_r, b_dst, dst_adm in tick_plan["handoffs"]:
            if self.admissions[b_src] is not src_adm:
                self.scheds[dst_r].on_finish(dst_adm)
                self._clear_slot(b_dst)
                self.stats.plan_invalidations += 1
                continue
            req = src_adm.req
            src_r = self._rep(b_src)
            k = len(src_adm.pages)
            src_pages = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
            dst_pages = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
            src_pages[:k] = src_adm.pages
            dst_pages[:k] = dst_adm.pages[:k]
            with self.mesh:
                self.cache = self.transfer_fn(
                    self.cache, jnp.int32(src_r), jnp.int32(dst_r),
                    jnp.asarray(src_pages), jnp.asarray(dst_pages))
            self.scheds[src_r].on_handoff_sent(
                src_adm, self.allocators[dst_r], dst_adm.pages[:k])
            self._clear_slot(b_src)
            req.replica = dst_r
            self.stats.handoffs += 1
            self.stats.pages_transferred += k
            rs = self.stats.replicas[src_r]
            rd = self.stats.replicas[dst_r]
            rs.handoffs_out += 1
            rd.handoffs_in += 1
            rs.pages_transferred_out += k
            rd.pages_transferred_in += k

    def _bt_row(self, b: int) -> np.ndarray:
        row = np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
        adm = self.admissions[b]
        if adm is not None and adm.pages is not None:
            row[:len(adm.pages)] = adm.pages
        return row

    def _cross_row(self, b: int) -> np.ndarray:
        row = np.full(self.n_cross_pages, SCRATCH_PAGE, np.int32)
        adm = self.admissions[b]
        if adm is not None and adm.cross_pages is not None:
            row[:] = adm.cross_pages
        return row

    def _slab_id(self, b: int, active: bool = True) -> int:
        adm = self.admissions[b]
        return adm.slab if (active and adm is not None
                            and adm.slab is not None) else SCRATCH_SLAB

    def _dispatch_prefill(self):
        """Advance every prefilling slot by one chunk.  Slots are batched
        across replicas: compiled chunk call k covers each replica's k-th
        prefilling slot (replicas with fewer ride along as scratch-page
        no-ops), so the dp mesh prefills all replicas in parallel.
        -> list of (logits handle, completions) per round, consumed at the
        next collect point."""
        per_rep = [[b for b in self._rep_slots(r)
                    if self.admissions[b] is not None
                    and self.slot_state[b] == "prefill"]
                   for r in range(self.R)]
        rounds = []
        for k in range(max((len(s) for s in per_rep), default=0)):
            rows = [s[k] if k < len(s) else None for s in per_rep]
            rounds.append(self._prefill_chunk_round(rows))
        return rounds

    def _prefill_chunk_round(self, rows: List[Optional[int]]):
        """One compiled chunk call: row r advances slot ``rows[r]`` (or is
        a scratch no-op when None).  Host bookkeeping (``prefill_done``)
        advances now; -> (logits handle, [(r, b, rid, prompt_len)] for
        rows whose prompt is now fully resident) — sampling waits for the
        collect point."""
        C = self.chunk
        toks = np.zeros((self.R, C), np.int32)
        starts = np.zeros(self.R, np.int32)
        last_idx = np.zeros(self.R, np.int32)
        bt = np.full((self.R, self.n_max_pages), SCRATCH_PAGE, np.int32)
        slabs = np.full(self.R, SCRATCH_SLAB, np.int32)
        cbt = np.full((self.R, self.n_cross_pages if self.has_cross else 1),
                      SCRATCH_PAGE, np.int32)
        prompts = {}
        for r, b in enumerate(rows):
            if b is None:
                continue
            req = self.admissions[b].req
            prompt = effective_prompt(req)   # includes resumed output tokens
            prompts[r] = (b, req, prompt)
            L, c0 = len(prompt), int(self.prefill_done[b])
            n = min(C, L - c0)
            toks[r, :n] = prompt[c0:c0 + n]
            starts[r] = c0
            last_idx[r] = min(L - 1 - c0, C - 1)
            bt[r] = self._bt_row(b)
            slabs[r] = self._slab_id(b)
            if self.has_cross:
                cbt[r] = self._cross_row(b)
        args = [self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(starts), jnp.asarray(last_idx), jnp.asarray(bt)]
        if self.has_ssm:
            args.append(jnp.asarray(slabs))
        if self.has_cross:
            args.append(jnp.asarray(cbt))
        with self.mesh:
            logits, self.cache = self.prefill_fn(*args)
        comps = []
        for r, (b, req, prompt) in prompts.items():
            L = len(prompt)
            self.prefill_done[b] = int(starts[r]) + C
            if int(starts[r]) + C >= L:      # prompt fully resident
                comps.append((r, b, req.rid, L))
        return logits, comps

    def _dispatch_step(self):
        """Dispatch the tick's decode-or-verify step over every
        decode-state slot.  -> ("decode", logits handle, [(b, rid)]) or
        ("verify", logits handle, [(b, rid)], drafts) or None; emissions
        happen at the next collect point."""
        active = [b for b in range(self.B)
                  if self.admissions[b] is not None
                  and self.slot_state[b] == "decode"]
        if not active:
            return None
        if self.speculative:
            drafts = self._plan_drafts(active)
            if drafts is not None:
                return self._dispatch_verify(active, drafts)
            # every draft came back empty (cold cache / no repeats):
            # fall through to the plain one-token step — identical to
            # running with speculation off
        # idle / prefilling lanes ride along pointed at the scratch page
        # (and scratch slab / scratch cross pages), so full-batch decode
        # never touches a live slab or a prefilling slot's pages
        act = set(active)
        bt = np.stack([self._bt_row(b) if b in act else
                       np.full(self.n_max_pages, SCRATCH_PAGE, np.int32)
                       for b in range(self.B)])
        pos = np.where(np.isin(np.arange(self.B), active), self.pos, 0)
        args = [self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(pos.astype(np.int32)), jnp.asarray(bt)]
        if self.has_ssm:
            slabs = np.asarray([self._slab_id(b, b in act)
                                for b in range(self.B)], np.int32)
            args.append(jnp.asarray(slabs))
        if self.has_cross:
            cbt = np.stack([self._cross_row(b) if b in act else
                            np.full(self.n_cross_pages, SCRATCH_PAGE,
                                    np.int32) for b in range(self.B)])
            args.append(jnp.asarray(cbt))
        with self.mesh:
            logits, self.cache = self.decode_fn(*args)
        return ("decode", logits,
                [(b, self.admissions[b].req.rid) for b in active])

    # ---------------------------------------------------- speculative decode
    def _plan_drafts(self, active: List[int]):
        """Draft up to k tokens per speculation-capable active slot.
        -> {slot: draft tokens} holding only non-empty drafts, or None
        when nothing drafted (the tick falls back to the one-token step).

        A slot whose drafts were rejected ``SPEC_DISABLE_AFTER`` times in
        a row stops drafting for good and returns its headroom pages via
        ``on_spec_trim`` — a refcount trim, because those tail pages may
        meanwhile have been donated to (or matched by) the prefix cache."""
        k = self.speculative
        drafts = {}
        for b in active:
            adm = self.admissions[b]
            if not adm.spec:
                continue
            req = adm.req
            if self.spec_miss[b] >= SPEC_DISABLE_AFTER:
                keep = pages_needed(len(req.prompt) + req.max_new_tokens,
                                    self.page_size)
                self.scheds[self._rep(b)].on_spec_trim(adm, keep)
                continue
            self.stats.spec_draft_lookups += 1
            draft = self.draft_sources[self._rep(b)].draft(
                effective_prompt(req), k)
            # cap to writable coverage: verify writes KV at pos..pos+kd,
            # which must stay inside the slot's pages and the seq budget
            cov = len(adm.pages) * self.page_size
            kd = min(len(draft), cov - 1 - int(self.pos[b]),
                     self.S - 1 - int(self.pos[b]))
            if kd <= 0:
                self.spec_miss[b] += 1
                continue
            self.stats.spec_draft_hits += 1
            drafts[b] = [int(t) for t in draft[:kd]]
        return drafts or None

    def _dispatch_verify(self, active: List[int], drafts: dict):
        """One fused verify step scores k+1 positions for every active
        slot (draftless slots ride along as qlen=1 plain decode rows);
        rejection sampling at the collect point then emits 1..kd+1 tokens
        per slot.

        Rollback of rejected-draft KV is pure host bookkeeping: ``pos``
        advances only past emitted tokens, per-query validity masks
        positions >= the current ``pos``, and the next step's write lands
        on position ``pos`` before any read — so the stale KV is never
        observed and the pages stay mapped for reuse."""
        Q = self.speculative + 1
        toks = np.zeros((self.B, Q), np.int32)
        qlen = np.ones(self.B, np.int32)
        pos = np.zeros(self.B, np.int32)
        bt = np.full((self.B, self.n_max_pages), SCRATCH_PAGE, np.int32)
        for b in active:
            d = drafts.get(b, [])
            toks[b, 0] = self.last_token[b]
            toks[b, 1:1 + len(d)] = d
            qlen[b] = len(d) + 1
            pos[b] = self.pos[b]
            bt[b] = self._bt_row(b)
        with self.mesh:
            logits, self.cache = self.verify_fn(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos), jnp.asarray(qlen), jnp.asarray(bt))
        return ("verify", logits,
                [(b, self.admissions[b].req.rid) for b in active], drafts)


def _splice_cache(big, lane, b):
    def leaf(big_l, lane_l):
        return big_l.at[:, b:b + 1].set(lane_l[:, 0:1]) \
            if big_l.ndim >= 2 else big_l
    return jax.tree_util.tree_map(leaf, big, lane)
