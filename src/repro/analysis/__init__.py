"""Static-analysis gate: pluggable checkers over the repo's own source.

The paper's argument is static — inference works because weights and
state *provably* fit the on-chip memories before anything runs.  This
package applies the same discipline to the repo: properties the test
suite only samples dynamically (VMEM budgets, page refcount pairing,
one-compiled-step-per-tick) are verified at every call site on every CI
run.  Entry point: ``scripts/check_static.py``.

Checkers (each a module with ``run() -> (findings, extra)``):

* ``budget``     — Pallas VMEM footprints vs the MCU on-chip budget.
* ``refcount``   — page-pool incref/decref discipline.
* ``trace``      — host-sync / recompile hazards in the serving hot loop.
* ``invariants`` — docstring ``Invariant:`` clauses must name enforcement.

Shared machinery (``core``): fingerprinted findings, ``# repro:
allow[rule-id]`` suppressions, and the ``.static-baseline.json``
strict-on-new-code baseline.
"""
from __future__ import annotations

from repro.analysis import budget, invariants, refcount, trace
from repro.analysis.core import (  # noqa: F401  (public API)
    BASELINE_FILE,
    Finding,
    SourceFile,
    apply_suppressions,
    load_baseline,
    split_by_baseline,
    write_baseline,
)

#: checker name -> (module, rule ids it can emit)
CHECKERS = {
    "budget": (budget, ("pallas-budget", "pallas-bounds",
                        "pallas-divisibility")),
    "refcount": (refcount, ("refcount-leak", "shared-free",
                            "allocator-internals")),
    "trace": (trace, ("host-sync", "missing-donation", "traced-shape",
                      "jit-stability", "async-barrier")),
    "invariants": (invariants, ("invariant-unenforced",
                                "invariant-stale-ref",
                                "invariant-missing")),
}

#: every rule id a finding (or an Enforced-by: analysis:<id> reference)
#: may legitimately use
RULE_IDS = frozenset(
    rid for _, rids in CHECKERS.values() for rid in rids)
