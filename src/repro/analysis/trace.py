"""Trace-hygiene checker: host-sync and recompile hazards in the hot loop.

The engine's throughput story rests on ONE compiled (chunk, decode) step
pair serving every request mix — no per-tick recompiles, no hidden
device->host syncs beyond the explicit ``jax.device_get`` at each step's
single read-back point.  Four static rules plus a runtime harness:

* ``host-sync`` — two scopes.  (a) In ``ServingEngine`` methods reachable
  from the ``run()``/``tick()`` hot loop (computed from the intra-class
  call graph), any ``.item()`` call, or ``float()``/``int()``/
  ``np.asarray()`` applied to a step-function result that was not first
  materialized through ``jax.device_get`` — each is an implicit blocking
  sync the profiler won't attribute.  (b) In ``core/steps.py``'s *traced*
  bodies (functions nested inside the ``make_*_step`` builders), any
  ``.item()``/``float()``/``int()``/``np.asarray()``/``np.array()`` on a
  non-``.shape`` value — on a tracer these either crash or silently
  constant-fold at trace time.
* ``missing-donation`` — every ``jax.jit`` call site in ``serving/`` and
  ``launch/serve.py`` must pass ``donate_argnums``/``donate_argnames``:
  these jits wrap step functions that thread the multi-MB cache through
  every tick, and the seed's train/dryrun paths set the donation
  precedent (launch/train.py, launch/dryrun.py).  Without donation the
  pool is double-buffered across every step call.
* ``traced-shape`` — a call to a jitted step attribute (``self.*_fn``)
  whose argument contains a slice with a non-constant Python bound: the
  bound becomes part of the traced shape, so every distinct value
  recompiles (the paged engine exists to avoid exactly this).
* ``async-barrier`` — the pipelined engine's overlap contract: in
  ``ServingEngine`` methods reachable from the plan/dispatch phases
  (``_plan_phase``/``_dispatch_phase``, via the intra-class call graph),
  any ``jax.device_get``, ``.block_until_ready()`` or ``.item()`` — a
  host barrier there serializes the host against the device mid-pipeline,
  silently destroying the one-tick-ahead overlap.  Barriers belong only
  at collect points (``_collect_phase``, which the rule does not scan).
  Scope note: the rule names the three explicit barrier forms;
  ``np.asarray`` on a device value also syncs but is covered by the
  ``host-sync`` taint rule where it matters (step-function results).

Runtime harness (``run_recompile_harness``): builds a tiny paged engine on
the paper's TinyLlama config, drives a mixed-length request batch to
completion tick by tick, and asserts every jitted step function gains
ZERO new jit cache entries after the first tick that used it.  (The
first use itself may insert two entries — the initial call sees
uncommitted host arrays while every later call sees the step's own
committed output — so the contract is no *growth* after first use, which
is exactly what a per-length retrace would violate.)
"""
from __future__ import annotations

import ast

from repro.analysis.core import iter_sources, scope_name

TARGETS = ["src/repro/serving", "src/repro/launch/serve.py",
           "src/repro/core/steps.py"]
ENGINE_PATH = "src/repro/serving/engine.py"
DONATION_PATHS = ("src/repro/serving/", "src/repro/launch/serve.py")
HOT_ROOTS = {"run", "tick"}
ASYNC_ROOTS = {"_plan_phase", "_dispatch_phase"}
HOST_CONVERTERS = {"float", "int"}
NP_CONVERTERS = {"asarray", "array"}


def _attr_chain(node) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_step_fn_attr(func) -> bool:
    return isinstance(func, ast.Attribute) and func.attr.endswith("_fn")


def _contains_device_get(node) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and \
                _attr_chain(n.func).endswith("device_get"):
            return True
    return False


# ---------------------------------------------------------------------------
# engine hot loop
# ---------------------------------------------------------------------------

def _reachable_methods(cls: ast.ClassDef, roots) -> dict:
    """Methods transitively reachable from ``roots`` via self.X() calls.
    -> {name: FunctionDef}."""
    methods = {n.name: n for n in cls.body
               if isinstance(n, ast.FunctionDef)}
    edges = {}
    for name, fn in methods.items():
        out = set()
        for n in ast.walk(fn):
            if isinstance(n, ast.Call) and \
                    isinstance(n.func, ast.Attribute) and \
                    isinstance(n.func.value, ast.Name) and \
                    n.func.value.id == "self" and \
                    n.func.attr in methods:
                out.add(n.func.attr)
        edges[name] = out
    seen = set()
    frontier = [r for r in roots if r in methods]
    while frontier:
        m = frontier.pop()
        if m in seen:
            continue
        seen.add(m)
        frontier.extend(edges[m] - seen)
    return {m: methods[m] for m in seen}


def _engine_hot_methods(cls: ast.ClassDef) -> dict:
    """Methods transitively reachable from run()/tick() via self.X() calls.
    -> {name: FunctionDef}."""
    return _reachable_methods(cls, HOT_ROOTS)


def _scan_hot_method(src, cls_name, fn, findings):
    scope = f"{cls_name}.{fn.name}"
    # names bound from step-function calls: logits, self.cache = self.X_fn()
    tainted = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call) \
                and _is_step_fn_attr(n.value.func):
            for t in n.targets:
                elts = t.elts if isinstance(t, ast.Tuple) else [t]
                for e in elts:
                    if isinstance(e, ast.Name):
                        tainted.add(e.id)
    for n in ast.walk(fn):
        if not isinstance(n, ast.Call):
            continue
        if isinstance(n.func, ast.Attribute) and n.func.attr == "item":
            findings.append(src.finding(
                "host-sync", n,
                ".item() in the engine hot loop blocks on the device "
                "per element — batch through one jax.device_get instead",
                scope))
            continue
        chain = _attr_chain(n.func)
        is_conv = (isinstance(n.func, ast.Name)
                   and n.func.id in HOST_CONVERTERS) or \
            (chain.startswith("np.") and chain.split(".")[-1]
             in NP_CONVERTERS)
        if not (is_conv and n.args):
            continue
        arg = n.args[0]
        arg_names = {x.id for x in ast.walk(arg) if isinstance(x, ast.Name)}
        if arg_names & tainted and not _contains_device_get(arg):
            findings.append(src.finding(
                "host-sync", n,
                f"{chain or n.func.id}(...) on a step-function result "
                f"without jax.device_get — an implicit blocking sync in "
                f"the per-tick path", scope))
        # traced-shape: self.*_fn(... x[:, :S] ...) with a variable bound
    for n in ast.walk(fn):
        if not (isinstance(n, ast.Call) and _is_step_fn_attr(n.func)):
            continue
        for a in n.args:
            for s in ast.walk(a):
                if not isinstance(s, ast.Subscript):
                    continue
                slices = s.slice.elts if isinstance(s.slice, ast.Tuple) \
                    else [s.slice]
                for sl in slices:
                    if isinstance(sl, ast.Slice) and any(
                            b is not None and not isinstance(b, ast.Constant)
                            for b in (sl.lower, sl.upper)):
                        findings.append(src.finding(
                            "traced-shape", s,
                            f"argument of {_attr_chain(n.func)}(...) is "
                            f"sliced by a per-request Python value — the "
                            f"bound becomes a traced shape and every "
                            f"distinct value recompiles the step", scope))


def _scan_async_method(src, cls_name, fn, findings):
    """Flag host barriers inside the plan/dispatch closure — between
    dispatching a tick's steps and the next plan phase, the host must
    never block on the device (barriers belong at collect points)."""
    scope = f"{cls_name}.{fn.name}"
    for n in ast.walk(fn):
        if isinstance(n, ast.Call):
            chain = _attr_chain(n.func)
            if chain.endswith("device_get"):
                findings.append(src.finding(
                    "async-barrier", n,
                    "jax.device_get in the plan/dispatch path blocks the "
                    "host on in-flight device work — read results at the "
                    "collect point instead", scope))
                continue
            if isinstance(n.func, ast.Attribute) and \
                    n.func.attr in ("block_until_ready", "item"):
                findings.append(src.finding(
                    "async-barrier", n,
                    f".{n.func.attr}() in the plan/dispatch path is a "
                    f"host barrier mid-pipeline — it serializes planning "
                    f"against the dispatched step and destroys the "
                    f"one-tick-ahead overlap", scope))


def _scan_engine(src, findings):
    for node in src.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == "ServingEngine":
            for _, fn in sorted(_engine_hot_methods(node).items()):
                _scan_hot_method(src, node.name, fn, findings)
            for _, fn in sorted(_reachable_methods(node,
                                                   ASYNC_ROOTS).items()):
                _scan_async_method(src, node.name, fn, findings)


# ---------------------------------------------------------------------------
# traced bodies in core/steps.py
# ---------------------------------------------------------------------------

def _scan_traced_bodies(src, findings):
    for node in src.tree.body:
        if not (isinstance(node, ast.FunctionDef)
                and node.name.startswith("make_")):
            continue
        for inner in ast.walk(node):
            if not (isinstance(inner, ast.FunctionDef)
                    and inner is not node):
                continue
            scope = f"{node.name}.{inner.name}"
            for n in ast.walk(inner):
                if not isinstance(n, ast.Call):
                    continue
                chain = _attr_chain(n.func)
                bad = (isinstance(n.func, ast.Attribute)
                       and n.func.attr == "item") or \
                    (chain.startswith("np.")
                     and chain.split(".")[-1] in NP_CONVERTERS)
                if not bad or not (n.args or isinstance(n.func,
                                                        ast.Attribute)):
                    continue
                probe = n.args[0] if n.args else n.func.value
                txt = ast.unparse(probe)
                if txt.endswith((".shape", ".size", ".ndim", ".dtype")):
                    continue   # static metadata, not a tracer read
                findings.append(src.finding(
                    "host-sync", n,
                    f"{chain}(...) inside a traced step body — on a "
                    f"tracer this crashes or constant-folds at trace "
                    f"time", scope))


# ---------------------------------------------------------------------------
# donation at jit call sites
# ---------------------------------------------------------------------------

def _scan_donation(src, findings):
    if not any(src.path == p or src.path.startswith(p)
               for p in DONATION_PATHS):
        return

    class V(ast.NodeVisitor):
        def __init__(self):
            self.stack = []

        def visit_FunctionDef(self, node):
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_ClassDef(self, node):
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        def visit_Call(self, node):
            if _attr_chain(node.func) == "jax.jit":
                kw = {k.arg for k in node.keywords}
                if not kw & {"donate_argnums", "donate_argnames"}:
                    findings.append(src.finding(
                        "missing-donation", node,
                        "jax.jit without donate_argnums: the cache "
                        "argument is threaded through every tick and gets "
                        "double-buffered without donation (precedent: "
                        "launch/train.py, launch/dryrun.py)",
                        scope_name(self.stack)))
            self.generic_visit(node)

    V().visit(src.tree)


def scan_source(src) -> list:
    findings = []
    if src.path == ENGINE_PATH:
        _scan_engine(src, findings)
    if src.path.endswith("core/steps.py"):
        _scan_traced_bodies(src, findings)
    _scan_donation(src, findings)
    return findings


def run(sources=None):
    sources = sources if sources is not None else iter_sources(TARGETS)
    findings = []
    for src in sources:
        findings.extend(scan_source(src))
    return findings, None


# ---------------------------------------------------------------------------
# runtime harness: zero recompiles across a mixed-length serving run
# ---------------------------------------------------------------------------

def run_recompile_harness(max_ticks: int = 200, verbose=print) -> list:
    """Drive a tiny paged engine (paper TinyLlama config, reduced dims)
    over mixed prompt lengths tick by tick and assert no jitted step
    function gains jit cache entries after the tick that first used it.
    -> list of Finding (empty = clean)."""
    import numpy as np

    from repro import compat
    from repro.analysis.core import Finding
    from repro.configs import get_config, reduced
    from repro.core import model
    from repro.core.partition import ShardingPlan
    from repro.serving import Request, ServingEngine

    import jax

    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    plan = ShardingPlan(tp=1, kv_cache_dtype="float32")
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
    params = model.init_params(cfg, plan)
    eng = ServingEngine.build_paged(cfg, plan, mesh, 2, 32, params,
                                    page_size=8, prefill_chunk=8)
    rng = np.random.RandomState(0)
    for rid, L in enumerate([3, 7, 12, 5, 17, 9]):   # mixed lengths
        eng.submit(Request(
            rid=rid, prompt=rng.randint(2, cfg.vocab_size, L)
            .astype(np.int32), max_new_tokens=4))

    fns = {"prefill_fn (chunk)": eng.prefill_fn,
           "decode_fn": eng.decode_fn}
    if eng.copy_fn is not None:
        fns["copy_fn"] = eng.copy_fn
    if eng.verify_fn is not None:
        fns["verify_fn"] = eng.verify_fn

    def sizes():
        return {name: getattr(fn, "_cache_size", lambda: -1)()
                for name, fn in fns.items()}

    first_use = {}          # name -> (tick, entries when first used)
    grew = {}               # name -> (tick, from, to)
    for t in range(max_ticks):
        if not (eng.has_pending()
                or any(a is not None for a in eng.admissions)):
            break
        eng.tick()
        for name, size in sizes().items():
            if size <= 0:
                continue
            if name not in first_use:
                first_use[name] = (t, size)
            elif size > first_use[name][1] and name not in grew:
                grew[name] = (t, first_use[name][1], size)

    findings = []
    for name, (t0, base) in sorted(first_use.items()):
        cur = sizes()[name]
        verbose(f"  {name}: first used tick {t0} ({base} jit cache "
                f"entr{'y' if base == 1 else 'ies'}), final {cur}")
        if name in grew:
            t, frm, to = grew[name]
            findings.append(Finding(
                rule="jit-stability", path=ENGINE_PATH, line=0,
                message=f"{name} retraced mid-run: {frm} jit cache "
                        f"entries after first use (tick {t0}) grew to "
                        f"{to} at tick {t} — the one-compiled-step-per-"
                        f"tick contract is broken", scope="harness",
                snippet=name))
    return findings
