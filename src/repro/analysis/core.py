"""Shared machinery for the static-analysis gate (``scripts/check_static.py``).

Every checker produces ``Finding`` records; this module owns the three
things all of them share:

* **Fingerprints** — a finding is identified by (rule, file, enclosing
  scope, normalized source line), NOT by line number, so baselines survive
  unrelated edits above the flagged line.
* **Suppressions** — ``# repro: allow[rule-id]`` on the flagged line (or
  the line directly above it) waives that rule there.  ``allow[*]`` waives
  every rule.  Suppressions are for reviewed, justified exceptions — the
  comment should say why.
* **Baseline** — ``.static-baseline.json`` at the repo root lists known
  findings (fingerprint + justification) so the gate is strict on new
  code: a finding matching a baseline entry passes, anything else fails.
  ``--strict`` additionally fails on *stale* baseline entries (entries no
  longer matched by any finding) so the baseline only ever shrinks.
"""
from __future__ import annotations

import ast
import hashlib
import json
import os
import re
from dataclasses import dataclass, field

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
BASELINE_FILE = ".static-baseline.json"

_ALLOW = re.compile(r"#\s*repro:\s*allow\[([a-z*][a-z0-9_,* -]*)\]")


@dataclass
class Finding:
    rule: str                 # e.g. "refcount-leak"
    path: str                 # repo-relative
    line: int                 # 1-based
    message: str
    scope: str = "<module>"   # enclosing function/class qualname
    snippet: str = ""         # stripped source of the flagged line

    @property
    def fingerprint(self) -> str:
        key = f"{self.rule}|{self.path}|{self.scope}|{self.snippet}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.message} "
                f"(fingerprint {self.fingerprint})")


@dataclass
class SourceFile:
    """One parsed python file plus the lookup tables checkers need."""
    path: str                 # repo-relative, forward slashes
    text: str
    tree: ast.AST
    lines: list = field(default_factory=list)

    @classmethod
    def load(cls, abspath: str) -> "SourceFile":
        rel = os.path.relpath(abspath, REPO_ROOT).replace(os.sep, "/")
        text = open(abspath, encoding="utf-8").read()
        return cls(path=rel, text=text, tree=ast.parse(text),
                   lines=text.splitlines())

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def allowed_rules(self, line: int) -> set:
        """Union of allow[...] ids on ``line`` and the line above it."""
        out: set = set()
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                for m in _ALLOW.finditer(self.lines[ln - 1]):
                    out.update(p.strip() for p in m.group(1).split(","))
        return out

    def finding(self, rule: str, node, message: str,
                scope: str = "<module>") -> Finding:
        line = getattr(node, "lineno", 0) if not isinstance(node, int) \
            else node
        return Finding(rule=rule, path=self.path, line=line, message=message,
                       scope=scope, snippet=self.snippet(line))


def iter_sources(rel_targets) -> list:
    """Load every .py under the given repo-relative files/directories."""
    out = []
    for rel in rel_targets:
        root = os.path.join(REPO_ROOT, rel)
        if os.path.isfile(root):
            out.append(SourceFile.load(root))
            continue
        for dirpath, _, names in sorted(os.walk(root)):
            for name in sorted(names):
                if name.endswith(".py"):
                    out.append(SourceFile.load(os.path.join(dirpath, name)))
    return out


def scope_name(stack) -> str:
    """Qualname-ish scope from a stack of FunctionDef/ClassDef nodes."""
    return ".".join(n.name for n in stack) or "<module>"


def apply_suppressions(findings, sources_by_path) -> list:
    kept = []
    for f in findings:
        src = sources_by_path.get(f.path)
        allowed = src.allowed_rules(f.line) if src else set()
        if f.rule in allowed or "*" in allowed:
            continue
        kept.append(f)
    return kept


def load_baseline(path=None) -> dict:
    """-> {fingerprint: justification}."""
    path = path or os.path.join(REPO_ROOT, BASELINE_FILE)
    if not os.path.exists(path):
        return {}
    data = json.load(open(path, encoding="utf-8"))
    return {e["fingerprint"]: e.get("justification", "")
            for e in data.get("entries", [])}


def write_baseline(findings, path=None):
    path = path or os.path.join(REPO_ROOT, BASELINE_FILE)
    entries = [{"fingerprint": f.fingerprint, "rule": f.rule,
                "where": f"{f.path}:{f.scope}", "snippet": f.snippet,
                "justification": "TODO: justify or fix"}
               for f in sorted(findings, key=lambda f: (f.path, f.line))]
    json.dump({"version": 1, "entries": entries},
              open(path, "w", encoding="utf-8"), indent=2)


def split_by_baseline(findings, baseline) -> tuple:
    """-> (new_findings, baselined_findings, stale_fingerprints)."""
    new, known = [], []
    seen = set()
    for f in findings:
        if f.fingerprint in baseline:
            known.append(f)
            seen.add(f.fingerprint)
        else:
            new.append(f)
    stale = sorted(set(baseline) - seen)
    return new, known, stale
