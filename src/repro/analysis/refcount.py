"""Refcount-discipline checker for the page-pool allocator protocol.

The paged serving stack keeps pages alive by reference counting
(``core.kvcache.PageAllocator``): slots, the radix prefix cache and the
cross-KV cache each hold one ref per page, and a page returns to the free
list exactly when its last ref drops.  Three AST rules keep every call
site honest (scanned: ``core/kvcache.py``, ``serving/``,
``core/steps.py``):

* ``refcount-leak`` — every ``incref(x)`` inside a function must be
  matched, somewhere in the same function, by either a *release* of ``x``
  (``decref``/``free``/``trim`` mentioning the same base variable — the
  rollback/exception arms count) or an *escape* (``x`` is returned, stored
  into an attribute/container, or passed to another call — i.e. the ref's
  ownership moves to a live structure that releases it later, e.g. an
  ``Admission`` record or the radix tree).  ``kvcache.handoff_refs`` is a
  recognized RELEASE, not a mere escape: it drops the source allocator's
  ref per page as the atomic cross-replica ownership move (disagg
  handoffs, drain-time migrations); host-spill writes
  (``HostSpillStore.put_prefix``/``put_cross``) copy payload bytes and
  take no refs, so they fall under ordinary escape handling.  A ref that neither escapes nor
  is released is unreachable and leaks its pages.  The analysis is
  intraprocedural and line-insensitive by design: it never false-positives
  on the scheduler's rollback arms, at the cost of trusting that an
  escaped ref's owner has its own release path (those owners are scanned
  too).
* ``shared-free`` — ``free()`` on a *page* allocator asserts sole
  ownership, so calling it on pages that may be cache-shared crashes (or,
  without the assert, would corrupt shared state).  Any ``<alloc>.free(x)``
  where ``x`` was not just allocated in the same function must be
  ``decref`` (or carry an allow comment).  Slab allocators are exempt —
  slabs are exclusive by construction.
* ``allocator-internals`` — the allocator's free list / refcounts
  (``_free``, ``_rc``, ``_free_set``, ``_scale_dirty``) are mutated only
  inside ``core/kvcache.py``; any store or mutating call on them elsewhere
  bypasses the double-free/scale-hygiene machinery.
"""
from __future__ import annotations

import ast

from repro.analysis.core import iter_sources, scope_name

TARGETS = ["src/repro/core/kvcache.py", "src/repro/serving",
           "src/repro/core/steps.py"]
ALLOCATOR_MODULE = "src/repro/core/kvcache.py"
RELEASE_METHODS = {"decref", "free", "trim"}
# plain functions that RELEASE their page arguments: handoff_refs moves
# ownership across allocators, dropping the source ref per page
RELEASE_FUNCS = {"handoff_refs"}
INTERNAL_ATTRS = {"_free", "_rc", "_free_set", "_scale_dirty"}
MUTATING_METHODS = {"append", "pop", "add", "remove", "discard", "clear",
                    "extend", "update", "insert", "difference_update"}


def _base_names(node) -> set:
    """Leftmost Name identifiers reachable in an expression — the variables
    through which a page list is held (``leaf.pages`` -> {leaf})."""
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
    return out


def _recv_chain(func) -> str:
    """Dotted receiver of a method call: ``self.allocator.incref`` ->
    ``self.allocator``."""
    parts = []
    n = func.value if isinstance(func, ast.Attribute) else None
    while isinstance(n, ast.Attribute):
        parts.append(n.attr)
        n = n.value
    if isinstance(n, ast.Name):
        parts.append(n.id)
    return ".".join(reversed(parts))


def _method_name(call) -> str:
    return call.func.attr if isinstance(call.func, ast.Attribute) else ""


def _is_page_allocator(recv: str) -> bool:
    r = recv.lower()
    return ("alloc" in r or r.endswith("allocator")) and "slab" not in r


class _FnScan(ast.NodeVisitor):
    """One pass over a function body collecting the refcount events."""

    def __init__(self):
        self.increfs = []      # (node, base-name set, arg source)
        self.released: set = set()
        self.escaped: set = set()
        self.frees = []        # (node, base-name set, receiver)
        self.fresh: set = set()  # names assigned from <alloc>.alloc(...)

    # pure observers: passing a ref here moves no ownership
    _OBSERVERS = {"len", "sorted", "min", "max", "sum", "enumerate", "range",
                  "print", "isinstance", "pages_needed", "assert"}

    def visit_Call(self, node):
        meth = _method_name(node)
        arg_names = set()
        for a in list(node.args) + [k.value for k in node.keywords]:
            arg_names |= _base_names(a)
        arg_names.discard("self")
        fname = node.func.id if isinstance(node.func, ast.Name) else ""
        if meth == "incref":
            self.increfs.append((node, arg_names))
        elif meth in RELEASE_FUNCS or fname in RELEASE_FUNCS:
            self.released |= arg_names
        elif meth in RELEASE_METHODS:
            self.released |= arg_names
            if meth == "free":
                recv = _recv_chain(node.func)
                if _is_page_allocator(recv):
                    self.frees.append((node, arg_names, recv))
        elif not (isinstance(node.func, ast.Name)
                  and node.func.id in self._OBSERVERS):
            # ownership handed to another structure/function
            self.escaped |= arg_names
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        pass               # nested defs are scanned as their own scope

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Return(self, node):
        if node.value is not None:
            self.escaped |= _base_names(node.value)
        self.generic_visit(node)

    def visit_Assign(self, node):
        for t in node.targets:
            if isinstance(t, (ast.Attribute, ast.Subscript)):
                self.escaped |= _base_names(node.value)
        val = node.value
        if isinstance(val, ast.Call) and _method_name(val) == "alloc":
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.fresh.add(t.id)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        if isinstance(node.target, (ast.Attribute, ast.Subscript)):
            self.escaped |= _base_names(node.value)
        self.generic_visit(node)


def _scan_function(src, fn, stack, findings):
    scan = _FnScan()
    for stmt in fn.body:
        scan.visit(stmt)
    scope = scope_name(stack)
    for node, names in scan.increfs:
        names = {n for n in names if n != "self"}
        if not names:
            continue       # attribute-rooted (self....): reachable by owner
        if names & (scan.released | scan.escaped):
            continue
        held = ", ".join(sorted(names))
        findings.append(src.finding(
            "refcount-leak", node,
            f"incref({held}) has no matching decref/free/trim and never "
            f"escapes this function — the ref (and its pages) leaks",
            scope))
    for node, names, recv in scan.frees:
        if names and names <= scan.fresh:
            continue       # freeing pages allocated in this very function
        findings.append(src.finding(
            "shared-free", node,
            f"{recv}.free({', '.join(sorted(names)) or '...'}) on pages "
            f"that may be cache-shared — free() asserts sole ownership; "
            f"use decref() for multi-ref releases", scope))


def _scan_internals(src, findings):
    """allocator-internals: flag mutations of allocator private state."""
    class V(ast.NodeVisitor):
        def __init__(self):
            self.stack = []

        def _flag(self, node, what):
            findings.append(src.finding(
                "allocator-internals", node,
                f"{what} mutates allocator-private state outside "
                f"core/kvcache.py — go through alloc/incref/decref/"
                f"free/trim", scope_name(self.stack)))

        def visit_FunctionDef(self, node):
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_ClassDef(self, node):
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        def _internal_attr(self, node) -> str:
            for n in ast.walk(node):
                if isinstance(n, ast.Attribute) and n.attr in INTERNAL_ATTRS:
                    return n.attr
            return ""

        def visit_Assign(self, node):
            for t in node.targets:
                a = self._internal_attr(t)
                if a:
                    self._flag(node, f"assignment to .{a}")
            self.generic_visit(node)

        def visit_AugAssign(self, node):
            a = self._internal_attr(node.target)
            if a:
                self._flag(node, f"augmented assignment to .{a}")
            self.generic_visit(node)

        def visit_Call(self, node):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in MUTATING_METHODS:
                a = self._internal_attr(f.value)
                if a:
                    self._flag(node, f".{a}.{f.attr}(...)")
            self.generic_visit(node)

    V().visit(src.tree)


def scan_source(src) -> list:
    findings = []

    class W(ast.NodeVisitor):
        def __init__(self):
            self.stack = []

        def visit_ClassDef(self, node):
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        def visit_FunctionDef(self, node):
            self.stack.append(node)
            _scan_function(src, node, self.stack, findings)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

    W().visit(src.tree)
    if src.path != ALLOCATOR_MODULE:
        _scan_internals(src, findings)
    return findings


def run(sources=None):
    sources = sources if sources is not None else iter_sources(TARGETS)
    findings = []
    for src in sources:
        findings.extend(scan_source(src))
    return findings, None
