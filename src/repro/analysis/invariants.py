"""Docstring-invariant cross-checker.

PR 5 wrote the serving-stack invariants into module docstrings as prose.
This checker turns them into a machine-checked contract: each invariant
is a docstring clause of the form::

    Invariant: <one-line statement of the property>
    Enforced-by: tests/test_x.py::test_name, analysis:<rule-id>

and the gate verifies every clause names at least one *live* enforcement
point.  Three rules:

* ``invariant-missing`` — a module on the required list (the serving
  stack plus the allocator) has no ``Invariant:`` clause at all.  The
  invariants exist — PR 5 wrote them — so an empty module means they were
  deleted or never converted.
* ``invariant-unenforced`` — an ``Invariant:`` clause with no
  ``Enforced-by:`` line on the next non-blank docstring line.  Prose
  without an enforcement pointer is exactly the hand-maintained state
  this PR retires.
* ``invariant-stale-ref`` — an ``Enforced-by:`` reference that no longer
  resolves: the test file is gone, the named ``def test_...`` is gone, or
  the ``analysis:<rule-id>`` names a checker rule that does not exist.
  This is how a refactor that silently drops a guarding test gets caught.
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.core import REPO_ROOT, iter_sources

REQUIRED_MODULES = [
    "src/repro/serving/engine.py",
    "src/repro/serving/scheduler.py",
    "src/repro/serving/prefix_cache.py",
    "src/repro/serving/policies.py",
    "src/repro/serving/router.py",
    "src/repro/core/kvcache.py",
]
TARGETS = list(REQUIRED_MODULES)

_INVARIANT = re.compile(r"^\s*Invariant:\s*(.+)$")
_ENFORCED = re.compile(r"^\s*Enforced-by:\s*(.+)$")
_TEST_REF = re.compile(r"^(tests/[\w./-]+\.py)::(\w+)$")
_RULE_REF = re.compile(r"^analysis:([a-z][a-z0-9-]*)$")


def _docstring_clauses(src):
    """Parse Invariant/Enforced-by pairs out of the module docstring.
    -> list of (lineno, invariant_text, [refs] | None)."""
    doc_node = None
    if src.tree.body and isinstance(src.tree.body[0], ast.Expr) and \
            isinstance(src.tree.body[0].value, ast.Constant) and \
            isinstance(src.tree.body[0].value.value, str):
        doc_node = src.tree.body[0]
    if doc_node is None:
        return []
    start = doc_node.lineno        # 1-based first line of the docstring
    doc_lines = src.lines[start - 1:doc_node.end_lineno]
    clauses = []
    i = 0
    while i < len(doc_lines):
        m = _INVARIANT.match(doc_lines[i])
        if not m:
            i += 1
            continue
        lineno = start + i
        text = m.group(1).strip()
        refs = None
        j = i + 1
        # an Enforced-by: line may follow directly or after continuation
        # lines of the invariant text (indented, no blank line between)
        while j < len(doc_lines) and doc_lines[j].strip():
            em = _ENFORCED.match(doc_lines[j])
            if em:
                refs = [r.strip() for r in em.group(1).split(",")
                        if r.strip()]
                break
            if _INVARIANT.match(doc_lines[j]):
                break
            j += 1
        clauses.append((lineno, text, refs))
        i = j if refs is None else j + 1
    return clauses


def _test_has_def(abspath: str, name: str) -> bool:
    try:
        tree = ast.parse(open(abspath, encoding="utf-8").read())
    except (OSError, SyntaxError):
        return False
    return any(isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
               and n.name == name for n in ast.walk(tree))


def _check_ref(ref: str, rule_ids) -> str:
    """-> '' if the reference resolves, else a reason string."""
    m = _TEST_REF.match(ref)
    if m:
        relpath, test = m.group(1), m.group(2)
        abspath = os.path.join(REPO_ROOT, relpath)
        if not os.path.exists(abspath):
            return f"test file {relpath} does not exist"
        if not _test_has_def(abspath, test):
            return f"{relpath} has no test named {test}"
        return ""
    m = _RULE_REF.match(ref)
    if m:
        if m.group(1) not in rule_ids:
            return f"no checker rule named {m.group(1)!r}"
        return ""
    return ("unrecognized reference (expected tests/<file>.py::<test> "
            "or analysis:<rule-id>)")


def scan_source(src, rule_ids) -> list:
    findings = []
    clauses = _docstring_clauses(src)
    if not clauses:
        if src.path in REQUIRED_MODULES:
            findings.append(src.finding(
                "invariant-missing", 1,
                "module docstring declares no Invariant: clauses — the "
                "serving invariants from PR 5 must be stated as "
                "machine-checked clauses here"))
        return findings
    for lineno, text, refs in clauses:
        label = text if len(text) <= 60 else text[:57] + "..."
        if refs is None:
            findings.append(src.finding(
                "invariant-unenforced", lineno,
                f"Invariant {label!r} has no Enforced-by: line — name the "
                f"test(s) or analysis:<rule-id> that enforce it"))
            continue
        for ref in refs:
            reason = _check_ref(ref, rule_ids)
            if reason:
                findings.append(src.finding(
                    "invariant-stale-ref", lineno,
                    f"Invariant {label!r}: Enforced-by reference "
                    f"{ref!r} is stale — {reason}"))
    return findings


def run(sources=None, rule_ids=None):
    if rule_ids is None:
        from repro.analysis import RULE_IDS
        rule_ids = RULE_IDS
    sources = sources if sources is not None else iter_sources(TARGETS)
    findings = []
    for src in sources:
        findings.extend(scan_source(src, rule_ids))
    return findings, None
