"""Pallas VMEM-budget checker: prove each kernel's working set fits on-chip.

The paper's core argument is static: inference works because weights and
state provably fit the stationary on-chip memories *before* anything runs.
This checker applies the same discipline to the repo's Pallas kernels — for
every kernel in ``repro.kernels`` it derives the per-invocation VMEM
footprint from the actual BlockSpecs/grid/dtypes the kernel builds at
representative shapes (the paper's own workloads, ``configs/paper_models``),
and asserts it fits a configurable on-chip budget.

Capture works by monkeypatching ``pl.pallas_call`` while invoking each
kernel's *unjitted* wrapper (``fn.__wrapped__``) eagerly with concrete
inputs: the wrapper runs its real padding/grid/BlockSpec logic, the patched
``pallas_call`` records everything and returns zeros of ``out_shape``, and
no kernel ever executes.  Three rules:

* ``pallas-budget`` — footprint = 2 x (sum of streamed in/out block bytes)
  + scratch bytes must fit the budget.  The factor 2 models the grid
  pipeline's double buffering (next block's DMA in flight while the current
  one computes); scratch is single-buffered (it persists across grid
  steps); SMEM blocks (scalars) are excluded.  The default budget is the
  paper MCU's usable on-chip SRAM, ``SiracusaConfig().onchip_budget``
  (budget_fraction x (L1 + L2)) — the same number the analytical sim holds
  resident weights to.
* ``pallas-bounds`` — every BlockSpec index map is re-evaluated at concrete
  grid points (with the real scalar-prefetch operands, e.g. block tables),
  and the resulting block offsets must stay inside the padded operand.
* ``pallas-divisibility`` — each blocked dim of the (padded) operand must
  divide by its block extent, so no grid step reads a ragged tail.

The per-kernel table lands in ``BUDGET_vmem.json`` next to the bench
artifacts (CI uploads it); rerun via ``scripts/check_static.py``.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.analysis.core import Finding

KERNELS_PATH = "src/repro/kernels"


@dataclass
class BlockInfo:
    role: str                # "in" / "out"
    block_shape: tuple
    array_shape: tuple
    dtype_size: int
    smem: bool
    index_map: object = None

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.block_shape:
            n *= int(d) if d is not None else 1
        return n * self.dtype_size


@dataclass
class CapturedCall:
    name: str                # "<kernel>[<shape label>]"
    kernel_file: str         # repo-relative source of the wrapper
    grid: tuple
    blocks: list = field(default_factory=list)
    scratch_bytes: int = 0
    scalar_args: tuple = ()  # concrete scalar-prefetch operands (np arrays)

    def vmem_bytes(self) -> int:
        streamed = sum(b.nbytes for b in self.blocks if not b.smem)
        return 2 * streamed + self.scratch_bytes


def _scratch_nbytes(shapes) -> int:
    total = 0
    for s in shapes or ():
        shape = tuple(getattr(s, "shape", ()))
        dt = np.dtype(getattr(s, "dtype", np.float32))
        n = 1
        for d in shape:
            n *= int(d)
        total += n * dt.itemsize
    return total


def _is_smem(spec) -> bool:
    return "smem" in str(getattr(spec, "memory_space", "")).lower()


def capture_invocation(label, kernel_file, fn, *args, **kwargs):
    """Run ``fn`` (the unjitted kernel wrapper) with ``pl.pallas_call``
    patched to record grid/BlockSpecs/scratch instead of compiling.
    -> list of CapturedCall (one per pallas_call the wrapper made)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    captured = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *, grid=None, in_specs=None, out_specs=None,
                         out_shape=None, scratch_shapes=(), grid_spec=None,
                         interpret=False, **kw):
        n_prefetch = 0
        if grid_spec is not None:
            grid = tuple(grid_spec.grid)
            in_specs = list(grid_spec.in_specs)
            out_specs = grid_spec.out_specs
            scratch_shapes = getattr(grid_spec, "scratch_shapes", ())
            n_prefetch = int(getattr(grid_spec, "num_scalar_prefetch", 0))

        def runner(*inputs):
            scalar = tuple(np.asarray(x) for x in inputs[:n_prefetch])
            arrays = inputs[n_prefetch:]
            call = CapturedCall(name=label, kernel_file=kernel_file,
                                grid=tuple(grid), scalar_args=scalar,
                                scratch_bytes=_scratch_nbytes(scratch_shapes))
            for spec, arr in zip(in_specs, arrays, strict=True):
                call.blocks.append(BlockInfo(
                    role="in", block_shape=tuple(spec.block_shape),
                    array_shape=tuple(arr.shape),
                    dtype_size=np.dtype(arr.dtype).itemsize,
                    smem=_is_smem(spec), index_map=spec.index_map))
            outs = out_shape if isinstance(out_shape, (tuple, list)) \
                else [out_shape]
            specs = out_specs if isinstance(out_specs, (tuple, list)) \
                else [out_specs]
            for spec, sds in zip(specs, outs, strict=True):
                call.blocks.append(BlockInfo(
                    role="out", block_shape=tuple(spec.block_shape),
                    array_shape=tuple(sds.shape),
                    dtype_size=np.dtype(sds.dtype).itemsize,
                    smem=_is_smem(spec), index_map=spec.index_map))
            captured.append(call)
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        fn(*args, **kwargs)
    finally:
        pl.pallas_call = real
    return captured


def _grid_points(grid, cap=4096):
    total = 1
    for g in grid:
        total *= int(g)
    if total <= cap:
        return itertools.product(*(range(int(g)) for g in grid))
    # corners + an evenly strided sample along each axis
    axes = [sorted({0, int(g) - 1, int(g) // 2}) for g in grid]
    return itertools.product(*axes)


def check_call(call: CapturedCall, budget: int) -> list:
    """Budget / bounds / divisibility findings for one captured call."""
    findings = []

    def mk(rule, msg):
        findings.append(Finding(rule=rule, path=call.kernel_file, line=0,
                                message=msg, scope=call.name,
                                snippet=call.name))

    used = call.vmem_bytes()
    if used > budget:
        mk("pallas-budget",
           f"VMEM footprint {used} bytes exceeds on-chip budget {budget} "
           f"(grid {call.grid}; 2x streamed blocks + scratch)")
    for b in call.blocks:
        if b.smem:
            continue
        ndim = len(b.block_shape)
        arr = b.array_shape[-ndim:] if ndim <= len(b.array_shape) \
            else b.array_shape
        for d, (bs, asz) in enumerate(zip(b.block_shape, arr, strict=True)):
            if bs is None:
                continue
            if int(asz) % int(bs) != 0:
                mk("pallas-divisibility",
                   f"{b.role} operand dim {d}: array extent {asz} not "
                   f"divisible by block extent {bs}")
    n_bounds_before = len(findings)
    for pt in _grid_points(call.grid):
        for b in call.blocks:
            if b.smem or b.index_map is None:
                continue
            try:
                idx = b.index_map(*pt, *call.scalar_args)
            except Exception as e:  # index map itself is broken
                mk("pallas-bounds",
                   f"{b.role} index map raised at grid point {pt}: {e!r}")
                continue
            idx = tuple(int(i) for i in np.atleast_1d(np.asarray(idx)))
            ndim = len(b.block_shape)
            arr = b.array_shape[-ndim:]
            for d, (i, bs, asz) in enumerate(zip(idx, b.block_shape, arr, strict=True)):
                bs = int(bs) if bs is not None else 1
                if i < 0 or (i + 1) * bs > int(asz):
                    mk("pallas-bounds",
                       f"{b.role} operand dim {d}: block index {i} "
                       f"(x block {bs}) out of bounds for extent {asz} "
                       f"at grid point {pt}")
        if len(findings) > n_bounds_before:
            break          # first failing grid point is enough per call
    return findings


# ---------------------------------------------------------------------------
# Representative shapes: the paper's own workloads (configs/paper_models)
# ---------------------------------------------------------------------------

def _paper_cfg(name):
    from repro.configs import get_config
    return get_config(name)


def representative_invocations():
    """-> list of CapturedCall covering every Pallas kernel in ``kernels/``
    at paper-model shapes.  Serving-path constants (decode batch, page
    size, draft depth) mirror the engine defaults (page_size=16,
    speculative k=3 -> 4 verify queries)."""
    import jax.numpy as jnp

    from repro.kernels import (decode_attention as dec_mod,
                               flash_attention as fl_mod, matmul as mm_mod,
                               rmsnorm as rn_mod, ssd_scan as ssd_mod)

    tl = _paper_cfg("tinyllama-42m")
    tl64 = _paper_cfg("tinyllama-42m-64h")
    mb = _paper_cfg("mobilebert")
    rng = np.random.RandomState(0)
    B, PSZ, NQ = 8, 16, 4

    def f32(*shape):
        return jnp.asarray(rng.randn(*shape).astype(np.float32))

    calls = []

    def cap(label, mod, fn, *args, **kw):
        rel = f"{KERNELS_PATH}/{mod.__name__.rsplit('.', 1)[-1]}.py"
        calls.extend(capture_invocation(label, rel, fn.__wrapped__,
                                        *args, **kw))

    # --- matmul: prompt-mode GEMMs of the paper's decoder ------------------
    S = 128                            # paper §V-A autoregressive S
    cap(f"matmul[tinyllama-42m ffn {S}x{tl.d_model}x{tl.d_ff}]",
        mm_mod, mm_mod.matmul, f32(S, tl.d_model), f32(tl.d_model, tl.d_ff))
    cap(f"matmul[tinyllama-42m lm_head {S}x{tl.d_model}x{tl.vocab_size}]",
        mm_mod, mm_mod.matmul,
        f32(S, tl.d_model), f32(tl.d_model, tl.vocab_size))

    # --- rmsnorm -----------------------------------------------------------
    cap(f"rmsnorm[tinyllama-42m {S}x{tl.d_model}]", rn_mod, rn_mod.rmsnorm,
        f32(S, tl.d_model), f32(tl.d_model))
    cap(f"rmsnorm[mobilebert 268x{mb.d_model}]", rn_mod, rn_mod.rmsnorm,
        f32(268, mb.d_model), f32(mb.d_model))

    # --- flash attention (prefill) -----------------------------------------
    for cfg, sq, causal in ((tl, tl.max_seq_len, True),
                            (tl64, tl64.max_seq_len, True),
                            (mb, 268, False)):
        cap(f"flash_attention[{cfg.name} H={cfg.n_heads} S={sq} "
            f"D={cfg.head_dim}]", fl_mod, fl_mod.flash_attention,
            f32(cfg.n_heads, sq, cfg.head_dim),
            f32(cfg.n_heads, sq, cfg.head_dim),
            f32(cfg.n_heads, sq, cfg.head_dim), causal=causal)

    # --- contiguous decode attention ---------------------------------------
    for cfg in (tl, tl64):
        Sd = cfg.max_seq_len
        cap(f"decode_attention[{cfg.name} B={B} H={cfg.n_heads} S={Sd}]",
            dec_mod, dec_mod.decode_attention,
            f32(B, cfg.n_heads, cfg.head_dim),
            f32(B, cfg.n_heads, Sd, cfg.head_dim),
            f32(B, cfg.n_heads, Sd, cfg.head_dim),
            jnp.asarray(rng.randint(1, Sd, B).astype(np.int32)))

    # --- paged decode / verify (fp32 and int8 pools) -----------------------
    Sp = 512                           # serving seq budget for the pool rows
    n_max = Sp // PSZ
    n_pages = B * n_max + 1
    H, D = tl.n_heads, tl.head_dim
    bt = np.zeros((B, n_max), np.int32)
    ids = rng.permutation(np.arange(1, n_pages))[:B * n_max]
    bt[...] = ids.reshape(B, n_max)
    bt_j = jnp.asarray(bt)
    lens = jnp.asarray(rng.randint(1, Sp, B).astype(np.int32))
    scale = jnp.asarray(rng.rand(n_pages, PSZ).astype(np.float32))
    kp8 = jnp.asarray(rng.randint(-127, 127, (n_pages, H, PSZ, D)
                                  ).astype(np.int8))
    kpf = f32(n_pages, H, PSZ, D)
    q1 = f32(B, H, D)
    qv = f32(B, H, NQ, D)
    cap(f"paged_decode_attention[tinyllama-42m B={B} psz={PSZ} fp32]",
        dec_mod, dec_mod.paged_decode_attention, q1, kpf, kpf, bt_j, lens)
    cap(f"paged_decode_attention[tinyllama-42m B={B} psz={PSZ} int8]",
        dec_mod, dec_mod.paged_decode_attention, q1, kp8, kp8, bt_j, lens,
        k_scale=scale, v_scale=scale)
    cap(f"paged_verify_attention[tinyllama-42m B={B} Q={NQ} psz={PSZ} fp32]",
        dec_mod, dec_mod.paged_verify_attention, qv, kpf, kpf, bt_j, lens)
    cap(f"paged_verify_attention[tinyllama-42m B={B} Q={NQ} psz={PSZ} int8]",
        dec_mod, dec_mod.paged_verify_attention, qv, kp8, kp8, bt_j, lens,
        k_scale=scale, v_scale=scale)

    # --- ssd scan (no SSM arch in the paper: dims are a paper-scale proxy,
    # sized like the paper models' attention working set) -------------------
    Ss, Hs, Ps, Ns = 256, 8, 64, 64
    x, dt = f32(Ss, Hs, Ps), f32(Ss, Hs)
    Bm, Cm, A = f32(Ss, Ns), f32(Ss, Ns), f32(Hs)
    cap(f"ssd_scan[paper-scale proxy S={Ss} H={Hs} P={Ps} N={Ns}]",
        ssd_mod, ssd_mod.ssd_scan, x, dt, Bm, Cm, A)
    st8 = jnp.asarray(rng.randint(-127, 127, (Hs, Ps, Ns)).astype(np.int8))
    cap(f"ssd_scan[paper-scale proxy int8 state0 S={Ss}]",
        ssd_mod, ssd_mod.ssd_scan, x, dt, Bm, Cm, A,
        state0=st8, state0_scale=f32(Hs))

    return calls


def default_budget() -> int:
    from repro.sim.siracusa import SiracusaConfig
    return SiracusaConfig().onchip_budget


def run(budget: int = 0):
    """-> (findings, table rows).  Rows go to BUDGET_vmem.json."""
    budget = budget or default_budget()
    findings, rows = [], []
    for call in representative_invocations():
        fs = check_call(call, budget)
        findings.extend(fs)
        rows.append({
            "kernel": call.name, "file": call.kernel_file,
            "grid": list(call.grid),
            "block_bytes": sum(b.nbytes for b in call.blocks if not b.smem),
            "scratch_bytes": call.scratch_bytes,
            "vmem_bytes": call.vmem_bytes(),
            "budget_bytes": budget,
            "utilization": round(call.vmem_bytes() / budget, 4),
            "ok": not any(f.rule == "pallas-budget" for f in fs),
        })
    return findings, rows
