"""Elastic resharding: restore a checkpoint under a DIFFERENT ShardingPlan.

Mechanism: the sharded layouts are invertible (``unshard_param`` strips
padding / de-duplicates KV slots back to canonical tensors), so a
checkpoint written on tp=16 restores onto tp=4 (or any mesh) by
canonicalize -> re-scatter.  This is the substrate for elastic scaling and
for recovering onto a degraded fleet after node loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import (_map_template, _mask_invalid_heads,
                              _with_reps, model_template, shard_full)
from repro.core.partition import ModelLayout, dim_layout, model_layout


def unshard_param(spec, sharded, cfg, plan, lay: ModelLayout):
    """Inverse of shard_full: sharded layout -> canonical full tensor."""
    kind, tp = spec.kind, plan.tp
    x = jnp.asarray(sharded)
    if kind == "replicated":
        return x
    hl = lay.ssm if kind.startswith("ssm_") else lay.attn
    k = kind[4:] if kind.startswith("ssm_") else kind
    full = spec.full

    if k == "col_heads":
        y = jnp.moveaxis(x, 0, 1).reshape(full[0], hl.hq_pad, full[2])
        return y[:, :full[1]]
    if k == "col_head_vec":
        y = jnp.moveaxis(x, 0, 1).reshape(full[0], hl.hq_pad)
        return y[:, :full[1]]
    if k == "row_heads":
        y = x.reshape(hl.hq_pad, full[1], full[2])
        return y[:full[0]]
    if k == "head_vec":
        return x.reshape(hl.hq_pad)[:full[0]]
    if k == "flat_heads":
        return x.reshape(hl.hq_pad, full[1])[:full[0]]
    if k == "conv_heads":
        return x.reshape(hl.hq_pad, full[1], full[2])[:full[0]]
    if k == "kv_heads":
        # slots duplicate kv heads; take the first slot holding each head
        kvm = np.asarray(hl.kv_map).reshape(-1)        # (tp*n_kv_loc,)
        y = jnp.moveaxis(x, 0, 1).reshape(full[0], tp * hl.n_kv_loc, full[2])
        first = [int(np.nonzero(kvm == h)[0][0]) for h in range(hl.n_kv)]
        return y[:, jnp.asarray(first)]
    if k == "col_dim":
        dl = dim_layout(full[1], tp)
        y = jnp.moveaxis(x, 0, 1).reshape(full[0], dl.n_pad)
        return y[:, :full[1]]
    if k == "row_dim":
        dl = dim_layout(full[0], tp)
        return x.reshape(dl.n_pad, full[1])[:full[0]]
    if k == "vocab":
        return x.reshape(lay.vocab.n_pad, full[1])[:full[0]]
    if k == "moe_col":
        if plan.moe_mode == "ep":
            return x.reshape(full)
        dl = dim_layout(full[2], tp)
        y = jnp.moveaxis(x, 0, 2).reshape(full[0], full[1], dl.n_pad)
        return y[..., :full[2]]
    if k == "moe_row":
        if plan.moe_mode == "ep":
            return x.reshape(full)
        dl = dim_layout(full[1], tp)
        # (tp, n_exp, f_loc, E) -> (n_exp, tp*f_loc, E)
        y = jnp.moveaxis(x, 0, 1).reshape(full[0], dl.n_pad, full[2])
        return y[:, :full[1]]
    raise ValueError(kind)


class _SpecLeaf:
    """Opaque leaf pairing a ParamSpec with its layer-group rep count, so a
    spec tree with the SAME structure as the param tree can be tree_map'd
    against it (robust to pytree key ordering)."""

    def __init__(self, spec, reps):
        self.spec, self.reps = spec, reps


def spec_tree(cfg):
    return _map_template(_with_reps(cfg, model_template(cfg)),
                         lambda spec, reps: _SpecLeaf(spec, reps))


def reshard_params(params, cfg, plan_from, plan_to):
    """params saved under plan_from -> layout for plan_to (canonicalize +
    re-scatter every leaf; layer-group stacking is preserved)."""
    lay_from = model_layout(cfg, plan_from)
    lay_to = model_layout(cfg, plan_to)

    def mk(sl, leaf):
        spec, reps = sl.spec, sl.reps
        leaves = []
        for r in range(max(reps, 1)):
            src = leaf[r] if reps else leaf
            full = unshard_param(spec, src, cfg, plan_from, lay_from)
            sh = shard_full(spec, full, cfg, plan_to, lay_to)
            sh = _mask_invalid_heads(spec, sh, cfg, plan_to, lay_to)
            leaves.append(sh.astype(src.dtype))
        return jnp.stack(leaves) if reps else leaves[0]

    return jax.tree_util.tree_map(
        mk, spec_tree(cfg), params,
        is_leaf=lambda x: isinstance(x, _SpecLeaf))
