"""Sharded, atomic, async checkpointing with elastic resharding on restore.

Design (works at 1000+ nodes because every host writes only ITS shards):

* layout: ``<dir>/step_<n>/
      manifest.json          tree structure, leaf shapes/dtypes, plan record
      shard_<host>.npz       flat {leaf_path -> local array} per host
      COMMIT``               empty file written LAST (atomic visibility)
* writes go to ``step_<n>.tmp/`` then ``os.rename`` — a crash mid-write can
  never corrupt the latest checkpoint (restore only trusts COMMITted dirs),
* an ``AsyncCheckpointer`` thread overlaps serialization with training
  (double buffering, again), bounded to one in-flight save,
* restore accepts a DIFFERENT ShardingPlan / mesh than the save used:
  leaves are assembled to canonical full tensors and re-scattered with
  ``model.shard_full`` — this is the elasticity mechanism (N pods -> M pods).

This container is single-host; the host dimension is exercised by treating
each model-axis shard group as a "virtual host" in tests.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import ml_dtypes
import numpy as np

# npz cannot represent bfloat16: store as a uint16 view + manifest dtype
_VIEW_DTYPES = {"bfloat16": np.uint16}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str):
    if name in _VIEW_DTYPES:
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict, skeleton):
    def build(node, prefix):
        if isinstance(node, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [build(v, f"{prefix}{i}/") for i, v in enumerate(node)]
        return flat[prefix[:-1]]
    return build(skeleton, "")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, extra: Optional[dict] = None):
        flat = _flatten(state)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        arrays = {}
        manifest = {"step": step, "extra": extra or {}, "leaves": {},
                    "time": time.time()}
        for path, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            stored, dtype_name = _encode(arr)
            # npz keys cannot contain '/': escape
            key = path.replace("/", "::")
            arrays[key] = stored
            manifest["leaves"][path] = {"shape": list(arr.shape),
                                        "dtype": dtype_name}
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w"):
            pass
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, skeleton, step: Optional[int] = None):
        """Restore into the structure of ``skeleton`` (shapes must match)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        flat = {}
        for k in data.files:
            path = k.replace("::", "/")
            flat[path] = _decode(data[k],
                                 manifest["leaves"][path]["dtype"])
        return _unflatten(flat, skeleton), manifest


class AsyncCheckpointer:
    """One background writer; ``save`` returns immediately.  ``wait()`` joins
    the in-flight write (call before exit / before reading back)."""

    def __init__(self, mgr: CheckpointManager):
        self.mgr = mgr
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, state, extra=None):
        self.wait()
        host_state = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), state)

        def run():
            try:
                self.mgr.save(step, host_state, extra)
            except BaseException as e:  # noqa: BLE001
                self._err = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
