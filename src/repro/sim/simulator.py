"""Timeline + energy simulation of the multi-chip system (paper §V).

Per-block timeline:
  t_comp  = MACs / (peak * kernel_efficiency)         (cluster compute)
  t_sync  = hierarchical groups-of-4 all-reduce + broadcast-back over MIPI
  t_l3    = this block's weight slice over the chip's L3 interface

Residency regimes (the paper's central mechanism):
  * whole model fits on-chip        -> no L3 at all (32+ chips, scaled model)
  * one block fits (but model not)  -> next block's weights double-buffer
                                       UNDER compute: t = max(t_comp+t_sync,
                                       t_l3)  [super-linear speedup regime]
  * block does not fit              -> weights stream synchronously:
                                       t = t_comp + t_sync + t_l3
                                       (1-4 chip regime; no room to ping-pong)

Energy follows the paper's equation (§V-A); L3 energy is paid whenever
weights stream, regardless of overlap — which is why the 8-chip system is
26x faster but only ~equal energy, while 32+ chips also cut energy (Fig 5).
"""
from __future__ import annotations

from dataclasses import dataclass


from repro.sim.siracusa import SiracusaConfig, kernel_efficiency
from repro.sim.workload import BlockWorkload


@dataclass
class BlockResult:
    t_block: float
    t_comp: float
    t_sync: float
    t_l3_exposed: float
    e_block: float
    e_comp: float
    e_l3: float
    e_l2: float
    e_c2c: float
    resident: str      # 'model' | 'block' | 'streaming'


def hierarchical_allreduce_time(cfg: SiracusaConfig, payload: float,
                                n_chips: int) -> tuple:
    """Groups-of-4 tree reduce + broadcast back (paper Fig. 1).
    Returns (time, total_bytes_on_wire)."""
    if n_chips <= 1:
        return 0.0, 0.0
    t, total_bytes = 0.0, 0.0
    n = n_chips
    while n > 1:
        fan = min(cfg.group, n)
        senders = fan - 1
        # senders share the root's ingress link -> serialized
        t += senders * (payload / cfg.mipi_bw) + cfg.mipi_latency_s
        level_groups = max(1, n // fan)
        total_bytes += senders * level_groups * payload
        n = level_groups
    return 2 * t, 2 * total_bytes          # reduce + broadcast back


def simulate_block(cfg: SiracusaConfig, wl: BlockWorkload, n_chips: int,
                   model_bytes_per_chip: float) -> BlockResult:
    eff = kernel_efficiency(cfg, wl.min_rows_per_core)
    t_comp = wl.macs_per_chip / (cfg.peak_macs * eff)
    t_sync, wire_bytes = hierarchical_allreduce_time(
        cfg, wl.sync_payload_bytes, n_chips)
    t_sync *= wl.n_syncs
    wire_bytes *= wl.n_syncs

    # L2 streaming floor: weights must cross L2->L1 once per use
    t_l2 = wl.w_bytes_per_chip / cfg.l2_bw
    t_comp = max(t_comp, t_l2)

    if model_bytes_per_chip <= cfg.onchip_budget:
        # whole model resident per chip: no L3 at all
        regime, l3_bytes = "model", 0.0
        t_block = t_comp + t_sync
        t_l3_exposed = 0.0
    elif wl.w_bytes_per_chip * 2 <= cfg.onchip_budget:
        # one block fits twice -> DMA double-buffer of the NEXT block under
        # the current block's compute (paper §V-A); full stream bandwidth
        regime = "block"
        t_l3_stream = wl.w_bytes_per_chip / cfg.l3_bw
        t_block = max(t_comp + t_sync, t_l3_stream)
        t_l3_exposed = max(0.0, t_l3_stream - (t_comp + t_sync))
        l3_bytes = wl.w_bytes_per_chip
    else:
        # no room to ping-pong: operands are demand-fetched from L3 at the
        # (much lower) non-DMA efficiency; intermediates (KV cache,
        # activations) also live off-chip (paper §V-B single-chip regime)
        regime = "streaming"
        l3_bytes = wl.w_bytes_per_chip + wl.kv_bytes_per_chip + \
            wl.act_bytes_per_chip
        t_l3 = l3_bytes / (cfg.l3_bw * cfg.demand_efficiency)
        t_block = t_comp + t_sync + t_l3
        t_l3_exposed = t_l3

    l2_bytes = wl.w_bytes_per_chip + wl.act_bytes_per_chip + \
        (wl.kv_bytes_per_chip if regime != "streaming" else 0.0)

    # clusters burn power for the whole block (busy-wait on DMA/links),
    # matching GVSoC-style end-to-end latency x power accounting
    e_comp = n_chips * cfg.p_cluster_w * t_block
    e_l3 = n_chips * l3_bytes * cfg.e_l3_per_byte
    e_l2 = n_chips * l2_bytes * cfg.e_l2_per_byte
    e_c2c = wire_bytes * cfg.e_c2c_per_byte
    return BlockResult(t_block, t_comp, t_sync, t_l3_exposed,
                       e_comp + e_l3 + e_l2 + e_c2c,
                       e_comp, e_l3, e_l2, e_c2c, regime)


def simulate_model(cfg: SiracusaConfig, wl: BlockWorkload, n_chips: int,
                   n_blocks: int) -> dict:
    model_bytes_per_chip = wl.w_bytes_per_chip * n_blocks
    blk = simulate_block(cfg, wl, n_chips, model_bytes_per_chip)
    return {
        "n_chips": n_chips,
        "t_model": blk.t_block * n_blocks,
        "e_model": blk.e_block * n_blocks,
        "t_block": blk.t_block,
        "e_block": blk.e_block,
        "regime": blk.resident,
        "breakdown_t": {"comp": blk.t_comp * n_blocks,
                        "c2c": blk.t_sync * n_blocks,
                        "l3_exposed": blk.t_l3_exposed * n_blocks},
        "breakdown_e": {"comp": blk.e_comp * n_blocks,
                        "l3": blk.e_l3 * n_blocks,
                        "l2": blk.e_l2 * n_blocks,
                        "c2c": blk.e_c2c * n_blocks},
    }


def speedup_curve(cfg: SiracusaConfig, wl_fn, n_blocks: int,
                  chips: list) -> dict:
    runs = {n: simulate_model(cfg, wl_fn(n), n, n_blocks) for n in chips}
    base = runs[chips[0]]["t_model"]
    for r in runs.values():
        r["speedup"] = base / r["t_model"]
    return runs
