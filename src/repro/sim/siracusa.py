"""Analytical model of the Siracusa multi-MCU system (paper §II-B / §V-A).

Published constants are taken verbatim from the paper; the two quantities
GVSoC provides that the paper does not print (effective MAC throughput of
the 8-core cluster and the L3 interface bandwidth) are free parameters
fitted once by ``sim.calibrate`` against the paper's headline numbers and
then frozen here.  Energy follows the paper's equation:

    E = N_C2C*E_C2C + sum_j [ P*T_comp_j + N_L3_j*E_L3 + N_L2_j*E_L2 ]
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class SiracusaConfig:
    # --- published constants (paper §V-A) ------------------------------------
    freq_hz: float = 500e6
    n_cores: int = 8
    p_core_w: float = 13e-3            # avg power per core
    e_l3_per_byte: float = 100e-12
    e_l2_per_byte: float = 2e-12
    e_c2c_per_byte: float = 100e-12
    mipi_bw: float = 0.5e9             # 0.5 GB/s chip-to-chip
    l1_bytes: int = 256 * 1024
    l2_bytes: int = 2 * 1024 * 1024
    group: int = 4                     # hierarchical reduction fan-in (Fig. 1)

    # --- calibrated (sim.calibrate; GVSoC-derived, not printed in the paper) --
    macs_per_cycle_per_core: float = 1.25   # int8 effective (calibrated)
    l3_bw: float = 0.8e9                    # per-chip L3 DMA stream bandwidth
    demand_efficiency: float = 0.30         # non-DMA (demand) L3 access eff.
    mipi_latency_s: float = 4.0e-6          # per-hop setup latency
    kernel_k0: float = 2.0                  # small-kernel efficiency knee
    l2_bw: float = 16e9                     # 256 bit/cycle @ 500 MHz = 16 GB/s

    budget_fraction: float = 0.6       # share of on-chip SRAM usable for
                                       # resident weights (rest: activations,
                                       # buffers, code — GVSoC-derived)

    @property
    def onchip_budget(self) -> int:
        return int(self.budget_fraction * (self.l2_bytes + self.l1_bytes))

    @property
    def peak_macs(self) -> float:
        return self.n_cores * self.macs_per_cycle_per_core * self.freq_hz

    @property
    def p_cluster_w(self) -> float:
        return self.n_cores * self.p_core_w

    def with_(self, **kw):
        return replace(self, **kw)


def kernel_efficiency(cfg: SiracusaConfig, rows_per_core: float) -> float:
    """Sub-linear GEMM/GEMV scaling as per-core tiles shrink (paper §V-B:
    'the runtime of a GEMM kernel does not scale down linearly as the
    overall kernel size is reduced').  Modeled as a loop-overhead knee."""
    return rows_per_core / (rows_per_core + cfg.kernel_k0)
