"""Calibrate the two GVSoC-derived free parameters of the Siracusa model.

Grid-search (macs_per_cycle_per_core, l3_bw, kernel_k0, mipi_latency)
against the paper's headline numbers:

    TinyLlama AR     8 chips : speedup 26.1x, 0.54 ms, 0.64 mJ / inference
    TinyLlama prompt 8 chips : speedup  9.9x
    MobileBERT       4 chips : speedup  4.7x
    TinyLlama-64h AR 64 chips: speedup 60.1x, energy reduction ~1.3x

Run:  PYTHONPATH=src python -m repro.sim.calibrate
Writes the best-fit constants report; the chosen values are frozen in
``sim.siracusa.SiracusaConfig`` and validated by benchmarks/.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.configs import get_config
from repro.sim.simulator import simulate_model
from repro.sim.siracusa import SiracusaConfig
from repro.sim.workload import mobilebert_block, tinyllama_block


def paper_metrics(cfg: SiracusaConfig) -> dict:
    tl = get_config("tinyllama-42m")
    tl64 = get_config("tinyllama-42m-64h")
    mb = get_config("mobilebert")

    def run(model_cfg, mode, chips, n_blocks, wl_fn):
        out = {}
        for n in chips:
            wl = wl_fn(model_cfg, mode, n) if mode else wl_fn(model_cfg, n)
            out[n] = simulate_model(cfg, wl, n, n_blocks)
        return out

    ar = run(tl, "autoregressive", [1, 2, 4, 8], 8, tinyllama_block)
    pr = run(tl, "prompt", [1, 2, 4, 8], 8, tinyllama_block)
    ar64 = run(tl64, "autoregressive", [1, 8, 16, 32, 64], 8, tinyllama_block)
    mbr = run(mb, None, [1, 2, 4], 24,
              lambda c, n: mobilebert_block(c, n))
    # paper §V-A: runtime/energy are reported for a single transformer block
    return {
        "ar_speedup8": ar[1]["t_block"] / ar[8]["t_block"],
        "ar_t8_ms": ar[8]["t_block"] * 1e3,
        "ar_e8_mj": ar[8]["e_block"] * 1e3,
        "prompt_speedup8": pr[1]["t_block"] / pr[8]["t_block"],
        "mb_speedup4": mbr[1]["t_block"] / mbr[4]["t_block"],
        "mb_t4_ms": mbr[4]["t_block"] * 1e3,
        "ar64_speedup64": ar64[1]["t_block"] / ar64[64]["t_block"],
        "ar64_energy_ratio": ar64[1]["e_block"] / ar64[64]["e_block"],
        "_curves": {"ar": ar, "prompt": pr, "ar64": ar64, "mb": mbr},
    }


TARGETS = {
    "ar_speedup8": 26.1,
    "ar_t8_ms": 0.54,       # paper headline (per-block reporting, §V-A)
    "ar_e8_mj": 0.64,
    "prompt_speedup8": 9.9,
    "mb_speedup4": 4.7,
    "mb_t4_ms": 38.8,
    "ar64_speedup64": 60.1,
    "ar64_energy_ratio": 1.3,
}


def loss(metrics) -> float:
    return float(np.mean([np.log(max(metrics[k], 1e-9) / v) ** 2
                          for k, v in TARGETS.items()]))


def search():
    best = (1e9, None)
    grid = itertools.product(
        [1.0, 1.25, 1.5, 1.75, 2.0, 2.5],            # macs/cycle/core
        [0.4e9, 0.6e9, 0.8e9, 1.0e9, 1.4e9, 2.0e9],  # l3 stream bw
        [0.15, 0.2, 0.3, 0.45, 0.6],                 # demand efficiency
        [2.0, 4.0, 8.0, 12.0],                       # kernel knee
        [0.5e-6, 1e-6, 2e-6, 4e-6],                  # mipi latency
    )
    for mac, l3, eta, k0, lat in grid:
        cfg = SiracusaConfig().with_(macs_per_cycle_per_core=mac, l3_bw=l3,
                                     demand_efficiency=eta,
                                     kernel_k0=k0, mipi_latency_s=lat)
        m = paper_metrics(cfg)
        lv = loss(m)
        if lv < best[0]:
            best = (lv, (mac, l3, eta, k0, lat),
                    {k: m[k] for k in TARGETS})
    return best


def main():
    lv, params, metrics = search()
    mac, l3, eta, k0, lat = params
    print(f"best fit: macs/cyc/core={mac} l3_bw={l3/1e9:.2f}GB/s eta={eta} "
          f"k0={k0} mipi_lat={lat*1e6:.1f}us  (logloss {lv:.4f})")
    print(f"{'metric':20s} {'paper':>8s} {'sim':>8s} {'ratio':>7s}")
    for k, tgt in TARGETS.items():
        print(f"{k:20s} {tgt:8.2f} {metrics[k]:8.2f} {metrics[k]/tgt:7.2f}")
    return params, metrics


if __name__ == "__main__":
    main()
