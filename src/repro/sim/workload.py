"""Per-chip workload extraction for the paper's partitioning (§IV).

Given a ModelConfig + inference mode, produce what ONE chip of an n-chip
system executes for ONE transformer block: MACs, weight bytes (int8,
head/F-sliced, zero duplication), activation traffic, KV-cache traffic and
the two synchronization payloads.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig

INT8 = 1
ACC = 4        # int32 accumulators / fp32 intermediates


@dataclass(frozen=True)
class BlockWorkload:
    macs_per_chip: float            # MAC count (per token step or per prompt)
    w_bytes_per_chip: float         # resident weight bytes (this block)
    act_bytes_per_chip: float       # L2 activation traffic
    kv_bytes_per_chip: float        # KV cache read+write traffic
    sync_payload_bytes: float       # per-sync partial output (S*E)
    n_syncs: int                    # 2 (paper §IV)
    min_rows_per_core: float        # smallest per-core tile (efficiency)


def tinyllama_block(cfg: ModelConfig, mode: str, n_chips: int,
                    n_cores: int = 8) -> BlockWorkload:
    """Decoder block under the paper's partitioning.

    mode: 'autoregressive' (1 token vs KV cache of S) | 'prompt' (S tokens).
    FFN uses the paper's two-matrix description (E x F, F x E).
    """
    E, F, H = cfg.d_model, cfg.d_ff, cfg.n_heads
    S_ctx = 128 if mode == "autoregressive" else 16
    s_new = 1 if mode == "autoregressive" else S_ctx
    P = cfg.head_dim_

    h_loc = max(1, H // n_chips)
    # weights per chip (int8, never duplicated)
    w_attn = (3 * E * P * H + H * P * E) / n_chips       # Wq,Wk,Wv,Wo slices
    w_ffn = (E * F + F * E) / n_chips                    # W_L1, W_L2 slices
    w_bytes = (w_attn + w_ffn) * INT8

    # MACs per chip
    proj = (4 * E * P * H) / n_chips * s_new
    attn = 2 * (h_loc * P) * S_ctx * s_new               # QK^T + AV local heads
    ffn = (2 * E * F) / n_chips * s_new
    macs = proj + attn + ffn

    act = 6 * s_new * E * INT8 + 2 * s_new * (F / n_chips) * INT8
    kv = 2 * h_loc * P * S_ctx * INT8 + 2 * h_loc * P * s_new * INT8

    sync_payload = s_new * E * ACC                       # partial sums int32
    rows = min((F / n_chips) / n_cores, (H * P / n_chips) / n_cores)
    return BlockWorkload(macs, w_bytes, act, kv, sync_payload, 2, max(rows, 1))


def mobilebert_block(cfg: ModelConfig, n_chips: int,
                     n_cores: int = 8) -> BlockWorkload:
    """Encoder block, S=268 bidirectional (no KV cache, prompt-like)."""
    E, F, H = cfg.d_model, cfg.d_ff, cfg.n_heads
    S = 268
    P = cfg.head_dim_
    h_loc = max(1, H // n_chips)
    w_bytes = ((4 * E * P * H) / n_chips + (2 * E * F) / n_chips) * INT8
    proj = (4 * E * P * H) / n_chips * S
    attn = 2 * (h_loc * P) * S * S
    ffn = (2 * E * F) / n_chips * S
    act = 6 * S * E * INT8 + 2 * S * (F / n_chips) * INT8
    sync_payload = S * E * ACC
    rows = min((F / n_chips) / n_cores, (H * P / n_chips) / n_cores)
    return BlockWorkload(proj + attn + ffn, w_bytes, act, 0.0, sync_payload,
                         2, max(rows, 1))
