"""Roofline summary over the dry-run results (EXPERIMENTS.md §Roofline feed).

Reads results_dryrun_sp.json (written by launch.dryrun --all) and prints the
per-cell three-term table; falls back to computing the analytic terms inline
(no 512-device mesh needed — the ledger is traced on a 1-device mesh with
axis sizes spoofed) when the file is missing.
"""
from __future__ import annotations

import json
import os


RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "results_dryrun_sp.json")


def rows(path=RESULTS):
    if not os.path.exists(path):
        return inline_rows()
    out = []
    for rec in json.load(open(path)):
        if rec.get("status") != "ok":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh", ""),
                        "status": rec.get("status", "?"),
                        "bound": rec.get("reason", "")[:40]})
            continue
        r = rec["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "t_compute_ms": r["t_compute"] * 1e3,
            "t_memory_ms": r["t_memory"] * 1e3,
            "t_collective_ms": r["t_collective"] * 1e3,
            "bound": r["bound"],
            "useful_ratio": r["useful_ratio"],
            "roofline_fraction": r["roofline_fraction"],
            "mfu_upper_bound": r["mfu_upper_bound"],
        })
    return out


def inline_rows():
    """Analytic-only fallback (1-device host)."""
    from repro.configs import ASSIGNED, SHAPES, get_config, shape_supported
    from repro.core import analytics, collectives as cc
    from repro.core.partition import ShardingPlan
    from repro.launch import roofline as rl
    out = []
    sizes = {"data": 16, "model": 16}
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = shape_supported(cfg, shape)
            if not ok:
                out.append({"arch": arch, "shape": sname, "mesh": "16x16",
                            "status": "skipped", "bound": reason[:40]})
                continue
            plan = ShardingPlan(
                tp=16, seq_shard_kv=(sname == "long_500k"
                                     and cfg.family != "ssm"),
                remat="block" if shape.kind == "train" else "none")
            cc.set_axis_sizes(sizes)
            cost = analytics.step_cost(cfg, plan, shape, sizes)
            roof = rl.build_roofline(arch, sname, "16x16", cost, 0.0, {},
                                     analytics.model_flops_ideal(cfg, shape),
                                     256)
            out.append({"arch": arch, "shape": sname, "mesh": "16x16",
                        "status": "ok(analytic)",
                        "t_compute_ms": roof.t_compute * 1e3,
                        "t_memory_ms": roof.t_memory * 1e3,
                        "t_collective_ms": 0.0,
                        "bound": roof.bound,
                        "useful_ratio": roof.useful_ratio,
                        "roofline_fraction": roof.roofline_fraction,
                        "mfu_upper_bound": roof.mfu_upper_bound})
    return out


def main(csv=True):
    out = rows()
    if csv:
        keys = ["arch", "shape", "mesh", "status", "t_compute_ms",
                "t_memory_ms", "t_collective_ms", "bound", "useful_ratio",
                "roofline_fraction", "mfu_upper_bound"]
        print(",".join(keys))
        for r in out:
            print(",".join(
                f"{r[k]:.4g}" if isinstance(r.get(k), float)
                else str(r.get(k, "")) for k in keys))
    return out


if __name__ == "__main__":
    main()
