"""Serving throughput bench: contiguous vs paged vs paged+prefix-cache,
plus a mixed-priority QoS scenario (FCFS vs preemptive priority), a
dp-scaling scenario, a hybrid-arch (attention+SSM slab) row whose
outputs are asserted token-identical to the contiguous oracle, a
speculative-decoding row (prompt-lookup drafts + k-token verify) gated
on accepted tokens per verify tick staying above one, and a
disaggregated-serving scenario (dp=2 interleaved vs ``disagg=(1, 1)``)
gated on burst p99 TTFT decoupling from the decode tail at tokens/s
within tolerance, and an elastic scenario (replica crash + rejoin under
steady traffic) gated on the recovered-throughput ratio with post-crash
arrival TTFT fed to the regression gate.

Drives the full ServingEngine on a shared-system-prompt workload (every
request = common prefix + unique suffix — the traffic shape the radix
prefix cache targets) and reports tokens/s, TTFT, and prefix-cache
effectiveness (prefill tokens skipped, hit rate, COW copies).

The priority scenario saturates the slots with low-priority bulk
requests, lands a high-priority burst mid-run, and reports p50/p99 TTFT
per class under FCFS vs ``PriorityScheduler(preemption=True)`` — the
paper's interactive-wearable case, where sensor-triggered queries must
not queue behind bulk work.  Greedy low-priority outputs are asserted
token-identical across the two policies (preempted-and-resumed requests
produce exactly the uncontended continuation).

    PYTHONPATH=src python benchmarks/serving_bench.py --smoke --json \
        BENCH_serving.json

All prompts share one length so the contiguous oracle compiles once; the
paged modes would handle mixed lengths with the same single compile.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

# --smoke swaps in a tiny reduced config: same code path, CI-friendly wall
# time, and a BENCH_serving.json artifact for the perf trajectory.
SIZES = {
    "full": {"requests": 24, "slots": 4, "seq_budget": 256, "prefix": 96,
             "suffix": 24, "max_new": 24, "page_size": 16, "chunk": 32},
    "smoke": {"requests": 6, "slots": 2, "seq_budget": 64, "prefix": 24,
              "suffix": 6, "max_new": 6, "page_size": 8, "chunk": 16},
}


def build_requests(sz, vocab, seed=0):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    shared = rng.randint(2, vocab, sz["prefix"]).astype(np.int32)
    out = []
    for rid in range(sz["requests"]):
        suf = rng.randint(2, vocab, sz["suffix"]).astype(np.int32)
        out.append(Request(rid=rid,
                           prompt=np.concatenate([shared, suf]),
                           max_new_tokens=sz["max_new"]))
    return out


def _stats_row(mode, eng, stats, dt, n_requests):
    """The per-mode result row every scenario shares."""
    row = {"mode": mode,
           "requests": n_requests,
           "decoded_tokens": stats.decoded_tokens,
           "tokens_per_s": stats.decoded_tokens / dt,
           "ttft_p50_ms": float(np.median(stats.ttft_s)) * 1e3,
           "ttft_p95_ms": float(np.percentile(stats.ttft_s, 95)) * 1e3,
           "tpot_p50_ms": float(np.median(stats.tpot_s)) * 1e3,
           "prefill_tokens_skipped": stats.prefill_tokens_skipped,
           "prefix_hit_rate": stats.prefix_hit_rate,
           "cow_copies": stats.cow_copies,
           "wall_s": dt}
    if eng.allocators:
        row["pages_allocated"] = sum(a.total_allocated
                                     for a in eng.allocators)
    return row


def run_mode(mode, cfg, plan, mesh, params, sz):
    import jax
    from repro.configs.base import ShapeConfig
    from repro.core import steps
    from repro.serving import ServingEngine

    if mode == "contiguous":
        dshape = ShapeConfig("sb_d", "decode", sz["seq_budget"], sz["slots"])
        pshape = ShapeConfig("sb_p", "decode", sz["seq_budget"], 1)
        dec, _, _ = steps.make_decode_step(cfg, plan, mesh, dshape)
        pre, _, _ = steps.make_prefill_step(cfg, plan, mesh, pshape)
        eng = ServingEngine(cfg, plan, mesh, sz["slots"], sz["seq_budget"],
                            params, jax.jit(pre), jax.jit(dec))
    else:
        eng = ServingEngine.build_paged(
            cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
            page_size=sz["page_size"], prefill_chunk=sz["chunk"],
            prefix_cache=(mode == "prefix"))
    reqs = build_requests(sz, cfg.vocab_size)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    stats = eng.run(max_ticks=50_000)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    row = _stats_row(mode, eng, stats, dt, sz["requests"])
    if mode == "prefix":
        # the whole point of the mode: the shared prefix is never recomputed
        assert stats.prefill_tokens_skipped > 0, \
            "prefix mode skipped no prefill tokens on a shared-prefix workload"
    return row


def run_priority_mode(mode, cfg, plan, mesh, params, sz):
    """Mixed-priority scenario: low-priority bulk saturates the slots, a
    high-priority burst lands mid-run.  mode: 'prio-fcfs' (baseline) or
    'prio-preempt' (PriorityScheduler with preemption).  -> (row, outputs)
    where outputs maps rid -> generated tokens (for cross-mode identity)."""
    import functools
    from repro.serving import PriorityScheduler, Request, ServingEngine

    scheduler = None
    if mode == "prio-preempt":
        scheduler = functools.partial(PriorityScheduler, preemption=True)
    # double-occupancy pool: enough slack that pages donated by preempted
    # requests survive (un-evicted) until the victims resume behind the
    # backlog — the KV-reuse signal this scenario reports
    n_pages = 2 * sz["slots"] * (sz["seq_budget"] // sz["page_size"]) + 1
    eng = ServingEngine.build_paged(
        cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
        page_size=sz["page_size"], prefill_chunk=sz["chunk"],
        n_pages=n_pages, prefix_cache=True, scheduler=scheduler)
    rng = np.random.RandomState(1)
    vocab = cfg.vocab_size
    low = [Request(rid=rid,
                   prompt=rng.randint(2, vocab, sz["prefix"]).astype(np.int32),
                   max_new_tokens=sz["max_new"], priority=0)
           for rid in range(sz["requests"])]
    high = [Request(rid=1000 + i,
                    prompt=rng.randint(2, vocab,
                                       sz["suffix"]).astype(np.int32),
                    max_new_tokens=sz["max_new"], priority=10)
            for i in range(max(2, sz["requests"] // 4))]
    for r in low:
        eng.submit(r)
    # land the burst once the first wave of prefills is decoding
    burst_at = -(-sz["prefix"] // sz["chunk"]) + 2
    t0 = time.perf_counter()
    tick = 0
    while eng.sched.has_pending() or \
            any(a is not None for a in eng.admissions):
        if tick == burst_at:
            for r in high:
                eng.submit(r)
        eng.tick()
        tick += 1
        assert tick < 50_000, "priority scenario did not converge"
    dt = time.perf_counter() - t0
    stats = eng.stats
    assert all(r.done for r in low + high)
    ttft = {cls: [stats.request_ttft[r.rid] for r in rs]
            for cls, rs in (("high", high), ("low", low))}
    row = _stats_row(mode, eng, stats, dt, len(low) + len(high))
    row["preemptions"] = stats.preemptions
    for cls in ("high", "low"):
        row[f"ttft_p50_ms_{cls}"] = float(np.median(ttft[cls])) * 1e3
        row[f"ttft_p99_ms_{cls}"] = float(np.percentile(ttft[cls], 99)) * 1e3
    outputs = {r.rid: tuple(r.out_tokens) for r in low + high}
    return row, outputs


def run_hybrid_mode(plan, mesh, sz):
    """Hybrid-arch (attention + SSM) paged serving row: the engine serves
    a reduced hymba config out of KV pages + recurrent-state slabs, and
    greedy outputs are asserted token-identical to the contiguous oracle
    (the acceptance bar for SSM slab paging).  -> row dict ("hybrid")."""
    import jax
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.core import model, steps
    from repro.serving import Request, ServingEngine

    cfg = reduced(get_config("hymba-1.5b"), dtype="float32")
    params = model.init_params(cfg, plan)
    rng = np.random.RandomState(5)
    base = [(rng.randint(2, cfg.vocab_size,
                         int(rng.randint(4, sz["prefix"]))).astype(np.int32),
             sz["max_new"]) for _ in range(sz["requests"])]

    dshape = ShapeConfig("hb_d", "decode", sz["seq_budget"], sz["slots"])
    pshape = ShapeConfig("hb_p", "decode", sz["seq_budget"], 1)
    dec, _, _ = steps.make_decode_step(cfg, plan, mesh, dshape)
    pre, _, _ = steps.make_prefill_step(cfg, plan, mesh, pshape)
    oracle = ServingEngine(cfg, plan, mesh, sz["slots"], sz["seq_budget"],
                           params, jax.jit(pre), jax.jit(dec))
    refs = [Request(rid=i, prompt=p.copy(), max_new_tokens=m)
            for i, (p, m) in enumerate(base)]
    for r in refs:
        oracle.submit(r)
    oracle.run(max_ticks=50_000)
    ref_out = {r.rid: tuple(r.out_tokens) for r in refs}

    eng = ServingEngine.build_paged(
        cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
        page_size=sz["page_size"], prefill_chunk=sz["chunk"])
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=m)
            for i, (p, m) in enumerate(base)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    stats = eng.run(max_ticks=50_000)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    assert {r.rid: tuple(r.out_tokens) for r in reqs} == ref_out, \
        "hybrid paged outputs diverged from the contiguous oracle"
    # slab + page leak-freedom at completion
    a = eng.allocators[0]
    assert a.n_free == a.n_pages - a.n_reserved
    assert eng.slab_allocators[0].n_free == eng.n_slabs - 1
    return _stats_row("hybrid", eng, stats, dt, sz["requests"])


def run_spec_mode(cfg, plan, mesh, params, sz, k=4):
    """Speculative-decoding scenario: prompt-lookup drafts + k-token verify
    on a shared-prefix workload whose suffixes repeat a short motif (the
    traffic prompt lookup targets).  Greedy outputs are asserted
    token-identical to the non-speculative paged engine (the full
    policy/dp/sampling matrix lives in scripts/check_spec_identity.py) and
    the accepted-tokens rate feeds the regression gate.  -> row dict
    ("speculative")."""
    from repro.serving import Request, ServingEngine

    rng = np.random.RandomState(7)
    vocab = cfg.vocab_size
    shared = rng.randint(2, vocab, sz["prefix"]).astype(np.int32)
    base = []
    for i in range(sz["requests"]):
        motif = rng.randint(2, vocab, 3 + i % 3).astype(np.int32)
        body = np.tile(motif, 4)[: sz["suffix"] + i % 4]
        base.append(np.concatenate([shared, body]).astype(np.int32))
    max_new = 2 * sz["max_new"]   # room for repetition loops to develop
    # headroom pool so speculative page budgeting is never the bottleneck
    n_pages = 2 * sz["slots"] * (sz["seq_budget"] // sz["page_size"]) + 1

    outs = {}
    for spec in (0, k):
        eng = ServingEngine.build_paged(
            cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
            page_size=sz["page_size"], prefill_chunk=sz["chunk"],
            n_pages=n_pages, prefix_cache=True, speculative=spec)
        reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(base)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        stats = eng.run(max_ticks=50_000)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        outs[spec] = {r.rid: tuple(r.out_tokens) for r in reqs}
    assert outs[0] == outs[k], \
        "speculative outputs diverged from the one-token engine"
    row = _stats_row("speculative", eng, stats, dt, sz["requests"])
    row["speculative_k"] = k
    row["accepted_tokens_per_tick"] = stats.accepted_tokens_per_tick
    row["draft_hit_rate"] = stats.draft_hit_rate
    row["spec_accepted"] = stats.spec_accepted
    row["spec_drafted"] = stats.spec_drafted
    # the acceptance bar: speculation must beat one token per verify tick
    # on this workload, or the feature is dead weight
    assert row["accepted_tokens_per_tick"] > 1.0, \
        f"accepted_tokens_per_tick={row['accepted_tokens_per_tick']:.2f}"
    return row


def run_disagg_mode(cfg, plan, mesh, params, smoke=False):
    """Disaggregated-serving scenario: a decode-heavy background flood
    holds every page pool's full horizon while a burst of long-prefill
    interactive requests lands mid-run.  dp=2 interleaved admits the
    burst only as background requests retire (their pages are reserved
    through max_new), so burst TTFT rides the decode tail; dp=2
    ``disagg=(1, 1)`` budgets prompt-only pages on the prefill replica —
    the burst prefills immediately and its first tokens land before any
    decode capacity frees.  Greedy outputs are asserted token-identical
    across the two modes and the burst p99 TTFT improvement is the gated
    headline, with tokens/s within tolerance (the lock-step single-host
    loop executes both roles' compiled steps serially, so disagg pays the
    unbatched prefill rounds; the TTFT decoupling is the signal).

    The shape is fixed (same for --smoke and full): the pool exactly
    holds the whole background on one replica — equal decode width in
    both modes — while the interleaved per-replica slack stays below the
    burst's page horizon.  Compile time is excluded by a warm-up flood on
    each engine before the measured phase.  -> (rows, outputs) for modes
    dp2-interleaved / dp2-disagg."""
    from repro.serving import Request, ServingEngine

    SLOTS, N_PAGES, SEQ, CHUNK, PSZ = 4, 25, 112, 16, 8
    BG_N, BG_PROMPT, BG_NEW = 4, 4, 40      # 4 x 6 pages = the whole pool
    BU_N, BU_PROMPT, BU_NEW = 2, 96, 8      # 13-page horizon > 12 slack
    BURST_AT = 4

    def drive(disagg):
        eng = ServingEngine.build_paged(
            cfg, plan, mesh, SLOTS, SEQ, params, page_size=PSZ,
            prefill_chunk=CHUNK, n_pages=N_PAGES, dp=2, disagg=disagg)
        # warm-up: compile every step (including the committed-input
        # prefill entry) before the measured phase
        warm = [Request(rid=10_000 + i,
                        prompt=np.arange(2, CHUNK + 5).astype(np.int32) + i,
                        max_new_tokens=2) for i in range(4)]
        for r in warm:
            eng.submit(r)
        eng.run(max_ticks=50_000)
        h0, p0 = eng.stats.handoffs, eng.stats.pages_transferred
        rng = np.random.RandomState(9)
        vocab = cfg.vocab_size
        bg = [Request(rid=i, prompt=rng.randint(2, vocab, BG_PROMPT)
                      .astype(np.int32), max_new_tokens=BG_NEW)
              for i in range(BG_N)]
        bu = [Request(rid=100 + i, prompt=rng.randint(2, vocab, BU_PROMPT)
                      .astype(np.int32), max_new_tokens=BU_NEW)
              for i in range(BU_N)]
        t0 = time.perf_counter()
        for r in bg:
            eng.submit(r)
        tick = 0
        while eng.has_pending() or \
                any(a is not None for a in eng.admissions):
            if tick == BURST_AT:
                for r in bu:
                    eng.submit(r)
            eng.tick()
            tick += 1
            assert tick < 50_000, "disagg scenario did not converge"
        eng.drain()
        dt = time.perf_counter() - t0
        stats = eng.stats
        assert all(r.done for r in bg + bu)
        toks = sum(len(r.out_tokens) for r in bg + bu)
        ttft = [stats.request_ttft[r.rid] for r in bg + bu]
        ttft_bu = [stats.request_ttft[r.rid] for r in bu]
        row = {"mode": "dp2-disagg" if disagg else "dp2-interleaved",
               "requests": len(bg) + len(bu),
               "decoded_tokens": toks,
               "tokens_per_s": toks / dt,
               "ttft_p50_ms": float(np.median(ttft)) * 1e3,
               "ttft_p99_ms": float(np.percentile(ttft, 99)) * 1e3,
               "ttft_p99_ms_burst": float(np.percentile(ttft_bu, 99)) * 1e3,
               "handoffs": stats.handoffs - h0,
               "pages_transferred": stats.pages_transferred - p0,
               "wall_s": dt}
        return row, {r.rid: tuple(r.out_tokens) for r in bg + bu}

    int_row, int_out = drive(None)
    dis_row, dis_out = drive((1, 1))
    assert int_out == dis_out, "outputs changed under disaggregation"
    # every request prefilled on replica 0 and crossed exactly once
    assert dis_row["handoffs"] == BG_N + BU_N
    assert dis_row["pages_transferred"] > 0
    speedup = int_row["ttft_p99_ms_burst"] / \
        max(dis_row["ttft_p99_ms_burst"], 1e-9)
    tps_ratio = dis_row["tokens_per_s"] / max(int_row["tokens_per_s"], 1e-9)
    print(f"# disagg 1:1: burst p99 TTFT "
          f"interleaved={int_row['ttft_p99_ms_burst']:.1f}ms "
          f"disagg={dis_row['ttft_p99_ms_burst']:.1f}ms ({speedup:.2f}x) "
          f"tok/s ratio={tps_ratio:.2f} "
          f"({dis_row['handoffs']} handoffs, "
          f"{dis_row['pages_transferred']} pages transferred)")
    # the point of disaggregation: burst TTFT decouples from the decode
    # tail (observed ~2-2.6x; 1.2x leaves slack) at tokens/s within
    # tolerance (observed ~0.73-0.89x).  Smoke-noise handling mirrors the
    # priority gate: measured walls are tens of ms, so on shared CI
    # runners warn instead of flaking; full mode asserts hard.
    if speedup < 1.2 or tps_ratio < 0.6:
        msg = (f"disagg burst p99 speedup {speedup:.2f}x (< 1.2x) or "
               f"tok/s ratio {tps_ratio:.2f} (< 0.6)")
        assert smoke, msg
        print(f"::warning::{msg} — smoke wall-clock noise?")
    return [int_row, dis_row]


def run_elastic_mode(cfg, plan, mesh, params, sz, smoke=False):
    """Elastic-serving scenario: steady decode traffic on dp=2 loses a
    replica mid-run (``kill_replica`` — in-flight requests re-admitted on
    the survivor) and scales back to dp=2 a few ticks later (``scale_to``
    — the rejoin).  A second request wave lands right after the crash, so
    its TTFT prices the recovery window.  Reported against an undisturbed
    dp=2 run of the same traffic: ``recovered_throughput_ratio``
    (event-run tokens/s over baseline — how much of the fleet's
    throughput the membership churn costs end-to-end) and
    ``ttft_p99_ms_event`` (p99 TTFT of the post-crash arrivals).  Greedy
    outputs are asserted token-identical across the two runs — the
    membership changes must be invisible in the tokens.  Compile time is
    excluded by a discarded warm-up drive (which also compiles the dp=1
    step set the crash window runs on).  -> row dict ("elastic")."""
    from repro.serving import Request, ServingEngine

    KILL_AT, WAVE_AT, REJOIN_AT = 3, 4, 8
    max_new = 2 * sz["max_new"]

    def mk_reqs(seed):
        rng = np.random.RandomState(seed)
        vocab = cfg.vocab_size
        wave_a = [Request(rid=i, prompt=rng.randint(2, vocab, sz["suffix"])
                          .astype(np.int32), max_new_tokens=max_new)
                  for i in range(2 * sz["slots"])]
        wave_b = [Request(rid=100 + i, prompt=rng.randint(2, vocab,
                                                          sz["suffix"])
                          .astype(np.int32), max_new_tokens=max_new)
                  for i in range(sz["slots"])]
        return wave_a, wave_b

    def drive(with_event):
        eng = ServingEngine.build_paged(
            cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
            page_size=sz["page_size"], prefill_chunk=sz["chunk"],
            prefix_cache=True, dp=2)
        if with_event:
            pending = [(KILL_AT, "kill"), (REJOIN_AT, "scale")]

            def hook(e):
                while pending and e.stats.ticks >= pending[0][0]:
                    _, kind = pending.pop(0)
                    if kind == "kill":
                        e.kill_replica(1)
                    else:
                        e.scale_to(2)

            eng.membership_hook = hook
        wave_a, wave_b = mk_reqs(seed=13)
        t0 = time.perf_counter()
        for r in wave_a:
            eng.submit(r)
        tick = 0
        while eng.has_pending() or \
                any(a is not None for a in eng.admissions):
            if tick == WAVE_AT:
                for r in wave_b:
                    eng.submit(r)
            eng.tick()
            tick += 1
            assert tick < 50_000, "elastic scenario did not converge"
        dt = time.perf_counter() - t0
        reqs = wave_a + wave_b
        assert all(r.done for r in reqs)
        toks = sum(len(r.out_tokens) for r in reqs)
        ttft_ev = [eng.stats.request_ttft[r.rid] for r in wave_b]
        return eng, toks / dt, ttft_ev, dt, \
            {r.rid: tuple(r.out_tokens) for r in reqs}

    drive(True)                      # warm-up: compile dp=2 AND dp=1 sets
    _, base_tps, _, _, base_out = drive(False)
    eng, ev_tps, ttft_ev, dt, ev_out = drive(True)
    assert ev_out == base_out, "outputs changed under membership churn"
    st = eng.stats
    assert st.crashes == 1 and st.scale_events == 1
    assert st.readmitted > 0, "crash re-admitted no in-flight requests"
    ratio = ev_tps / max(base_tps, 1e-9)
    row = {"mode": "elastic",
           "requests": 3 * sz["slots"],
           "decoded_tokens": st.decoded_tokens,
           "tokens_per_s": ev_tps,
           "ttft_p99_ms_event": float(np.percentile(ttft_ev, 99)) * 1e3,
           "recovered_throughput_ratio": ratio,
           "crashes": st.crashes, "scale_events": st.scale_events,
           "migrations": st.migrations, "readmitted": st.readmitted,
           "wall_s": dt}
    print(f"# elastic: kill@{KILL_AT} rejoin@{REJOIN_AT}: "
          f"tok/s {ev_tps:.1f} vs baseline {base_tps:.1f} "
          f"(ratio {ratio:.2f}), post-crash p99 TTFT "
          f"{row['ttft_p99_ms_event']:.1f}ms, "
          f"{st.readmitted} re-admitted, {st.migrations} migrations")
    # the recovery bar: one crash + one rejoin must not halve the run's
    # throughput (observed ~0.7-0.95; 0.4 leaves slack for the re-prefill
    # work the crash forces).  Smoke walls are tens of ms on shared CI
    # runners — warn there, assert hard in full mode.
    if ratio < 0.4:
        msg = f"recovered throughput ratio {ratio:.2f} (< 0.4)"
        assert smoke, msg
        print(f"::warning::{msg} — smoke wall-clock noise?")
    return row


def _kv_pool_bytes(cfg, plan, n_pages, page_size):
    """Exact KV/cross pool footprint (payload + scale side tensors) from
    the cache template — what the engine would allocate, without building
    one."""
    from repro.core import kvcache
    from repro.core.partition import model_layout
    tmpl = kvcache.paged_cache_template(cfg, plan, model_layout(cfg, plan),
                                        n_pages, page_size)
    total = 0
    for pat in tmpl:
        for d in pat:
            for kind in ("kv", "cross"):
                for shape, dtype, _ in d.get(kind, {}).values():
                    total += int(np.prod(shape)) * np.dtype(dtype).itemsize
    return total


def run_quant_mode(cfg, plan_fp16, plan_i8, mesh, params, sz):
    """Quantized-KV scenario: int8 pools + per-page scales vs fp16 pools
    AT A FIXED POOL BYTE BUDGET.  The budget fits the fp16 pool exactly
    one request's pages; int8 pages cost ~half the bytes, so the same
    budget holds ~2x the pages and the engine admits strictly more
    requests concurrently — the capacity story behind quantizing at all.
    Reports pool bytes (ratio gated at <= 0.55x), tokens/s, and max
    concurrently admitted requests per variant.  -> row dict
    ("quant-int8")."""
    from repro.core.kvcache import pages_needed
    from repro.serving import ServingEngine

    need = pages_needed(sz["prefix"] + sz["suffix"] + sz["max_new"],
                        sz["page_size"])
    n_pages_fp16 = need + 1                      # budget: one admission
    budget = _kv_pool_bytes(cfg, plan_fp16, n_pages_fp16, sz["page_size"])
    per_page_i8 = _kv_pool_bytes(cfg, plan_i8, 2, sz["page_size"]) - \
        _kv_pool_bytes(cfg, plan_i8, 1, sz["page_size"])
    n_pages_i8 = budget // per_page_i8

    def drive(plan, n_pages):
        eng = ServingEngine.build_paged(
            cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
            page_size=sz["page_size"], prefill_chunk=sz["chunk"],
            n_pages=int(n_pages))
        reqs = build_requests(sz, cfg.vocab_size, seed=11)
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        tick, max_conc = 0, 0
        while eng.has_pending() or \
                any(a is not None for a in eng.admissions):
            eng.tick()
            tick += 1
            max_conc = max(max_conc,
                           sum(a is not None for a in eng.admissions))
            assert tick < 50_000, "quant scenario did not converge"
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        return eng, eng.stats, dt, max_conc

    eng16, st16, dt16, conc16 = drive(plan_fp16, n_pages_fp16)
    eng8, st8, dt8, conc8 = drive(plan_i8, n_pages_i8)
    row = _stats_row("quant-int8", eng8, st8, dt8, sz["requests"])
    row["pool_bytes_fp16"] = budget
    row["pool_bytes_int8"] = _kv_pool_bytes(cfg, plan_i8, n_pages_fp16,
                                            sz["page_size"])
    row["bytes_ratio"] = row["pool_bytes_int8"] / budget
    row["n_pages_fp16"] = n_pages_fp16
    row["n_pages_int8"] = int(n_pages_i8)
    row["max_concurrent_fp16"] = conc16
    row["max_concurrent_int8"] = conc8
    row["tokens_per_s_fp16"] = st16.decoded_tokens / dt16
    # the two acceptance bars: int8 pages cost at most 0.55x the fp16
    # bytes, and the reclaimed budget buys real admission headroom
    assert row["bytes_ratio"] <= 0.55, \
        f"int8 pool bytes ratio {row['bytes_ratio']:.3f} > 0.55"
    assert conc8 > conc16, (conc8, conc16)
    return row


def run_dp_mode(dp, cfg, plan, mesh, params, sz):
    """dp-scaling scenario: two tenant groups, each sharing its own system
    prompt.  With dp=2 the router splits the tenants across replicas by
    prefix affinity, so each replica serves its tenant's prefix out of its
    own replica-local pool — per-replica hit rates stay high and greedy
    outputs are token-identical to the dp=1 oracle.  -> (row, outputs)."""
    from repro.serving import Request, ServingEngine
    eng = ServingEngine.build_paged(
        cfg, plan, mesh, sz["slots"], sz["seq_budget"], params,
        page_size=sz["page_size"], prefill_chunk=sz["chunk"],
        prefix_cache=True, dp=dp)
    rng = np.random.RandomState(3)
    vocab = cfg.vocab_size
    tenants = [rng.randint(2, vocab, sz["prefix"]).astype(np.int32)
               for _ in range(2)]
    reqs = []
    for rid in range(2 * sz["requests"]):
        suf = rng.randint(2, vocab, sz["suffix"]).astype(np.int32)
        reqs.append(Request(
            rid=rid, prompt=np.concatenate([tenants[rid % 2], suf]),
            max_new_tokens=sz["max_new"]))
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    stats = eng.run(max_ticks=50_000)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    row = _stats_row(f"dp{dp}", eng, stats, dt, len(reqs))
    row["dp"] = dp
    row["affinity_routed"] = eng.router.affinity_routed
    for rr, rs in enumerate(stats.replicas):
        row[f"prefix_hit_rate_r{rr}"] = rs.prefix_hit_rate
        row[f"routed_r{rr}"] = rs.routed
    # per-replica leak-freedom: every page free or cache-held after the run
    for rr in range(dp):
        a, c = eng.allocators[rr], eng.prefix_caches[rr]
        assert a.n_free + c.n_cached_pages == a.n_pages - a.n_reserved, rr
    return row, {r.rid: tuple(r.out_tokens) for r in reqs}


def rows(smoke: bool = False):
    import jax
    from repro import compat
    from repro.configs import get_config, reduced
    from repro.core import model
    from repro.core.partition import ShardingPlan

    sz = SIZES["smoke" if smoke else "full"]
    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    plan = ShardingPlan(tp=1, kv_cache_dtype="float32")
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
    params = model.init_params(cfg, plan)
    out = [run_mode(m, cfg, plan, mesh, params, sz)
           for m in ("contiguous", "paged", "prefix")]
    fcfs_row, fcfs_out = run_priority_mode("prio-fcfs", cfg, plan, mesh,
                                           params, sz)
    pre_row, pre_out = run_priority_mode("prio-preempt", cfg, plan, mesh,
                                         params, sz)
    # schedule-invariance: greedy outputs are identical under both policies
    # even though prio-preempt evicted and resumed low-priority requests
    assert fcfs_out == pre_out, "outputs changed under preemptive scheduling"
    speedup = fcfs_row["ttft_p99_ms_high"] / max(pre_row["ttft_p99_ms_high"],
                                                 1e-9)
    print(f"# high-priority p99 TTFT: fcfs={fcfs_row['ttft_p99_ms_high']:.1f}"
          f"ms preempt={pre_row['ttft_p99_ms_high']:.1f}ms "
          f"({speedup:.1f}x, {pre_row['preemptions']} preemptions)")
    # the QoS point of the policy: urgent arrivals must not queue behind
    # bulk work (observed ~8-10x; 2x leaves slack for noise).  Smoke-shape
    # TTFTs are single-digit ms over ~2 samples, so on shared CI runners
    # one scheduler stall could flake the ratio — warn there and leave the
    # trend to check_regression; full mode asserts hard.
    if speedup < 2.0:
        msg = f"priority preemption gained only {speedup:.2f}x (< 2x)"
        assert smoke, msg
        print(f"::warning::{msg} — smoke wall-clock noise?")
    assert pre_row["preemptions"] > 0
    # ...and the victims' KV was reused on resume, not recomputed
    assert pre_row["prefill_tokens_skipped"] > 0
    # dp scaling: replica-sharded pools + prefix-affinity routing
    dp1_row, dp1_out = run_dp_mode(1, cfg, plan, mesh, params, sz)
    dp2_row, dp2_out = run_dp_mode(2, cfg, plan, mesh, params, sz)
    assert dp1_out == dp2_out, "outputs changed under dp=2 routing"
    # each replica owns one tenant's prefix: both hit rates are nonzero
    assert dp2_row["routed_r0"] > 0 and dp2_row["routed_r1"] > 0
    assert dp2_row["prefix_hit_rate_r0"] > 0
    assert dp2_row["prefix_hit_rate_r1"] > 0
    print(f"# dp scaling: dp1={dp1_row['tokens_per_s']:.1f} tok/s "
          f"dp2={dp2_row['tokens_per_s']:.1f} tok/s "
          f"(replica hit rates {dp2_row['prefix_hit_rate_r0']:.2f}/"
          f"{dp2_row['prefix_hit_rate_r1']:.2f}, "
          f"{dp2_row['affinity_routed']} affinity-routed)")
    # hybrid (attention + SSM slabs) paged serving, oracle-checked
    hybrid_row = run_hybrid_mode(plan, mesh, sz)
    print(f"# hybrid arch: {hybrid_row['tokens_per_s']:.1f} tok/s "
          f"(outputs oracle-identical, slabs leak-free)")
    # speculative decoding: prompt-lookup drafts, identity-checked
    spec_row = run_spec_mode(cfg, plan, mesh, params, sz)
    print(f"# speculative k={spec_row['speculative_k']}: "
          f"accepted_tokens_per_tick="
          f"{spec_row['accepted_tokens_per_tick']:.2f} "
          f"draft_hit_rate={spec_row['draft_hit_rate']:.2f} "
          f"({spec_row['spec_accepted']}/{spec_row['spec_drafted']} "
          f"draft tokens accepted; outputs identical to one-token engine)")
    # quantized pools: int8 vs fp16 at a fixed pool byte budget
    quant_row = run_quant_mode(
        cfg, ShardingPlan(tp=1, kv_cache_dtype="bfloat16"),
        ShardingPlan(tp=1, kv_cache_dtype="int8"), mesh, params, sz)
    print(f"# quantized KV: int8 pool bytes "
          f"{quant_row['bytes_ratio']:.3f}x fp16, max concurrent "
          f"{quant_row['max_concurrent_int8']} vs "
          f"{quant_row['max_concurrent_fp16']} at the same byte budget "
          f"({quant_row['n_pages_int8']} vs {quant_row['n_pages_fp16']} "
          f"pages)")
    # disaggregated prefill/decode: burst TTFT decoupling, oracle-checked
    disagg_rows = run_disagg_mode(cfg, plan, mesh, params, smoke=smoke)
    # elastic membership: crash + rejoin under load, identity-checked
    elastic_row = run_elastic_mode(cfg, plan, mesh, params, sz, smoke=smoke)
    return out + [fcfs_row, pre_row, dp1_row, dp2_row, hybrid_row, spec_row,
                  quant_row] + disagg_rows + [elastic_row]


def main(smoke=False, json_path=None):
    import jax
    out = rows(smoke=smoke)
    keys = list(dict.fromkeys(k for r in out for k in r))
    print(",".join(keys))
    for r in out:
        print(",".join(f"{r.get(k):.4g}" if isinstance(r.get(k), float)
                       else str(r.get(k, "")) for k in keys))
    if json_path:
        payload = {"bench": "serving", "mode": "smoke" if smoke else "full",
                   "unix_time": time.time(), "jax": jax.__version__,
                   "rows": out}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI bench-smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_*.json artifact")
    a = ap.parse_args()
    main(smoke=a.smoke, json_path=a.json)
