"""Fig. 4 reproduction: speedup + runtime breakdown, 1-8 chips.

(a) TinyLlama autoregressive, (b) TinyLlama prompt, (c) MobileBERT.
Paper claims: 26.1x AR / 9.9x prompt @ 8 chips; 4.7x MobileBERT @ 4 chips;
AR memory-dominated vs prompt compute-dominated.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.sim.simulator import simulate_model
from repro.sim.siracusa import SiracusaConfig
from repro.sim.workload import mobilebert_block, tinyllama_block

PAPER = {"ar_8": 26.1, "prompt_8": 9.9, "mb_4": 4.7}


def rows():
    cfg = SiracusaConfig()
    tl = get_config("tinyllama-42m")
    mb = get_config("mobilebert")
    out = []
    for mode, chips in (("autoregressive", [1, 2, 4, 8]),
                        ("prompt", [1, 2, 4, 8])):
        base = None
        for n in chips:
            r = simulate_model(cfg, tinyllama_block(tl, mode, n), n, 8)
            base = base or r["t_block"]
            bt = r["breakdown_t"]
            out.append({
                "fig": f"4{'a' if mode == 'autoregressive' else 'b'}",
                "model": f"tinyllama-{mode}", "chips": n,
                "t_block_ms": r["t_block"] * 1e3,
                "speedup": base / r["t_block"],
                "regime": r["regime"],
                "frac_comp": bt["comp"] / (r["t_model"] + 1e-30),
                "frac_c2c": bt["c2c"] / (r["t_model"] + 1e-30),
                "frac_l3": bt["l3_exposed"] / (r["t_model"] + 1e-30),
            })
    base = None
    for n in [1, 2, 4]:
        r = simulate_model(cfg, mobilebert_block(mb, n), n, 24)
        base = base or r["t_block"]
        bt = r["breakdown_t"]
        out.append({
            "fig": "4c", "model": "mobilebert", "chips": n,
            "t_block_ms": r["t_block"] * 1e3,
            "speedup": base / r["t_block"],
            "regime": r["regime"],
            "frac_comp": bt["comp"] / (r["t_model"] + 1e-30),
            "frac_c2c": bt["c2c"] / (r["t_model"] + 1e-30),
            "frac_l3": bt["l3_exposed"] / (r["t_model"] + 1e-30),
        })
    return out


def derived():
    rs = {(r["model"], r["chips"]): r for r in rows()}
    ar8 = rs[("tinyllama-autoregressive", 8)]["speedup"]
    pr8 = rs[("tinyllama-prompt", 8)]["speedup"]
    mb4 = rs[("mobilebert", 4)]["speedup"]
    return {
        "ar_speedup8_sim_vs_paper": f"{ar8:.1f}/{PAPER['ar_8']}",
        "prompt_speedup8_sim_vs_paper": f"{pr8:.1f}/{PAPER['prompt_8']}",
        "mb_speedup4_sim_vs_paper": f"{mb4:.1f}/{PAPER['mb_4']}",
        "ar_memory_dominated_1chip":
            rs[("tinyllama-autoregressive", 1)]["frac_l3"] >
            rs[("tinyllama-autoregressive", 1)]["frac_comp"],
        "prompt_compute_dominated_1chip":
            rs[("tinyllama-prompt", 1)]["frac_comp"] >=
            max(rs[("tinyllama-prompt", 1)]["frac_l3"],
                rs[("tinyllama-prompt", 1)]["frac_c2c"]),
    }


def main(csv=True):
    out = rows()
    if csv:
        keys = list(out[0])
        print(",".join(keys))
        for r in out:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
        for k, v in derived().items():
            print(f"# {k}: {v}")
    return out


if __name__ == "__main__":
    main()
