"""Bench regression gate: compare a fresh BENCH_*.json against history.

Appends the current payload to a JSONL history file (persisted across CI
runs via actions/cache; see .github/workflows/ci.yml) and compares each
mode's key metrics against the median of prior runs.  Warn-only until
``--min-history`` prior runs exist — perf history has to accumulate before
gating is meaningful — then a regression beyond ``--tol`` fails the job.

    python benchmarks/check_regression.py BENCH_serving.json \
        --history .bench-history/serving.jsonl

Stdlib-only on purpose: it must run before (or without) the jax install.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

# metric -> direction: +1 = higher is better, -1 = lower is better
METRICS = {
    "tokens_per_s": +1,
    "ttft_p50_ms": -1,
    "ttft_p99_ms_high": -1,   # QoS headline of the priority scenario
    "cpu_us_per_call": -1,    # kernels bench (BENCH_kernels.json rows)
    "accepted_tokens_per_tick": +1,   # speculative-decoding scenario
    "ttft_p99_ms_burst": -1,  # disaggregated-serving scenario headline
    "recovered_throughput_ratio": +1,  # elastic scenario: post-crash recovery
    "ttft_p99_ms_event": -1,  # elastic scenario: arrivals landing post-crash
}


def row_key(row):
    """Identity of a row across runs: serving rows carry ``mode``; kernel
    rows carry (kernel, shape)."""
    if row.get("mode") is not None:
        return row["mode"]
    if row.get("kernel") is not None:
        return f"{row['kernel']}[{row.get('shape')}]"
    return None


def load_history(path):
    """-> list of prior payloads (oldest first); [] when no file yet."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


MAX_HISTORY = 20


def append_history(path, payload, prior):
    """Append and window to the last MAX_HISTORY payloads, so a stale
    machine profile can't pin the median forever."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    kept = (prior + [payload])[-MAX_HISTORY:]
    with open(path, "w") as f:
        for p in kept:
            f.write(json.dumps(p) + "\n")


def compare(current_rows, history, tol, min_history=3):
    """-> (failures, warnings): regression messages per mode/metric.

    Each mode/metric is compared against the median of that metric over the
    prior payloads that report it; modes or metrics absent from history are
    skipped (new benches never fail on their first appearance).  A
    violation gates (failure) only once that mode/metric has at least
    ``min_history`` prior samples — a newly added mode is warn-only until
    its own history accumulates, regardless of how old the file is."""
    failures, warnings = [], []
    for row in current_rows:
        mode = row_key(row)
        if mode is None:
            continue
        for metric, sign in METRICS.items():
            if metric not in row:
                continue
            prior = [r[metric] for p in history for r in p.get("rows", [])
                     if row_key(r) == mode and metric in r]
            if not prior:
                continue
            med = statistics.median(prior)
            cur = row[metric]
            if med <= 0:
                continue
            if (sign > 0 and cur < med * (1 - tol)) or \
                    (sign < 0 and cur > med * (1 + tol)):
                msg = (f"{mode}/{metric}: {cur:.4g} is {cur / med:.2f}x the "
                       f"median of {len(prior)} prior runs ({med:.4g})")
                (failures if len(prior) >= min_history
                 else warnings).append(msg)
    return failures, warnings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json", help="fresh BENCH_*.json to check")
    ap.add_argument("--history", required=True,
                    help="JSONL file of prior payloads (appended to)")
    ap.add_argument("--min-history", type=int, default=3,
                    help="prior samples of a mode/metric required before "
                         "its regressions fail (below this: warn-only)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="fractional slack before a delta counts "
                         "(CI runners are noisy, but the 20-run median "
                         "absorbs most of it; default 25%%)")
    args = ap.parse_args(argv)

    with open(args.bench_json) as f:
        payload = json.load(f)
    history = load_history(args.history)
    failures, warnings = compare(payload.get("rows", []), history, args.tol,
                                 args.min_history)
    # failing runs never enter history: a real regression must not
    # re-baseline itself after a few red runs
    if not failures:
        append_history(args.history, payload, history)
    # ::warning::/::error:: render as GitHub Actions annotations
    for v in failures:
        print(f"::error::bench regression: {v}")
    for v in warnings:
        print(f"::warning::bench regression (warn-only, thin history): {v}")
    if not failures and not warnings:
        print(f"bench OK vs {len(history)} prior run(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
