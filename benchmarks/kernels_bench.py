"""Kernel microbenchmarks: pure-JAX reference path wall-time on CPU +
analytic TPU roofline estimates for the Pallas kernels.

(Pallas interpret mode is a correctness tool, not a performance proxy, so
TPU numbers are roofline-derived: bytes/FLOPs of the kernel's tiling over
the v5e constants.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters


def rows():
    out = []
    rng = np.random.RandomState(0)
    # decode attention: the paper's AR GEMV regime
    for S in (4096, 32768):
        B, H, D = 4, 8, 128
        q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
        k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        ln = jnp.full((B,), S, jnp.int32)
        f = jax.jit(lambda q, k, v, ln: ref.ref_decode_attention(q, k, v, ln))
        t = _time(f, q, k, v, ln)
        bytes_ = 2 * B * H * S * D * 2                     # bf16 on TPU
        flops = 4 * B * H * S * D
        out.append({"kernel": "decode_attention", "shape": f"S={S}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # flash attention prefill tile
    for S in (1024, 4096):
        H, D = 4, 128
        q = jnp.asarray(rng.randn(H, S, D), jnp.float32)
        k = jnp.asarray(rng.randn(H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(H, S, D), jnp.float32)
        f = jax.jit(lambda q, k, v: ref.ref_flash_attention(q, k, v))
        t = _time(f, q, k, v)
        flops = 2 * H * S * S * D * 2 / 2                 # causal half
        bytes_ = 3 * H * S * D * 2 + H * S * D * 2
        out.append({"kernel": "flash_attention", "shape": f"S={S}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # matmul (prompt-mode GEMM)
    for M, K, N in ((512, 512, 2048), (2048, 2048, 2048)):
        a = jnp.asarray(rng.randn(M, K), jnp.float32)
        b = jnp.asarray(rng.randn(K, N), jnp.float32)
        f = jax.jit(ref.ref_matmul)
        t = _time(f, a, b)
        flops = 2 * M * K * N
        bytes_ = (M * K + K * N + M * N) * 2
        out.append({"kernel": "matmul", "shape": f"{M}x{K}x{N}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # ssd scan
    S, H, P, N = 2048, 8, 64, 64
    x = jnp.asarray(rng.randn(S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(S, H)) * 0.05, jnp.float32)
    Bm = jnp.asarray(rng.randn(S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(S, N), jnp.float32)
    A = -jnp.asarray(np.abs(rng.rand(H)) + 0.5, jnp.float32)
    f = jax.jit(lambda *a: ref.ref_ssd_scan(*a)[0])
    t = _time(f, x, dt, Bm, Cm, A)
    flops = S * H * P * N * 6
    bytes_ = (S * H * P * 2 + 2 * S * N * 2) * 2
    out.append({"kernel": "ssd_scan", "shape": f"S={S}",
                "cpu_us_per_call": t * 1e6,
                "tpu_roofline_us": max(bytes_ / HBM_BW,
                                       flops / PEAK_FLOPS) * 1e6,
                "arithmetic_intensity": flops / bytes_})
    return out


def main(csv=True):
    out = rows()
    if csv:
        keys = list(out[0])
        print(",".join(keys))
        for r in out:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return out


if __name__ == "__main__":
    main()
