"""Kernel microbenchmarks: pure-JAX reference path wall-time on CPU +
analytic TPU roofline estimates for the Pallas kernels.

(Pallas interpret mode is a correctness tool, not a performance proxy, so
TPU numbers are roofline-derived: bytes/FLOPs of the kernel's tiling over
the v5e constants.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

# --smoke swaps in tiny shapes: same code path, CI-friendly wall time, and a
# BENCH_*.json artifact so the perf trajectory records from day one.
SIZES = {
    "full": {"decode_S": (4096, 32768), "flash_S": (1024, 4096),
             "matmul": ((512, 512, 2048), (2048, 2048, 2048)),
             "ssd_S": 2048},
    "smoke": {"decode_S": (512,), "flash_S": (256,),
              "matmul": ((128, 128, 256),), "ssd_S": 256},
}


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters


def rows(smoke: bool = False):
    sz = SIZES["smoke" if smoke else "full"]
    out = []
    rng = np.random.RandomState(0)
    # decode attention: the paper's AR GEMV regime
    for S in sz["decode_S"]:
        B, H, D = 4, 8, 128
        q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
        k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        ln = jnp.full((B,), S, jnp.int32)
        f = jax.jit(lambda q, k, v, ln: ref.ref_decode_attention(q, k, v, ln))
        t = _time(f, q, k, v, ln)
        bytes_ = 2 * B * H * S * D * 2                     # bf16 on TPU
        flops = 4 * B * H * S * D
        out.append({"kernel": "decode_attention", "shape": f"S={S}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # int8 decode attention: same GEMV regime, int8 K/V payloads +
    # per-(page, slot) f32 scales dequantized in-register — the pool
    # traffic halves vs bf16, which is the whole point in this
    # memory-bound regime (the roofline column shows it directly)
    for S in sz["decode_S"]:
        B, H, D, psz = 4, 8, 128, 16
        n_pages = B * (S // psz) + 1
        q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
        kq = jnp.asarray(rng.randint(-127, 128, (n_pages, H, psz, D)),
                         jnp.int8)
        vq = jnp.asarray(rng.randint(-127, 128, (n_pages, H, psz, D)),
                         jnp.int8)
        ks = jnp.asarray(np.abs(rng.randn(n_pages, psz)) * 0.02, jnp.float32)
        vs = jnp.asarray(np.abs(rng.randn(n_pages, psz)) * 0.02, jnp.float32)
        bt = jnp.asarray(np.arange(1, n_pages).reshape(B, S // psz),
                         jnp.int32)
        ln = jnp.full((B,), S, jnp.int32)

        def deq_gather(pool, sc, S=S):        # bind the loop var (B023)
            g = pool[bt.reshape(-1)].astype(jnp.float32) * \
                sc[bt.reshape(-1)][:, None, :, None]
            return g.reshape(B, S // psz, H, psz, D) \
                .transpose(0, 2, 1, 3, 4).reshape(B, H, S, D)

        f = jax.jit(lambda q, kq, ks, vq, vs, ln: ref.ref_decode_attention(
            q, deq_gather(kq, ks), deq_gather(vq, vs), ln))
        t = _time(f, q, kq, ks, vq, vs, ln)
        bytes_ = 2 * B * H * S * D * 1 + 2 * B * S * 4     # int8 + scales
        flops = 4 * B * H * S * D
        out.append({"kernel": "decode_attention_int8", "shape": f"S={S}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # flash attention prefill tile
    for S in sz["flash_S"]:
        H, D = 4, 128
        q = jnp.asarray(rng.randn(H, S, D), jnp.float32)
        k = jnp.asarray(rng.randn(H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(H, S, D), jnp.float32)
        f = jax.jit(lambda q, k, v: ref.ref_flash_attention(q, k, v))
        t = _time(f, q, k, v)
        flops = 2 * H * S * S * D * 2 / 2                 # causal half
        bytes_ = 3 * H * S * D * 2 + H * S * D * 2
        out.append({"kernel": "flash_attention", "shape": f"S={S}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # matmul (prompt-mode GEMM)
    for M, K, N in sz["matmul"]:
        a = jnp.asarray(rng.randn(M, K), jnp.float32)
        b = jnp.asarray(rng.randn(K, N), jnp.float32)
        f = jax.jit(ref.ref_matmul)
        t = _time(f, a, b)
        flops = 2 * M * K * N
        bytes_ = (M * K + K * N + M * N) * 2
        out.append({"kernel": "matmul", "shape": f"{M}x{K}x{N}",
                    "cpu_us_per_call": t * 1e6,
                    "tpu_roofline_us": max(bytes_ / HBM_BW,
                                           flops / PEAK_FLOPS) * 1e6,
                    "arithmetic_intensity": flops / bytes_})
    # ssd scan
    S, H, P, N = sz["ssd_S"], 8, 64, 64
    x = jnp.asarray(rng.randn(S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(S, H)) * 0.05, jnp.float32)
    Bm = jnp.asarray(rng.randn(S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(S, N), jnp.float32)
    A = -jnp.asarray(np.abs(rng.rand(H)) + 0.5, jnp.float32)
    f = jax.jit(lambda *a: ref.ref_ssd_scan(*a)[0])
    t = _time(f, x, dt, Bm, Cm, A)
    flops = S * H * P * N * 6
    bytes_ = (S * H * P * 2 + 2 * S * N * 2) * 2
    out.append({"kernel": "ssd_scan", "shape": f"S={S}",
                "cpu_us_per_call": t * 1e6,
                "tpu_roofline_us": max(bytes_ / HBM_BW,
                                       flops / PEAK_FLOPS) * 1e6,
                "arithmetic_intensity": flops / bytes_})
    return out


def main(csv=True, smoke=False, json_path=None):
    out = rows(smoke=smoke)
    if csv:
        keys = list(out[0])
        print(",".join(keys))
        for r in out:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    if json_path:
        payload = {"bench": "kernels", "mode": "smoke" if smoke else "full",
                   "unix_time": time.time(), "jax": jax.__version__,
                   "rows": out}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI bench-smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_*.json artifact")
    a = ap.parse_args()
    main(smoke=a.smoke, json_path=a.json)
