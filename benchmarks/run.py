"""Benchmark driver: one section per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` style CSV sections.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    sections = [
        ("fig4_speedup (paper Fig.4: speedup + breakdown)",
         "benchmarks.fig4_speedup"),
        ("fig5_energy (paper Fig.5: energy x latency)",
         "benchmarks.fig5_energy"),
        ("fig6_scalability (paper Fig.6: 2-64 chips)",
         "benchmarks.fig6_scalability"),
        ("table1_properties (paper Table I: zero-dup + two-sync audit)",
         "benchmarks.table1_properties"),
        ("kernels (Pallas kernel rooflines + CPU ref timings)",
         "benchmarks.kernels_bench"),
        ("roofline (40-cell dry-run three-term table)",
         "benchmarks.roofline_bench"),
    ]
    failed = []
    for title, mod in sections:
        print(f"\n==== {title} ====")
        t0 = time.time()
        try:
            __import__(mod, fromlist=["main"]).main()
            print(f"# section_seconds={time.time() - t0:.1f}")
        except Exception:  # noqa: BLE001
            failed.append(mod)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED sections: {failed}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
