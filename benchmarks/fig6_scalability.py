"""Fig. 6 reproduction: scaled TinyLlama (64 heads) on 2-64 chips.

Paper claims: quasi-linear AR speedup up to 60.1x @ 64 chips; prompt mode
linear to 16 chips then diminishing; 1.3x energy reduction @ 64 chips.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.sim.simulator import simulate_model
from repro.sim.siracusa import SiracusaConfig
from repro.sim.workload import tinyllama_block

PAPER = {"ar_64": 60.1, "energy_ratio_64": 1.3}

CHIPS = [1, 2, 4, 8, 16, 32, 64]


def rows():
    cfg = SiracusaConfig()
    tl64 = get_config("tinyllama-42m-64h")
    out = []
    for mode in ("autoregressive", "prompt"):
        base_t = base_e = None
        for n in CHIPS:
            r = simulate_model(cfg, tinyllama_block(tl64, mode, n), n, 8)
            base_t = base_t or r["t_block"]
            base_e = base_e or r["e_block"]
            out.append({"fig": "6", "model": f"tinyllama64h-{mode}",
                        "chips": n,
                        "t_block_ms": r["t_block"] * 1e3,
                        "speedup": base_t / r["t_block"],
                        "energy_ratio_vs_1chip": base_e / r["e_block"],
                        "regime": r["regime"]})
    return out


def derived():
    rs = {(r["model"], r["chips"]): r for r in rows()}
    ar = rs[("tinyllama64h-autoregressive", 64)]
    pr16 = rs[("tinyllama64h-prompt", 16)]
    pr64 = rs[("tinyllama64h-prompt", 64)]
    return {
        "ar_speedup64_sim_vs_paper": f"{ar['speedup']:.1f}/{PAPER['ar_64']}",
        "ar_energy_ratio64_sim_vs_paper":
            f"{ar['energy_ratio_vs_1chip']:.2f}/{PAPER['energy_ratio_64']}",
        "prompt_diminishing_returns_past_16":
            (pr64["speedup"] / pr16["speedup"]) < (64 / 16) * 0.75,
    }


def main(csv=True):
    out = rows()
    if csv:
        keys = list(out[0])
        print(",".join(keys))
        for r in out:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
        for k, v in derived().items():
            print(f"# {k}: {v}")
    return out


if __name__ == "__main__":
    main()
