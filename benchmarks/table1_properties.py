"""Table I reproduction: partitioning properties, audited on OUR system.

The paper's row: Transformer / Extreme Edge / no pipelining / no weight
duplication.  We verify the two structural properties (zero weight
duplication, two synchronizations per block) on the JAX implementation
itself via the duplication audit and the CommLedger — for every assigned
architecture.
"""
from __future__ import annotations

import jax

from repro.configs import ASSIGNED, get_config, reduced
from repro.configs.base import FFN_NONE, ShapeConfig
from repro.core import collectives as cc
from repro.core import steps
from repro.core.partition import ShardingPlan, duplication_report


def expected_syncs(cfg):
    """Per-forward sync count implied by the paper contract (DESIGN.md):
    1 per mixer + 1 per FFN + 1 per cross-attn + ssm-norm scalar psums +
    1 embed + 3 loss psums (train)."""
    n = 0.0
    specs = cfg.layer_specs() + (cfg.encoder_layer_specs()
                                 if cfg.is_encdec else [])
    for s in specs:
        n += 1                                # mixer psum
        if s.ffn != FFN_NONE:
            n += 1                            # ffn psum
        if s.cross_attn:
            n += 1
        if s.mixer in ("ssm", "hybrid"):
            n += 1                            # ssm-norm sum-of-squares psum
    return n


def rows():
    out = []
    plan = ShardingPlan(tp=16)
    for name in ASSIGNED:
        cfg = get_config(name)
        rep = duplication_report(cfg, plan)
        # audit the traced sync count on the reduced config (same layer
        # structure per block, fewer blocks)
        rcfg = reduced(cfg)
        rplan = ShardingPlan(tp=1)
        from repro import compat
        mesh = compat.make_mesh((1, 1), ("data", "model"),
                                devices=jax.devices()[:1])
        shape = ShapeConfig("t", "train", 32, 2)
        cc.LEDGER.start()
        ts, _ = steps.make_train_step(rcfg, rplan, mesh, shape=shape)
        batch = {"tokens": jax.numpy.zeros((2, 32), "int32"),
                 "labels": jax.numpy.zeros((2, 32), "int32")}
        if rcfg.is_encdec:
            batch["frames"] = jax.numpy.zeros((2, 32, rcfg.d_model),
                                              "bfloat16")
        if rcfg.frontend == "vision_patches":
            batch["image_embeds"] = jax.numpy.zeros(
                (2, rcfg.n_frontend_embeds, rcfg.d_model), "bfloat16")
        jax.eval_shape(ts, steps.abstract_train_state(rcfg, rplan),
                       jax.tree_util.tree_map(
                           lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           batch))
        cc.LEDGER.stop()
        audited = cc.LEDGER.sync_count("block/")
        out.append({
            "arch": name,
            "dup_fraction": rep["dup_fraction"],
            "pad_fraction": rep["pad_fraction"],
            "zero_dup_core": rep["zero_dup_core"],
            "block_syncs_audited": audited,
            "block_syncs_expected": expected_syncs(rcfg),
            "syncs_match": abs(audited - expected_syncs(rcfg)) < 1e-6,
        })
    return out


def main(csv=True):
    out = rows()
    if csv:
        keys = list(out[0])
        print(",".join(keys))
        for r in out:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return out


if __name__ == "__main__":
    main()
