"""Fig. 5 reproduction: energy x latency per block (default + scaled models).

Paper claims: 0.64 mJ / 0.54 ms @ 8 chips TinyLlama AR (per block, §V-A
reporting); energy drops when weights become fully resident (32+ chips on
the scaled model); slight MobileBERT energy increase from kernel
inefficiency at 4 chips.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.sim.simulator import simulate_model
from repro.sim.siracusa import SiracusaConfig
from repro.sim.workload import mobilebert_block, tinyllama_block

PAPER = {"ar8_ms": 0.54, "ar8_mj": 0.64}


def rows():
    cfg = SiracusaConfig()
    out = []
    tl = get_config("tinyllama-42m")
    tl64 = get_config("tinyllama-42m-64h")
    mb = get_config("mobilebert")
    for label, mcfg, mode, chips in (
            ("tinyllama-ar", tl, "autoregressive", [1, 2, 4, 8]),
            ("tinyllama-prompt", tl, "prompt", [1, 2, 4, 8]),
            ("tinyllama64h-ar", tl64, "autoregressive", [8, 16, 32, 64]),
            ("tinyllama64h-prompt", tl64, "prompt", [8, 16, 32, 64])):
        for n in chips:
            r = simulate_model(cfg, tinyllama_block(mcfg, mode, n), n, 8)
            be = r["breakdown_e"]
            out.append({"fig": "5", "model": label, "chips": n,
                        "t_block_ms": r["t_block"] * 1e3,
                        "e_block_mj": r["e_block"] * 1e3,
                        "regime": r["regime"],
                        "e_l3_frac": be["l3"] / (r["e_model"] + 1e-30)})
    for n in [1, 2, 4]:
        r = simulate_model(cfg, mobilebert_block(mb, n), n, 24)
        out.append({"fig": "5c", "model": "mobilebert", "chips": n,
                    "t_block_ms": r["t_block"] * 1e3,
                    "e_block_mj": r["e_block"] * 1e3,
                    "regime": r["regime"],
                    "e_l3_frac": r["breakdown_e"]["l3"] /
                    (r["e_model"] + 1e-30)})
    return out


def derived():
    rs = {(r["model"], r["chips"]): r for r in rows()}
    r8 = rs[("tinyllama-ar", 8)]
    r32 = rs[("tinyllama64h-ar", 32)]
    r16 = rs[("tinyllama64h-ar", 16)]
    return {
        "ar8_ms_sim_vs_paper": f"{r8['t_block_ms']:.2f}/{PAPER['ar8_ms']}",
        "ar8_mj_sim_vs_paper": f"{r8['e_block_mj']:.2f}/{PAPER['ar8_mj']}",
        "resident_at_32chips": r32["regime"] == "model",
        "energy_drops_when_resident":
            r32["e_block_mj"] < r16["e_block_mj"],
    }


def main(csv=True):
    out = rows()
    if csv:
        keys = list(out[0])
        print(",".join(keys))
        for r in out:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
        for k, v in derived().items():
            print(f"# {k}: {v}")
    return out


if __name__ == "__main__":
    main()
