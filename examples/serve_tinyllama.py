"""End-to-end serving driver: batched requests through the engine
(continuous-batching-lite) on TinyLlama-42M — the paper's decoder workload.

    PYTHONPATH=src python examples/serve_tinyllama.py [--full]

``--full`` uses the real 42M config (slower on CPU); default is the reduced
smoke model.  Demonstrates prefill->slot splice->fused batch decode, greedy
sampling, TTFT/TPOT reporting — the autoregressive mode the paper
accelerates 26.1x.
"""
import sys

from repro.launch.serve import main as serve_main


def main():
    args = ["--arch", "tinyllama-42m", "--requests", "12", "--slots", "4",
            "--seq-budget", "128", "--prompt-len", "24", "--max-new", "12"]
    if "--full" not in sys.argv:
        args.append("--smoke")
    return serve_main(args)


if __name__ == "__main__":
    sys.exit(main())
