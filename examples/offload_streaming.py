"""Host-offload streaming: the paper's L3->L2 double buffering, one tier up.

The paper streams the NEXT transformer block's weights into on-chip memory
while the current block computes (§V-A).  This example runs the same
discipline between host DRAM ("L3") and device memory ("L2"): layer-group
weights live on host; group i+1 stages via async ``jax.device_put`` while
group i computes.  It reports achieved overlap and the bandwidth the paper's
§V-C analysis says is needed for streaming to be free.

    PYTHONPATH=src python examples/offload_streaming.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.offload import OffloadExecutor, required_bandwidth


def main():
    # a toy "model": 8 groups of 2 matmul layers, weights held on HOST
    E, F, B, S = 512, 2048, 8, 128
    n_groups = 8
    rng = np.random.RandomState(0)
    host_groups = [
        {"w1": rng.randn(E, F).astype(np.float32) * 0.02,
         "w2": rng.randn(F, E).astype(np.float32) * 0.02}
        for _ in range(n_groups)
    ]

    @jax.jit
    def group_fwd(x, p):
        h = jax.nn.silu(x @ p["w1"])
        return x + h @ p["w2"]

    def fn(x, p):
        return group_fwd(x, p)

    x = jnp.asarray(rng.randn(B, S, E), jnp.float32)

    # cold pass (includes compile)
    execu = OffloadExecutor(host_groups)
    y = execu.stream_forward(x, [fn] * n_groups)
    jax.block_until_ready(y)

    # measured pass
    execu = OffloadExecutor(host_groups)
    t0 = time.perf_counter()
    y = execu.stream_forward(x, [fn] * n_groups)
    jax.block_until_ready(y)
    wall = time.perf_counter() - t0

    bytes_per_group = sum(a.nbytes for a in host_groups[0].values())
    st = execu.stats
    print(f"groups={st.groups}  wall={wall*1e3:.1f}ms  "
          f"stage(dispatch)={st.stage_s*1e3:.1f}ms  "
          f"compute(dispatch)={st.compute_s*1e3:.1f}ms")
    print(f"weights/group = {bytes_per_group/1e6:.1f} MB")
    need = required_bandwidth(bytes_per_group, wall / st.groups)
    print(f"host-link bandwidth for FREE streaming (paper §V-C logic): "
          f">= {need/1e9:.2f} GB/s")
    print(f"on TPU v5e: PCIe ~{32:.0f} GB/s => streaming is "
          f"{'free' if need < 32e9 else 'exposed'} at this compute intensity")
    # correctness vs all-resident execution
    ref = x
    for p in host_groups:
        ref = group_fwd(ref, jax.device_put(p))
    err = float(jnp.max(jnp.abs(ref - y)))
    print(f"max |offloaded - resident| = {err:.2e}")
    assert err < 1e-5
    print("OK")


if __name__ == "__main__":
    main()
