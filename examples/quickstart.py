"""Quickstart: the paper's partitioning in ~60 lines.

Builds a reduced qwen3 model, shards it with the paper's head-parallel /
F-sliced plan, runs a train step and a decode step, and prints the audited
communication ledger — showing the two-synchronizations-per-block contract.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import collectives as cc
from repro.core import steps
from repro.core.partition import ShardingPlan, duplication_report
from repro.launch.mesh import host_mesh


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    plan = ShardingPlan(tp=1)             # try tp=4 with 4+ devices
    mesh = host_mesh(tp=plan.tp, dp=1)
    print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}")

    # --- the paper's §IV properties, audited --------------------------------
    rep = duplication_report(cfg, ShardingPlan(tp=4))
    print(f"zero-dup core: {rep['zero_dup_core']}  "
          f"(kv-dup fraction {rep['dup_fraction']:.4f}, "
          f"padding {rep['pad_fraction']:.4f})")

    # --- one train step -------------------------------------------------------
    shape = ShapeConfig("demo", "train", 64, 2)
    state = steps.init_train_state(cfg, plan)
    train_step, _ = steps.make_train_step(cfg, plan, mesh, shape=shape)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 64)), jnp.int32)
    cc.LEDGER.start()
    with mesh:
        state, stats = jax.jit(train_step)(state,
                                           {"tokens": tokens, "labels": tokens})
    cc.LEDGER.stop()
    print(f"train loss={float(stats['loss']):.4f} "
          f"grad_norm={float(stats['grad_norm']):.3f}")
    print(f"block syncs audited: {cc.LEDGER.sync_count('block/'):.0f} "
          f"(= 2 x {cfg.n_layers} layers)")

    # --- one decode step -------------------------------------------------------
    dshape = ShapeConfig("demo-d", "decode", 64, 2)
    decode_step, _, _ = steps.make_decode_step(cfg, plan, mesh, dshape)
    cache = steps.zero_cache_for(cfg, plan, mesh, 2, 64)
    with mesh:
        logits, cache = jax.jit(decode_step)(
            state["params"], cache, tokens[:, :1], jnp.zeros((2,), jnp.int32))
    print(f"decode logits: {logits.shape}, argmax={int(logits[0].argmax())}")
    print("OK")


if __name__ == "__main__":
    main()
