"""End-to-end training driver with checkpointing + auto-resume.

Default: a quick CPU-sized run.  ``--full`` trains the real TinyLlama-42M
(~42M params — the '~100M-class' driver; a few hundred steps are feasible
on real hardware, and the config/step/ckpt machinery is identical):

    PYTHONPATH=src python examples/train_small.py            # smoke
    PYTHONPATH=src python examples/train_small.py --full     # 42M params
"""
import sys

from repro.launch.train import main as train_main


def main():
    if "--full" in sys.argv:
        args = ["--arch", "tinyllama-42m", "--steps", "300", "--batch", "8",
                "--seq-len", "256", "--ckpt-dir", "/tmp/repro_ckpt_full",
                "--ckpt-every", "50", "--auto-resume"]
    else:
        args = ["--arch", "tinyllama-42m", "--smoke", "--steps", "30",
                "--batch", "4", "--seq-len", "64",
                "--ckpt-dir", "/tmp/repro_ckpt_smoke", "--ckpt-every", "10",
                "--auto-resume", "--log-every", "5"]
    return train_main(args)


if __name__ == "__main__":
    sys.exit(main())
