"""Reproduce the paper's evaluation on the calibrated Siracusa cluster model.

Prints the Fig. 4/5/6 tables: speedups, runtime breakdowns, energy/latency
for TinyLlama (AR + prompt), MobileBERT, and the 64-head scalability study.

    PYTHONPATH=src python examples/mcu_cluster_sim.py
"""
from repro.configs import get_config
from repro.sim.simulator import simulate_model
from repro.sim.siracusa import SiracusaConfig
from repro.sim.workload import mobilebert_block, tinyllama_block


def main():
    cfg = SiracusaConfig()
    tl = get_config("tinyllama-42m")
    tl64 = get_config("tinyllama-42m-64h")
    mb = get_config("mobilebert")

    print("== TinyLlama-42M, autoregressive (paper Fig. 4a) ==")
    base = None
    for n in (1, 2, 4, 8):
        r = simulate_model(cfg, tinyllama_block(tl, "autoregressive", n), n, 8)
        base = base or r["t_block"]
        print(f"  {n} chips: {r['t_block']*1e3:7.3f} ms/block  "
              f"speedup {base/r['t_block']:5.1f}x  regime={r['regime']}")
    print("  paper: 26.1x @ 8 chips, 0.54 ms, 0.64 mJ")
    r8 = simulate_model(cfg, tinyllama_block(tl, "autoregressive", 8), 8, 8)
    print(f"  sim  : {base/r8['t_block']:.1f}x, {r8['t_block']*1e3:.2f} ms, "
          f"{r8['e_block']*1e3:.2f} mJ")

    print("== TinyLlama-42M, prompt (Fig. 4b) ==")
    base = None
    for n in (1, 2, 4, 8):
        r = simulate_model(cfg, tinyllama_block(tl, "prompt", n), n, 8)
        base = base or r["t_block"]
        print(f"  {n} chips: {r['t_block']*1e3:7.3f} ms/block  "
              f"speedup {base/r['t_block']:5.1f}x  (paper @8: 9.9x)")

    print("== MobileBERT (Fig. 4c) ==")
    base = None
    for n in (1, 2, 4):
        r = simulate_model(cfg, mobilebert_block(mb, n), n, 24)
        base = base or r["t_block"]
        print(f"  {n} chips: {r['t_block']*1e3:7.2f} ms/block  "
              f"speedup {base/r['t_block']:5.1f}x  (paper @4: 4.7x, 38.8 ms)")

    print("== Scaled TinyLlama 64 heads, 2-64 chips (Fig. 6) ==")
    base_t = base_e = None
    for n in (1, 2, 4, 8, 16, 32, 64):
        r = simulate_model(cfg, tinyllama_block(tl64, "autoregressive", n),
                           n, 8)
        base_t = base_t or r["t_block"]
        base_e = base_e or r["e_block"]
        print(f"  {n:3d} chips: speedup {base_t/r['t_block']:5.1f}x  "
              f"energy ratio {base_e/r['e_block']:4.2f}x  "
              f"regime={r['regime']}")
    print("  paper: 60.1x speedup, ~1.3x energy reduction @ 64 chips")


if __name__ == "__main__":
    main()
