"""Docs-freshness gate (CI): keep the prose tethered to the tree.

Checks, stdlib-only so it runs before any jax install:

1. Every internal (non-URL) markdown link in ARCHITECTURE.md, README.md
   and ROADMAP.md resolves to a real file or directory in the repo.
2. Every module under src/repro/serving/ has a non-empty module
   docstring — the serving layer documents its invariants at the top of
   each file, not only in tests.

    python scripts/check_docs.py            # from the repo root

Exit code 0 = clean; 1 = stale docs, with one line per violation.
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["ARCHITECTURE.md", "README.md", "ROADMAP.md"]
DOCSTRING_GLOBS = [os.path.join("src", "repro", "serving")]

# [text](target) — ignore images; fragments/URLs filtered below
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def check_links(errors):
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            errors.append(f"{doc}: file missing")
            continue
        text = open(path, encoding="utf-8").read()
        for target in _LINK.findall(text):
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.join(ROOT, rel)):
                errors.append(f"{doc}: broken internal link -> {target}")


def check_docstrings(errors):
    for base in DOCSTRING_GLOBS:
        d = os.path.join(ROOT, base)
        for name in sorted(os.listdir(d)):
            if not name.endswith(".py"):
                continue
            path = os.path.join(d, name)
            try:
                tree = ast.parse(open(path, encoding="utf-8").read())
            except SyntaxError as e:
                errors.append(f"{base}/{name}: unparseable ({e})")
                continue
            doc = ast.get_docstring(tree)
            if not doc or not doc.strip():
                errors.append(f"{base}/{name}: empty module docstring")


def main():
    errors = []
    check_links(errors)
    check_docstrings(errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    print("check_docs: links resolve, serving docstrings present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
