#!/usr/bin/env python
"""Static-analysis gate over the repo source (CI: static-analysis job).

Runs the ``repro.analysis`` checkers — Pallas VMEM budgets, page-pool
refcount discipline, trace hygiene, docstring invariants — and fails on
any finding that is neither suppressed (``# repro: allow[rule-id]``) nor
listed in ``.static-baseline.json``.

Usage:
    PYTHONPATH=src python scripts/check_static.py            # gate
    PYTHONPATH=src python scripts/check_static.py --strict   # + stale
                                                             #   baseline
                                                             #   entries
                                                             #   fail too
    ... --budget 1048576          # override the on-chip VMEM budget
    ... --json BUDGET_vmem.json   # where the budget table is written
    ... --checkers budget,trace   # run a subset
    ... --runtime-ticks 0         # skip the engine recompile harness
    ... --write-baseline          # snapshot current findings as baseline

Exit status: 0 clean, 1 unbaselined findings (or, with --strict, stale
baseline entries), 2 internal error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import (  # noqa: E402
    CHECKERS,
    apply_suppressions,
    load_baseline,
    split_by_baseline,
    write_baseline,
)
from repro.analysis import budget as budget_mod  # noqa: E402
from repro.analysis import trace as trace_mod  # noqa: E402
from repro.analysis.core import REPO_ROOT, iter_sources  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries")
    ap.add_argument("--budget", type=int, default=0,
                    help="on-chip VMEM budget in bytes "
                         "(default: the paper MCU's usable L1)")
    ap.add_argument("--json", default=os.path.join(REPO_ROOT,
                                                   "BUDGET_vmem.json"),
                    help="path for the per-kernel VMEM budget table")
    ap.add_argument("--checkers", default="all",
                    help="comma-separated subset of: "
                         + ",".join(CHECKERS))
    ap.add_argument("--runtime-ticks", type=int, default=60,
                    help="ticks for the engine recompile harness "
                         "(0 disables it)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current unsuppressed findings to "
                         ".static-baseline.json and exit")
    args = ap.parse_args(argv)

    names = list(CHECKERS) if args.checkers == "all" \
        else [c.strip() for c in args.checkers.split(",") if c.strip()]
    unknown = [c for c in names if c not in CHECKERS]
    if unknown:
        print(f"unknown checkers: {', '.join(unknown)}", file=sys.stderr)
        return 2

    findings, sources_by_path = [], {}
    budget_rows = None
    for name in names:
        mod = CHECKERS[name][0]
        print(f"== {name} ==")
        if name == "budget":
            got, budget_rows = budget_mod.run(budget=args.budget)
        else:
            got, _ = mod.run()
        # suppression lookups need the parsed sources of each target
        for src in iter_sources(getattr(mod, "TARGETS", [])):
            sources_by_path[src.path] = src
        print(f"   {len(got)} raw finding(s)")
        findings.extend(got)

    if "trace" in names and args.runtime_ticks > 0:
        print("== trace: recompile harness ==")
        findings.extend(trace_mod.run_recompile_harness(
            max_ticks=args.runtime_ticks))

    findings = apply_suppressions(findings, sources_by_path)

    if budget_rows is not None:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump({"budget_bytes": budget_rows[0]["budget_bytes"]
                       if budget_rows else args.budget,
                       "kernels": budget_rows}, fh, indent=2)
            fh.write("\n")
        print(f"\nVMEM budget table ({len(budget_rows)} kernel "
              f"invocations) -> {os.path.relpath(args.json, REPO_ROOT)}")
        width = max(len(r["kernel"]) for r in budget_rows) + 2
        for r in budget_rows:
            flag = "ok" if r["ok"] else "OVER"
            print(f"  {r['kernel']:<{width}} {r['vmem_bytes']:>10,} B"
                  f"  {r['utilization']:>6.1%}  {flag}")

    if args.write_baseline:
        write_baseline(findings)
        print(f"\nwrote {len(findings)} entries to .static-baseline.json "
              f"— fill in the justifications")
        return 0

    baseline = load_baseline()
    new, known, stale = split_by_baseline(findings, baseline)

    if known:
        print(f"\n{len(known)} baselined finding(s) (pass):")
        for f in known:
            print(f"  {f.render()}")
    if new:
        print(f"\n{len(new)} NEW finding(s):")
        for f in new:
            print(f"  {f.render()}")
    if stale:
        verb = "FAIL" if args.strict else "warn"
        print(f"\n{len(stale)} stale baseline entrie(s) [{verb}] — "
              f"remove from .static-baseline.json:")
        for fp in stale:
            print(f"  {fp}: {baseline[fp]}")

    failed = bool(new) or (args.strict and bool(stale))
    print(f"\nstatic analysis: "
          f"{'FAIL' if failed else 'OK'} "
          f"({len(new)} new, {len(known)} baselined, {len(stale)} stale)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
