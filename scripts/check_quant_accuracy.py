#!/usr/bin/env python
"""Quantized-pool accuracy gate (CI: the ``accuracy-gate`` step).

int8 page pools trade 4x/2x memory for a bounded precision loss; this
gate pins down "bounded".  For each paged arch family — attention-only
(int8 self-KV), hybrid (int8 KV + int8 SSM slabs), pure SSM (int8
slabs), enc-dec (int8 cross-KV) — it runs the paged engine greedy twice
on the same requests, float pools vs int8 pools, recording the logits
row behind every emitted token, and requires

  1. **greedy identity** on short horizons: the int8 run emits EXACTLY
     the float oracle's tokens, and
  2. **logit drift** below ``DRIFT_BOUND``: max |logits_int8 - logits_fp|
     over every emitted position, so near-ties that happen not to flip
     the argmax today cannot be hiding drift that would flip them under
     any small perturbation tomorrow.

Identity alone is too weak (argmax can mask drift); drift alone is too
weak (a tiny drift on a near-tie still flips tokens).  Together they say:
quantization changed nothing a user can see, and not much a user cannot.

    PYTHONPATH=src python scripts/check_quant_accuracy.py
"""
import sys

import numpy as np

SEED = 0
MAX_NEW = 8
# max |logit drift| allowed per arch family.  Measured drift on these
# reduced configs is <= 0.005 (see the printed table); the 10x headroom
# absorbs accumulation differences across BLAS backends without letting
# a real regression through.
DRIFT_BOUND = 0.05


def _recording_engine_cls():
    from repro.serving import ServingEngine

    class LogitRecordingEngine(ServingEngine):
        """Records the logits row behind every emitted token, per rid."""

        def _init_recorder(self):
            self.recorded = {}

        def _sample_row(self, logits, b, req):
            self.recorded.setdefault(req.rid, []).append(
                logits[b].copy())
            return super()._sample_row(logits, b, req)

    return LogitRecordingEngine


def run_family(name, plan_fp, plan_i8, mesh, frames_of=None):
    from repro.configs import get_config, reduced
    from repro.core import model
    from repro.serving import Request

    Eng = _recording_engine_cls()
    cfg = reduced(get_config(name), dtype="float32")
    params = model.init_params(cfg, plan_fp, seed=SEED)
    rng = np.random.RandomState(SEED)
    frames = frames_of(cfg, rng) if frames_of else None

    def run(plan):
        eng = Eng.build_paged(cfg, plan, mesh, 2, 64, params,
                              page_size=8, prefill_chunk=8)
        eng._init_recorder()
        reqs = [Request(rid=i,
                        prompt=rng_p.randint(2, cfg.vocab_size,
                                             L).astype(np.int32),
                        max_new_tokens=MAX_NEW,
                        frames=(frames[i % len(frames)] if frames else None))
                for i, L in enumerate([13, 9, 17, 6])]
        for r in reqs:
            eng.submit(r)
        eng.run(max_ticks=2000)
        assert all(r.done for r in reqs), name
        return ({r.rid: tuple(r.out_tokens) for r in reqs}, eng.recorded)

    rng_p = np.random.RandomState(SEED + 1)
    fp_toks, fp_logits = run(plan_fp)
    rng_p = np.random.RandomState(SEED + 1)       # identical prompts
    i8_toks, i8_logits = run(plan_i8)

    drift = 0.0
    for rid, rows in fp_logits.items():
        got = i8_logits.get(rid, [])
        assert len(got) == len(rows), (name, rid)
        for a, b in zip(rows, got, strict=True):
            drift = max(drift, float(np.abs(a - b).max()))
    identical = fp_toks == i8_toks
    status = "ok  " if identical and drift <= DRIFT_BOUND else "FAIL"
    print(f"{status} {name:24s} greedy_identical={identical} "
          f"max_logit_drift={drift:.4f} (bound {DRIFT_BOUND})")
    if not identical:
        for rid in sorted(fp_toks):
            if i8_toks.get(rid) != fp_toks[rid]:
                print(f"  rid {rid}:\n    fp   {fp_toks[rid]}"
                      f"\n    int8 {i8_toks.get(rid)}")
    return identical and drift <= DRIFT_BOUND


def main():
    from repro.core.partition import ShardingPlan
    from repro.launch.mesh import host_mesh

    mesh = host_mesh(tp=1, dp=1)
    fp = ShardingPlan(tp=1, kv_cache_dtype="float32")
    i8_kv = ShardingPlan(tp=1, kv_cache_dtype="int8")
    i8_all = ShardingPlan(tp=1, kv_cache_dtype="int8",
                          ssm_cache_dtype="int8")

    def enc_frames(cfg, rng):
        return [rng.randn(cfg.enc_seq_len, cfg.d_model).astype(np.float32)
                for _ in range(2)]

    ok = True
    ok &= run_family("tinyllama-42m", fp, i8_kv, mesh)
    ok &= run_family("hymba-1.5b", fp, i8_all, mesh)
    ok &= run_family("mamba2-370m", fp, i8_all, mesh)
    ok &= run_family("seamless-m4t-large-v2", fp, i8_kv, mesh,
                     frames_of=enc_frames)
    if not ok:
        print("accuracy gate FAILED")
        return 1
    print("accuracy gate passed: greedy-identical, drift within bound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
