#!/usr/bin/env python
"""Speculative-decoding identity gate (CI: the ``spec-decode-identity`` step).

Speculative decoding must be a pure latency optimization: for every
request, the engine running with ``speculative=k`` must emit EXACTLY the
tokens the one-token engine emits — greedy and seeded-sampled, under every
scheduling policy, and with dp replicas.  This script runs the speculative
engine against the one-token oracle over a matrix of

    temperature in {0.0 (greedy), 0.7 (seeded sampling)}
  x policy      in {fcfs, priority(+preemption), fair}
  x dp          in {1, 2}

on a tiny reduced config (CPU), with prompts built from a shared prefix
plus repeating motifs so the prompt-lookup draft source actually proposes
(and sometimes loses) drafts.  Any token divergence exits non-zero; it
also fails if the speculative runs never accepted a draft token (the gate
must exercise the verify path, not vacuously pass through the one-token
fallback).

A second matrix covers the quantized pools: with
``kv_cache_dtype="int8"`` the greedy short-horizon outputs must stay
token-identical to the FLOAT oracle — speculative off AND on, across
dp {1, 2} x {fcfs, priority, fair} — so quantization composes with
speculation, preemption and dp routing without changing a single token.

Every oracle runs with ``overlap=False`` (the serial plan-dispatch-
collect loop) while the candidate rows run pipelined, so each comparison
also certifies that one-tick-ahead execution changes no token.  A third
matrix covers disaggregation: dp=2 with ``disagg=(1, 1)`` — prefill on
replica 0, page-transfer handoff, decode on replica 1 — against the dp=1
serial oracle, across {greedy, seeded sampling} x spec {0, K} plus an
int8 row.

    PYTHONPATH=src python scripts/check_spec_identity.py
"""
import functools
import sys

import numpy as np

SEED = 0
K = 4


def build_prompts(cfg, rng, n=6):
    """Shared system prefix + per-request motif repetitions: radix-cache
    hits for the draft corpus, in-context repeats for prompt lookup."""
    shared = rng.randint(2, cfg.vocab_size, 12).astype(np.int32)
    prompts = []
    for i in range(n):
        motif = rng.randint(2, cfg.vocab_size, 3 + i % 3).astype(np.int32)
        body = np.tile(motif, 4)[: 8 + 3 * (i % 4)]
        prompts.append(np.concatenate([shared, body]).astype(np.int32))
    return prompts


def run_engine(cfg, plan, params, mesh, prompts, *, speculative, policy,
               temperature, dp, overlap=True, disagg=None):
    from repro.serving import (FairScheduler, PriorityScheduler, Request,
                               SamplerConfig, ServingEngine)
    scheduler = None
    if policy == "priority":
        scheduler = functools.partial(PriorityScheduler, preemption=True)
    elif policy == "fair":
        scheduler = FairScheduler
    eng = ServingEngine.build_paged(
        cfg, plan, mesh, 2, 64, params, page_size=8, prefill_chunk=8,
        sampler=SamplerConfig(temperature=temperature, top_k=40),
        prefix_cache=True, scheduler=scheduler, rng_seed=SEED, dp=dp,
        speculative=speculative, overlap=overlap, disagg=disagg)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=12,
                    priority=10 if i % 3 == 0 else 0, client_id=i % 2)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_ticks=3000)
    assert all(r.done for r in reqs), \
        f"undrained requests: {[r.rid for r in reqs if not r.done]}"
    return {r.rid: tuple(r.out_tokens) for r in reqs}, stats


def main():
    from repro.configs import get_config, reduced
    from repro.core import model
    from repro.core.partition import ShardingPlan
    from repro.launch.mesh import host_mesh

    cfg = reduced(get_config("tinyllama-42m"), dtype="float32")
    plan = ShardingPlan(tp=1, kv_cache_dtype="float32")
    mesh = host_mesh(tp=1, dp=1)
    params = model.init_params(cfg, plan, seed=SEED)
    rng = np.random.RandomState(SEED)
    prompts = build_prompts(cfg, rng)

    failures, total_accepted = 0, 0
    for dp in (1, 2):
        for policy in ("fcfs", "priority", "fair"):
            for temp in (0.0, 0.7):
                tag = f"dp={dp} policy={policy} temp={temp}"
                oracle, _ = run_engine(cfg, plan, params, mesh, prompts,
                                       speculative=0, policy=policy,
                                       temperature=temp, dp=dp,
                                       overlap=False)
                spec, st = run_engine(cfg, plan, params, mesh, prompts,
                                      speculative=K, policy=policy,
                                      temperature=temp, dp=dp)
                total_accepted += st.spec_accepted
                if spec == oracle:
                    print(f"ok   {tag}  accepted={st.spec_accepted}"
                          f"/{st.spec_drafted} drafted "
                          f"apt={st.accepted_tokens_per_tick:.2f}")
                    continue
                failures += 1
                print(f"FAIL {tag}: token divergence")
                for rid in sorted(oracle):
                    if spec.get(rid) != oracle[rid]:
                        print(f"  rid {rid}:\n    oracle {oracle[rid]}"
                              f"\n    spec   {spec.get(rid)}")
    # quantized pools: int8 greedy rows vs the fp oracle (spec off and on)
    plan_i8 = ShardingPlan(tp=1, kv_cache_dtype="int8")
    for dp in (1, 2):
        for policy in ("fcfs", "priority", "fair"):
            oracle, _ = run_engine(cfg, plan, params, mesh, prompts,
                                   speculative=0, policy=policy,
                                   temperature=0.0, dp=dp, overlap=False)
            for spec_k in (0, K):
                tag = f"kv=int8 dp={dp} policy={policy} spec={spec_k}"
                got, st = run_engine(cfg, plan_i8, params, mesh, prompts,
                                     speculative=spec_k, policy=policy,
                                     temperature=0.0, dp=dp)
                total_accepted += st.spec_accepted
                if got == oracle:
                    print(f"ok   {tag}")
                    continue
                failures += 1
                print(f"FAIL {tag}: token divergence vs fp oracle")
                for rid in sorted(oracle):
                    if got.get(rid) != oracle[rid]:
                        print(f"  rid {rid}:\n    oracle {oracle[rid]}"
                              f"\n    int8   {got.get(rid)}")
    # disaggregated serving: dp=2 prefill/decode split vs the dp=1 serial
    # oracle — the page-transfer handoff must change no token either
    for temp in (0.0, 0.7):
        oracle, _ = run_engine(cfg, plan, params, mesh, prompts,
                               speculative=0, policy="fcfs",
                               temperature=temp, dp=1, overlap=False)
        for spec_k in (0, K):
            tag = f"disagg=1:1 temp={temp} spec={spec_k}"
            got, st = run_engine(cfg, plan, params, mesh, prompts,
                                 speculative=spec_k, policy="fcfs",
                                 temperature=temp, dp=2, disagg=(1, 1))
            total_accepted += st.spec_accepted
            if got == oracle and st.handoffs == len(prompts):
                print(f"ok   {tag}  handoffs={st.handoffs} "
                      f"pages_transferred={st.pages_transferred}")
                continue
            failures += 1
            if st.handoffs != len(prompts):
                print(f"FAIL {tag}: {st.handoffs} handoffs for "
                      f"{len(prompts)} requests — the disagg path was "
                      f"not exercised")
            else:
                print(f"FAIL {tag}: token divergence vs serial dp=1 oracle")
                for rid in sorted(oracle):
                    if got.get(rid) != oracle[rid]:
                        print(f"  rid {rid}:\n    oracle {oracle[rid]}"
                              f"\n    disagg {got.get(rid)}")
    oracle, _ = run_engine(cfg, plan, params, mesh, prompts, speculative=0,
                           policy="fcfs", temperature=0.0, dp=1,
                           overlap=False)
    got, st = run_engine(cfg, plan_i8, params, mesh, prompts, speculative=0,
                         policy="fcfs", temperature=0.0, dp=2,
                         disagg=(1, 1))
    if got == oracle:
        print(f"ok   disagg=1:1 kv=int8 greedy  handoffs={st.handoffs}")
    else:
        failures += 1
        print("FAIL disagg=1:1 kv=int8 greedy: token divergence")
    if total_accepted == 0:
        print("FAIL: no draft token was ever accepted — the verify path "
              "was not exercised")
        failures += 1
    if failures:
        print(f"{failures} configuration(s) diverged")
        return 1
    print(f"all configurations token-identical "
          f"(total accepted draft tokens: {total_accepted})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
